//! File-descriptor hygiene under connection churn.
//!
//! The epoll backend owns kernel objects the sweep backend never touches —
//! the epoll instance, the worker-pool waker, per-edge doorbells — and
//! every TCP edge adds a socket on both sides plus a registration that must
//! be deregistered on hangup.  A leak of any of these survives every
//! byte-level parity test (the traffic is identical) and only shows up as
//! descriptor exhaustion hours into a real serving session.  So this test
//! measures the one thing that matters directly: join and leave 256 edges
//! through the reactor on EACH readiness backend, then assert the process'
//! `/proc/self/fd` population is exactly back at its baseline.
//!
//! Everything runs inside one `#[test]` on purpose: the descriptor table is
//! process-global, and a concurrently running test opening so much as a
//! socket would make the counts lie.

#![cfg(target_os = "linux")]

use c3sl::transport::inproc_reactor_pair_with;
use c3sl::transport::reactor::{Event, NbTcp, Reactor, ReactorConfig, ReactorConn};
use c3sl::transport::readiness::ReadinessBackend;
use c3sl::transport::tcp::Tcp;
use std::time::{Duration, Instant};

/// Live descriptors right now.  The `read_dir` handle itself is open while
/// counting, so the absolute number is one high — a constant bias that
/// cancels in the baseline comparison.
fn fd_count() -> usize {
    std::fs::read_dir("/proc/self/fd")
        .expect("procfs must be mounted on Linux")
        .count()
}

/// Drive `r` until every connection has left, draining events.  The
/// reactor is caller-driven (no background threads), so when this returns
/// every per-edge descriptor the reactor held is closed and deregistered.
fn drain_until_empty(r: &mut Reactor, events: &mut Vec<Event>) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while r.open_count() > 0 {
        assert!(
            Instant::now() < deadline,
            "edges never drained — a leave went unnoticed by the reactor"
        );
        r.poll_wait(events, 10);
        events.clear();
    }
}

/// One churn round: `edges` clients join over real TCP, then immediately
/// leave; the reactor must notice every hangup (EOF or reset — both are
/// legitimate leaves) and close its side.  Dropping the reactor at the end
/// releases the backend's own descriptors too.
fn tcp_churn_round(backend: ReadinessBackend, edges: usize) {
    let listener = Tcp::bind("127.0.0.1:0").expect("bind churn listener");
    let addr = listener
        .local_addr()
        .expect("bound listener has an address")
        .to_string();
    let clients: Vec<_> = (0..edges)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // join, then leave by dropping the socket
                let _edge = Tcp::connect(&addr).expect("churn client connect");
            })
        })
        .collect();
    let streams =
        Tcp::accept_streams(&listener, edges, Duration::from_secs(30)).expect("accept churn edges");
    let conns: Vec<Box<dyn ReactorConn>> = streams
        .into_iter()
        .map(|s| Box::new(NbTcp::from_stream(s).expect("nonblocking edge")) as Box<dyn ReactorConn>)
        .collect();
    let mut r = Reactor::new(conns, ReactorConfig { backend, ..ReactorConfig::default() });
    assert_eq!(
        r.backend(),
        backend,
        "the requested readiness backend must realize on Linux TCP edges"
    );
    let mut events = Vec::new();
    drain_until_empty(&mut r, &mut events);
    for c in clients {
        c.join().expect("churn client thread");
    }
}

/// In-proc doorbell churn: each edge is a doorbelled in-proc pair — the
/// join allocates the doorbell descriptor, the leave (dropping the edge
/// endpoint) must ring it, be observed, and release it.  This is the
/// drop-order protocol `tests/interleave.rs` pins, exercised here for its
/// descriptor lifecycle.
fn doorbell_churn_round(backend: ReadinessBackend, edges: usize) {
    for _ in 0..edges {
        let (edge, nb) = inproc_reactor_pair_with(true);
        let mut r = Reactor::new(
            vec![Box::new(nb) as Box<dyn ReactorConn>],
            ReactorConfig { backend, ..ReactorConfig::default() },
        );
        drop(edge); // the leave
        let mut events = Vec::new();
        drain_until_empty(&mut r, &mut events);
    }
}

fn churn(backend: ReadinessBackend) {
    // settle one-time allocations (DNS-free loopback still warms libc
    // internals, thread stacks, etc.) before taking the baseline
    tcp_churn_round(backend, 4);
    doorbell_churn_round(backend, 4);
    let baseline = fd_count();

    const ROUNDS: usize = 8;
    const EDGES: usize = 32; // 8 × 32 = 256 join/leave edges per backend
    for _ in 0..ROUNDS {
        tcp_churn_round(backend, EDGES);
    }
    doorbell_churn_round(backend, 64);

    assert_eq!(
        fd_count(),
        baseline,
        "descriptor leak: the {} backend did not return every fd after churn",
        backend.name()
    );
}

#[test]
fn fd_population_returns_to_baseline_after_256_edge_churn_on_both_backends() {
    churn(ReadinessBackend::Sweep);
    churn(ReadinessBackend::Epoll);
}
