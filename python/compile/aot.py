# AOT compiler: lower every split-learning step function to HLO **text** and
# emit a manifest.json describing argument/result shapes for the rust runtime.
#
# HLO text — NOT lowered.compile() or proto .serialize() — is the interchange
# format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
# the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
# the text parser reassigns ids and round-trips cleanly.  See
# /opt/xla-example/README.md and gen_hlo.py.
#
# Usage:
#   cd python && python -m compile.aot --preset tiny --out ../artifacts
#   cd python && python -m compile.aot --preset tiny --kernel fft ...

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_registry
from . import split

DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("int32"): "i32",
    jnp.dtype("uint32"): "u32",
    jnp.dtype("bfloat16"): "bf16",
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(x):
    return {"shape": list(x.shape), "dtype": DTYPE_NAMES[jnp.dtype(x.dtype)]}


def lower_fn(fn, example_args, path: str):
    """Lower fn at example_args, write HLO text, return manifest entry."""
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *example_args)
    entry = {
        "args": [_spec(a) for a in example_args],
        "outputs": [_spec(o) for o in out_avals],
        "hlo_bytes": len(text),
        "lower_seconds": round(time.time() - t0, 2),
    }
    return entry


def _shape_struct(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def emit_model(cfg, out_root: str) -> dict:
    """Emit the full artifact set for one ModelConfig; return manifest dict."""
    edge, cloud, d_tx, d_cut = cfg.build()
    b, img, ncls = cfg.batch, cfg.image, cfg.classes
    in_shape = (3, img, img)

    rng = jax.random.PRNGKey(0)
    edge_params, edge_out = edge.init(rng, in_shape)
    cloud_params, cloud_out = cloud.init(rng, edge_out)
    assert edge_out == (d_tx,), (edge_out, d_tx)
    assert cloud_out == (ncls,), (cloud_out, ncls)

    edge_leaves, edge_tree = split.flatten_spec(edge_params)
    cloud_leaves, cloud_tree = split.flatten_spec(cloud_params)
    ne, nc = len(edge_leaves), len(cloud_leaves)

    outdir = os.path.join(out_root, cfg.key)
    os.makedirs(outdir, exist_ok=True)

    seed = _shape_struct((2,), jnp.uint32)
    x = _shape_struct((b, 3, img, img))
    y = _shape_struct((b,), jnp.int32)
    ztx = _shape_struct((b, d_tx))
    eleaf_specs = [_shape_struct(l.shape, l.dtype) for l in edge_leaves]
    cleaf_specs = [_shape_struct(l.shape, l.dtype) for l in cloud_leaves]
    scalar = _shape_struct((), jnp.float32)

    manifest = {
        "key": cfg.key,
        "arch": cfg.arch,
        "width": cfg.width,
        "image": img,
        "classes": ncls,
        "batch": b,
        "d_tx": d_tx,
        "d_cut": d_cut,
        "bnpp_ratio": cfg.bnpp_ratio,
        "edge_param_leaves": ne,
        "cloud_param_leaves": nc,
        "edge_params": [_spec(l) for l in edge_leaves],
        "cloud_params": [_spec(l) for l in cloud_leaves],
        "artifacts": {},
    }
    art = manifest["artifacts"]

    def emit(name, fn, args):
        path = os.path.join(outdir, f"{name}.hlo.txt")
        art[name] = lower_fn(fn, args, path)
        art[name]["file"] = f"{name}.hlo.txt"
        print(f"  {cfg.key}/{name}: {art[name]['hlo_bytes']} bytes "
              f"({art[name]['lower_seconds']}s)")

    emit("edge_init", split.make_init(edge, in_shape), (seed,))
    emit("cloud_init", split.make_init(cloud, edge_out), (seed,))
    emit("edge_fwd", split.make_edge_fwd(edge, edge_tree, ne),
         tuple(eleaf_specs) + (x,))
    emit("edge_bwd", split.make_edge_bwd(edge, edge_tree, ne),
         tuple(eleaf_specs) + (x, ztx))
    emit("cloud_step", split.make_cloud_step(cloud, cloud_tree, nc),
         tuple(cleaf_specs) + (ztx, y))
    emit("cloud_eval", split.make_cloud_eval(cloud, cloud_tree, nc),
         tuple(cleaf_specs) + (ztx, y))
    emit("edge_adam", split.make_adam(ne),
         tuple(eleaf_specs) * 4 + (scalar, scalar))
    emit("cloud_adam", split.make_adam(nc),
         tuple(cleaf_specs) * 4 + (scalar, scalar))

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def emit_codec(cfg, r: int, kernel: str, out_root: str) -> dict:
    """Emit the C3 codec artifact set for ratio R (model-independent except
    for B and D_tx)."""
    _, _, d_tx, _ = cfg.build()
    b = cfg.batch
    if b % r != 0:
        raise ValueError(f"batch {b} not divisible by R={r}")
    g = b // r

    outdir = os.path.join(out_root, cfg.key, f"codec_c3_r{r}")
    os.makedirs(outdir, exist_ok=True)

    seed = _shape_struct((2,), jnp.uint32)
    zflat = _shape_struct((b, d_tx))
    keys = _shape_struct((r, d_tx))
    s = _shape_struct((g, d_tx))

    manifest = {"key": cfg.key, "r": r, "g": g, "d": d_tx, "batch": b,
                "kernel": kernel, "artifacts": {}}
    art = manifest["artifacts"]

    def emit(name, fn, args):
        path = os.path.join(outdir, f"{name}.hlo.txt")
        art[name] = lower_fn(fn, args, path)
        art[name]["file"] = f"{name}.hlo.txt"
        print(f"  {cfg.key}/codec_c3_r{r}/{name}: {art[name]['hlo_bytes']} bytes "
              f"({art[name]['lower_seconds']}s)")

    emit("gen_keys", split.make_gen_keys(r, d_tx), (seed,))
    emit("c3_encode", split.make_c3_encode(b, r, d_tx, kernel), (zflat, keys))
    emit("c3_decode", split.make_c3_decode(b, r, d_tx, kernel), (s, keys))

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny",
                    help="preset name or model key (see compile/model.py)")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--kernel", default="pallas", choices=["pallas", "fft"],
                    help="C3 codec implementation to lower")
    ap.add_argument("--ratios", default=None,
                    help="comma-separated C3 ratios (default: 2,4,8,16)")
    args = ap.parse_args()

    cfgs = model_registry.resolve(args.preset)
    ratios = ([int(r) for r in args.ratios.split(",")] if args.ratios
              else model_registry.C3_RATIOS)

    t0 = time.time()
    for cfg in cfgs:
        print(f"[aot] model {cfg.key}")
        emit_model(cfg, args.out)
        # C3 codecs only make sense for the un-composed (non-bnpp) models.
        if cfg.bnpp_ratio is None:
            for r in ratios:
                emit_codec(cfg, r, args.kernel, args.out)
    print(f"[aot] done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
