# Core combinators: Layer = (init, apply); Sequential chains layers.
#
# init(rng, in_shape) -> (params, out_shape)   — shapes exclude the batch dim
# apply(params, x)    -> y                     — x is batched (N leading)
#
# Params are nested lists/tuples of jnp arrays: a plain JAX pytree.

from typing import Callable, NamedTuple, Sequence, Tuple

import jax


class Layer(NamedTuple):
    """A pure (init, apply) pair with a debug name."""

    name: str
    init: Callable  # (rng, in_shape) -> (params, out_shape)
    apply: Callable  # (params, x) -> y


def Identity() -> Layer:
    """No-op layer (used for the vanilla-SL codec slot)."""
    return Layer("identity", lambda rng, s: ([], s), lambda p, x: x)


def Lambda(name: str, fn: Callable, shape_fn: Callable = None) -> Layer:
    """Parameter-free layer from a function.  shape_fn maps in_shape→out_shape."""
    sf = shape_fn or (lambda s: s)
    return Layer(name, lambda rng, s: ([], sf(s)), lambda p, x: fn(x))


def Sequential(layers: Sequence[Layer], name: str = "seq") -> Layer:
    """Chain layers; params is the list of per-layer params."""
    layers = list(layers)

    def init(rng, in_shape):
        params = []
        shape = in_shape
        for layer in layers:
            rng, sub = jax.random.split(rng)
            p, shape = layer.init(sub, shape)
            params.append(p)
        return params, shape

    def apply(params, x):
        for layer, p in zip(layers, params):
            x = layer.apply(p, x)
        return x

    return Layer(name, init, apply)
