//! Minimal JSON substrate: parser + writer.
//!
//! Used for the AOT `manifest.json` files and for metrics dumps.  No serde in
//! this environment; the grammar we need is small and fully covered here
//! (objects, arrays, strings with escapes, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One JSON value.  Numbers are uniformly `f64` (like JavaScript); object
/// keys are kept sorted (`BTreeMap`) so serialization is deterministic —
/// important for committed artifacts like the bench baselines.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (integers included — see [`Json::as_i64`]).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, keys sorted.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    /// Member `key` of an object (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Element `i` of an array (`None` for non-arrays / out of range).
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The numeric payload, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric payload as a `usize` (manifest shapes/counts), or `None`
    /// unless the value is an exactly-representable non-negative integer.
    ///
    /// The old `as`-cast version silently saturated: `-3` read as `0`, NaN
    /// as `0`, `2.7` as `2` — a malformed manifest dimension became a
    /// plausible small number instead of a load error.
    pub fn as_usize(&self) -> Option<usize> {
        let x = self.as_f64()?;
        if !exact_int(x) || x < 0.0 {
            return None;
        }
        Some(x as usize)
    }

    /// The numeric payload as an `i64`, or `None` unless the value is an
    /// exactly-representable integer (no NaN, no fractional part, within
    /// the f64 exact-integer range — same rationale as [`Json::as_usize`]).
    pub fn as_i64(&self) -> Option<i64> {
        let x = self.as_f64()?;
        if !exact_int(x) {
            return None;
        }
        Some(x as i64)
    }

    /// The string payload, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean payload, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key → value map, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- construction helpers for writers ---------------------------------

    /// An object from `(key, value)` pairs (keys are copied).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// A number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// A string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// An array.
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    // ---- serialization (stringify via Display / `.to_string()`) -----------
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Stringification: `json.to_string()` (via the blanket `ToString`) and
/// `format!("{json}")` both produce the compact wire form.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// A parse failure, pinned to its byte offset in the input.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset the error was detected at.
    pub pos: usize,
    /// What went wrong there.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse one complete JSON document (trailing data is an error).  Fully
/// checked — malformed input returns a positioned [`ParseError`], never a
/// panic.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let rest = &self.b[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Whether `x` is an integer every one of whose values survives a round
/// trip through `f64` — finite, no fractional part, and within ±2^53
/// (beyond that, adjacent integers alias and an `as` cast fabricates data).
fn exact_int(x: f64) -> bool {
    x.is_finite() && x.fract() == 0.0 && x.abs() <= 9_007_199_254_740_992.0
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"obj":{"k":true},"z":null}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors_have_position() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn integer_accessors_reject_unrepresentable_values() {
        // the regression: `as` casts silently saturated these to 0/garbage
        assert_eq!(Json::Num(-3.0).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_i64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(f64::NEG_INFINITY).as_i64(), None);
        assert_eq!(Json::Num(2.7).as_usize(), None);
        assert_eq!(Json::Num(-0.5).as_i64(), None);
        // beyond 2^53 adjacent integers alias in f64 — refuse to invent one
        assert_eq!(Json::Num(1e300).as_i64(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        // non-numbers still read as None, as before
        assert_eq!(Json::Str("7".into()).as_usize(), None);
        assert_eq!(Json::Null.as_i64(), None);
    }

    #[test]
    fn integer_accessors_accept_exact_integers() {
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(32.0).as_usize(), Some(32));
        assert_eq!(Json::Num(-7.0).as_i64(), Some(-7));
        assert_eq!(Json::Num(-0.0).as_usize(), Some(0));
        // 2^53, the largest f64 whose integer neighborhood is still exact
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_i64(), Some(1 << 53));
        // parse path too: manifest-style literals keep working
        assert_eq!(parse("1024").unwrap().as_usize(), Some(1024));
        assert_eq!(parse("-12").unwrap().as_i64(), Some(-12));
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"artifacts": {"edge_fwd": {"args": [{"shape": [32, 3, 16, 16], "dtype": "f32"}], "file": "edge_fwd.hlo.txt"}}}"#;
        let j = parse(src).unwrap();
        let args = j.get("artifacts").unwrap().get("edge_fwd").unwrap()
            .get("args").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = args[0].get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![32, 3, 16, 16]);
    }
}
