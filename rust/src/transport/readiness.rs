//! OS readiness substrate for the reactor: epoll + eventfd, std-only.
//!
//! The portable reactor backend ([`super::reactor`]) discovers work by
//! *sweeping* — polling every connection each pass and sleeping a fixed
//! `poll_us` when nothing moved.  That burns a full CPU at high idle fan-in
//! (N connections × 10k sweeps/s of `WouldBlock` syscalls) and taxes every
//! worker-pool reply with up to one `poll_us` tick of discovery latency.
//! This module provides the event-driven alternative on Linux:
//!
//! * [`Epoll`] — a thin, safe wrapper over raw `epoll_create1` /
//!   `epoll_ctl` / `epoll_wait` FFI (no crate dependency, keeping the
//!   crate's std-only stance).  The reactor registers per-connection
//!   *interest* (read / write) and blocks in [`Epoll::wait`] until the OS
//!   reports readiness — zero CPU while every edge is idle.
//! * [`EventFd`] / [`WakeHandle`] — an `eventfd`-based waker.  Codec
//!   workers ring it when a job completes, waking the I/O thread out of
//!   `epoll_wait` immediately instead of on the next timed sweep.  The
//!   eventfd is a kernel *counter*, so a ring that lands before the waiter
//!   enters `epoll_wait` is never lost: level-triggered readiness holds
//!   until the counter is [`WakeHandle::clear`]ed.
//! * [`ReadinessBackend`] — the `[transport] backend = "epoll" | "sweep"`
//!   knob ([`ReadinessBackend::platform_default`] picks `epoll` on Linux,
//!   `sweep` elsewhere; the sweep loop remains the portable fallback).
//! * [`thread_cpu_time`] — `CLOCK_THREAD_CPUTIME_ID`, so the scale bench
//!   can report how much CPU the I/O thread actually burned per backend.
//!
//! Everything Linux-specific is `cfg(target_os = "linux")`-gated; on other
//! platforms the types still exist but are permanently unarmed, so callers
//! (the reactor, the in-proc doorbell) compile unchanged everywhere.

/// Raw OS file descriptor, as the FFI layer sees it (`c_int` everywhere —
/// on non-unix platforms nothing ever produces one, but the type keeps the
/// [`super::reactor::ReactorConn::readiness_fd`] signature portable).
pub type RawFd = std::os::raw::c_int;

/// Which readiness discovery the reactor runs on
/// (`[transport] backend = "epoll" | "sweep"`, CLI `--reactor-backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadinessBackend {
    /// Event-driven: block in `epoll_wait` on registered interest, wake on
    /// socket readiness / in-proc doorbells / the worker-pool eventfd.
    /// Linux only; zero idle CPU, immediate worker-completion replies.
    Epoll,
    /// Portable fallback: the original fair round-robin poll sweep with a
    /// timed idle backoff (`poll_us`).  Runs on every std platform.
    Sweep,
}

impl ReadinessBackend {
    /// Stable lowercase name, as written in configs and bench venue labels.
    pub fn name(self) -> &'static str {
        match self {
            ReadinessBackend::Epoll => "epoll",
            ReadinessBackend::Sweep => "sweep",
        }
    }

    /// Parse a config/CLI value (`"epoll"` or `"sweep"`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "epoll" => Some(ReadinessBackend::Epoll),
            "sweep" => Some(ReadinessBackend::Sweep),
            _ => None,
        }
    }

    /// The default for this platform: `epoll` on Linux, `sweep` elsewhere.
    pub fn platform_default() -> Self {
        if cfg!(target_os = "linux") {
            ReadinessBackend::Epoll
        } else {
            ReadinessBackend::Sweep
        }
    }

    /// Whether this backend can actually run on the current platform.
    pub fn supported(self) -> bool {
        match self {
            ReadinessBackend::Epoll => cfg!(target_os = "linux"),
            ReadinessBackend::Sweep => true,
        }
    }
}

/// Readiness interest for one registered connection: what the reactor wants
/// the OS to watch.  Read interest is armed whenever the connection may be
/// read (not held, outbox under its bound); write interest only while the
/// outbox has parked bytes — re-armed on partial writes, dropped the moment
/// the outbox drains, so a writable-and-empty socket never spins the loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    /// Watch for readable data (or peer close).
    pub read: bool,
    /// Watch for writability (only meaningful with a non-empty outbox).
    pub write: bool,
}

impl Interest {
    /// No interest at all — the connection should be *deregistered* (a
    /// held connection with an empty outbox must not wake the loop, not
    /// even via the always-reported error/hangup events).
    pub fn none() -> Self {
        Interest { read: false, write: false }
    }

    /// True when neither direction is watched.
    pub fn is_none(self) -> bool {
        !self.read && !self.write
    }
}

/// One readiness report from [`Epoll::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Ready {
    /// The `token` the fd was registered with (connection index, or
    /// [`WAKER_TOKEN`] for the worker-pool waker).
    pub token: u64,
    /// Data (or EOF/error) is readable.  Error and hangup conditions are
    /// folded in: a read attempt is what surfaces them as proper
    /// transport errors.
    pub readable: bool,
    /// The fd accepted more bytes (or errored; folded in likewise).
    pub writable: bool,
}

/// Registration token reserved for the reactor's own waker eventfd — never
/// a valid connection index.
pub const WAKER_TOKEN: u64 = u64::MAX;

// ---------------------------------------------------------------------------
// Linux: raw FFI over epoll(7) + eventfd(2) + clock_gettime(2).
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_long, c_uint, c_void};

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;
    pub const CLOCK_THREAD_CPUTIME_ID: c_int = 3;

    /// Matches the kernel's `struct epoll_event`: packed on x86-64 (the
    /// kernel ABI there has no padding between `events` and `data`),
    /// naturally aligned elsewhere — the same split glibc encodes with its
    /// `__EPOLL_PACKED` attribute.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: c_long,
        pub tv_nsec: c_long,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
        pub fn clock_gettime(clockid: c_int, tp: *mut Timespec) -> c_int;
    }
}

/// CPU time consumed by the *calling thread* (`CLOCK_THREAD_CPUTIME_ID`),
/// in seconds.  `None` where the clock is unavailable (non-Linux).  The
/// scale bench diffs two readings around a serve to report how much CPU the
/// I/O thread burned — the number the epoll backend exists to shrink.
pub fn thread_cpu_time() -> Option<f64> {
    #[cfg(target_os = "linux")]
    {
        let mut ts = sys::Timespec { tv_sec: 0, tv_nsec: 0 };
        // SAFETY: `ts` is a live, properly aligned Timespec matching the
        // kernel's struct layout; clock_gettime writes it or fails.
        let rc = unsafe { sys::clock_gettime(sys::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        if rc != 0 {
            return None;
        }
        Some(ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// A nonblocking `eventfd`: a kernel counter that is readable whenever
/// non-zero.  [`EventFd::ring`] adds to the counter (from any thread);
/// [`EventFd::clear`] resets it.  Because readiness is *level-triggered* on
/// the counter, a ring that happens-before the waiter's `epoll_wait` still
/// wakes it — the lost-wakeup race a condvar would have to be careful about
/// simply cannot happen.
#[cfg(target_os = "linux")]
#[derive(Debug)]
pub struct EventFd {
    fd: RawFd,
}

#[cfg(target_os = "linux")]
impl EventFd {
    /// Create a fresh counter (CLOEXEC + nonblocking).
    pub fn new() -> std::io::Result<Self> {
        // SAFETY: eventfd(2) takes no pointers; it returns a fresh fd we
        // own (closed in Drop) or a negative errno checked below.
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(EventFd { fd })
    }

    /// The descriptor to register with [`Epoll`] (read interest).
    pub fn raw_fd(&self) -> RawFd {
        self.fd
    }

    /// Add 1 to the counter, making the fd readable.  Thread-safe (`&self`:
    /// one `write(2)`).  A saturated counter returns `EAGAIN`, which is
    /// fine — the fd is already readable, so the wakeup is not lost.
    pub fn ring(&self) {
        let one: u64 = 1;
        // SAFETY: `one` is a live 8-byte u64 on this stack frame and
        // `self.fd` is an eventfd we own; write(2) reads exactly 8 bytes.
        let _ = unsafe { sys::write(self.fd, (&one as *const u64).cast(), 8) };
    }

    /// Reset the counter (one nonblocking `read(2)`; `EAGAIN` when already
    /// zero).  Clear *before* draining the guarded queue: anything enqueued
    /// after the clear re-rings and re-arms the level trigger.
    pub fn clear(&self) {
        let mut buf: u64 = 0;
        // SAFETY: `buf` is a live, writable 8-byte u64 on this stack frame;
        // an eventfd read(2) writes exactly 8 bytes or fails with EAGAIN.
        let _ = unsafe { sys::read(self.fd, (&mut buf as *mut u64).cast(), 8) };
    }
}

#[cfg(target_os = "linux")]
impl Drop for EventFd {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is the eventfd this struct owns exclusively;
        // it is closed exactly once, here.
        let _ = unsafe { sys::close(self.fd) };
    }
}

/// A cloneable, cross-thread wakeup handle over an [`EventFd`] — or a
/// no-op when unarmed (non-Linux, eventfd exhaustion, or sweep backend,
/// where waking is unnecessary).  Used two ways:
///
/// * the reactor's **worker waker**: codec workers [`WakeHandle::wake`]
///   after publishing a finished job, pulling the I/O thread out of
///   `epoll_wait` immediately;
/// * the in-proc **doorbell**: the blocking `InProc` edge endpoint rings
///   after every channel send (and on drop), giving channel-backed
///   connections a pollable fd like a socket's.
#[derive(Clone, Debug, Default)]
pub struct WakeHandle {
    #[cfg(target_os = "linux")]
    fd: Option<std::sync::Arc<EventFd>>,
}

impl WakeHandle {
    /// A permanently unarmed handle (every operation is a no-op).
    pub fn none() -> Self {
        WakeHandle::default()
    }

    /// A fresh armed handle.  Falls back to unarmed when the platform has
    /// no eventfd or the process is out of descriptors — callers degrade
    /// to sweep-based discovery instead of failing.
    pub fn armed() -> Self {
        #[cfg(target_os = "linux")]
        {
            WakeHandle { fd: EventFd::new().ok().map(std::sync::Arc::new) }
        }
        #[cfg(not(target_os = "linux"))]
        {
            WakeHandle {}
        }
    }

    /// Whether this handle actually wakes anything.
    pub fn is_armed(&self) -> bool {
        #[cfg(target_os = "linux")]
        {
            self.fd.is_some()
        }
        #[cfg(not(target_os = "linux"))]
        {
            false
        }
    }

    /// Ring the counter (no-op when unarmed).  Never lost: the level
    /// trigger holds until [`WakeHandle::clear`].
    pub fn wake(&self) {
        #[cfg(target_os = "linux")]
        if let Some(fd) = &self.fd {
            fd.ring();
        }
    }

    /// Reset the counter (no-op when unarmed).
    pub fn clear(&self) {
        #[cfg(target_os = "linux")]
        if let Some(fd) = &self.fd {
            fd.clear();
        }
    }

    /// The pollable descriptor behind this handle, when armed.
    pub fn raw_fd(&self) -> Option<RawFd> {
        #[cfg(target_os = "linux")]
        {
            self.fd.as_ref().map(|fd| fd.raw_fd())
        }
        #[cfg(not(target_os = "linux"))]
        {
            None
        }
    }
}

/// Safe wrapper over one epoll instance.  Registrations carry a `u64`
/// token (the reactor uses the connection index; [`WAKER_TOKEN`] marks the
/// worker waker) that [`Epoll::wait`] hands back with each readiness
/// report.  All readiness is level-triggered: un-consumed input (or an
/// un-cleared eventfd counter) keeps reporting until acted on, so no edge
/// condition can be missed between waits.
#[cfg(target_os = "linux")]
#[derive(Debug)]
pub struct Epoll {
    epfd: RawFd,
}

#[cfg(target_os = "linux")]
impl Epoll {
    /// Create an epoll instance (CLOEXEC).
    pub fn new() -> std::io::Result<Self> {
        // SAFETY: epoll_create1(2) takes no pointers; it returns a fresh
        // fd we own (closed in Drop) or a negative errno checked below.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Epoll { epfd })
    }

    fn ctl(&self, op: std::os::raw::c_int, fd: RawFd, interest: Interest, token: u64) -> std::io::Result<()> {
        let mut events = 0u32;
        if interest.read {
            events |= sys::EPOLLIN;
        }
        if interest.write {
            events |= sys::EPOLLOUT;
        }
        let mut ev = sys::EpollEvent { events, data: token };
        // SAFETY: `ev` is a live EpollEvent matching the kernel ABI layout
        // (see the cfg_attr on the struct); epoll_ctl only reads it.
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc != 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` under `token` with the given interest.
    pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Re-arm `fd`'s interest (must already be registered).
    pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Deregister `fd`.  Best-effort: an fd that was already closed (and
    /// therefore auto-removed by the kernel) is not an error worth
    /// surfacing, so failures are swallowed.
    pub fn del(&self, fd: RawFd) {
        let mut ev = sys::EpollEvent { events: 0, data: 0 };
        // SAFETY: `ev` is a live EpollEvent (pre-2.6.9 kernels demand a
        // non-null pointer even for DEL, which ignores its contents).
        let _ = unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, &mut ev) };
    }

    /// Block until at least one registered fd is ready or `timeout_ms`
    /// elapses (`0` = poll without blocking, negative = wait forever).
    /// Ready reports are appended to `ready` (cleared first); returns the
    /// report count.  `EINTR` retries internally.
    pub fn wait(&self, ready: &mut Vec<Ready>, timeout_ms: i32) -> std::io::Result<usize> {
        const CAP: usize = 256;
        ready.clear();
        let mut buf = [sys::EpollEvent { events: 0, data: 0 }; CAP];
        let n = loop {
            // SAFETY: `buf` is a live array of CAP properly laid-out
            // EpollEvents; the kernel writes at most `maxevents` = CAP
            // entries and we read back only the first `n` it reports.
            let n = unsafe {
                sys::epoll_wait(self.epfd, buf.as_mut_ptr(), CAP as std::os::raw::c_int, timeout_ms)
            };
            if n >= 0 {
                break n as usize;
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        };
        for ev in buf.iter().take(n) {
            let e = *ev;
            let bits = e.events;
            // Error/hangup are folded into both directions: the service
            // attempt (a read / write) is what turns them into a typed
            // transport error or a clean close.
            let err = bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0;
            ready.push(Ready {
                token: e.data,
                readable: bits & sys::EPOLLIN != 0 || err,
                writable: bits & sys::EPOLLOUT != 0 || err,
            });
        }
        Ok(n)
    }
}

#[cfg(target_os = "linux")]
impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.epfd` is the epoll fd this struct owns exclusively;
        // it is closed exactly once, here.
        let _ = unsafe { sys::close(self.epfd) };
    }
}

// ---------------------------------------------------------------------------
// SIGHUP: the ops control plane's live-reload trigger.
//
// All signal FFI lives here with the rest of the raw OS surface (repolint's
// FFI-containment scan confines `signal`/`raise` to this file).  The handler
// does the only async-signal-safe thing possible: bump an atomic counter.
// The reactor serve loop polls [`hangup_count`] between passes and performs
// the actual (allocating, locking) reload work on its own thread.
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod hup {
    use std::os::raw::c_int;
    use std::sync::atomic::AtomicU64;

    /// SIGHUP's number on Linux.
    pub const SIGHUP: c_int = 1;

    /// Hangups received since the handler was installed.
    pub static COUNT: AtomicU64 = AtomicU64::new(0);

    extern "C" {
        pub fn signal(signum: c_int, handler: usize) -> usize;
        pub fn raise(sig: c_int) -> c_int;
    }

    /// The installed handler: one relaxed atomic increment — allocation-free
    /// and lock-free, the whole async-signal-safe budget.
    pub extern "C" fn on_sighup(_sig: c_int) {
        COUNT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Install the process-wide SIGHUP handler that feeds [`hangup_count`].
/// Idempotent; a no-op on platforms without signals.  Best-effort: if the
/// handler cannot be installed the counter simply never advances and live
/// reload stays off — never a reason to fail a serve.
pub fn install_hangup_handler() {
    #[cfg(target_os = "linux")]
    {
        // SAFETY: signal(2) with a non-NULL handler address; `on_sighup` is
        // an `extern "C" fn(c_int)` matching the expected handler ABI and
        // lives for the whole program.  glibc's signal() installs
        // BSD/SA_RESTART semantics, so blocking syscalls resume.
        let _ = unsafe { hup::signal(hup::SIGHUP, hup::on_sighup as usize) };
    }
}

/// Number of SIGHUPs delivered since [`install_hangup_handler`] (0 before
/// install, and always 0 on platforms without signals).  Monotonic; callers
/// diff successive readings to detect a reload request.
pub fn hangup_count() -> u64 {
    #[cfg(target_os = "linux")]
    {
        hup::COUNT.load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(target_os = "linux"))]
    {
        0
    }
}

/// Deliver a SIGHUP to this process — how the reload tests (and the CI
/// ops-smoke step via `kill -HUP`) exercise the live path.  No-op on
/// platforms without signals.
pub fn raise_hangup() {
    #[cfg(target_os = "linux")]
    {
        // SAFETY: raise(3) takes no pointers; it synchronously delivers the
        // signal to this thread, running the handler installed above (or
        // the default, which for SIGHUP without a handler would terminate —
        // callers install first, exactly like an external `kill -HUP`).
        let _ = unsafe { hup::raise(hup::SIGHUP) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_and_parse_roundtrip() {
        for b in [ReadinessBackend::Epoll, ReadinessBackend::Sweep] {
            assert_eq!(ReadinessBackend::parse(b.name()), Some(b));
        }
        assert_eq!(ReadinessBackend::parse("magic"), None);
        assert!(ReadinessBackend::Sweep.supported());
        assert!(ReadinessBackend::platform_default().supported());
        #[cfg(target_os = "linux")]
        assert_eq!(ReadinessBackend::platform_default(), ReadinessBackend::Epoll);
        #[cfg(not(target_os = "linux"))]
        assert_eq!(ReadinessBackend::platform_default(), ReadinessBackend::Sweep);
    }

    #[test]
    fn unarmed_handle_is_inert() {
        let w = WakeHandle::none();
        assert!(!w.is_armed());
        assert_eq!(w.raw_fd(), None);
        w.wake();
        w.clear();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn eventfd_wake_before_wait_is_not_lost() {
        // The lost-wakeup race: the worker completes (and rings) just as —
        // or strictly before — the I/O thread enters epoll_wait.  The
        // eventfd counter is level-triggered, so the wait must return
        // immediately instead of sleeping out its timeout.
        let ep = Epoll::new().unwrap();
        let ef = EventFd::new().unwrap();
        ep.add(ef.raw_fd(), WAKER_TOKEN, Interest { read: true, write: false }).unwrap();

        ef.ring(); // happens-before the wait
        let mut ready = Vec::new();
        let t0 = std::time::Instant::now();
        let n = ep.wait(&mut ready, 5_000).unwrap();
        assert_eq!(n, 1, "pre-wait ring must wake the waiter");
        assert_eq!(ready[0].token, WAKER_TOKEN);
        assert!(ready[0].readable);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(1),
            "wake must be immediate, not a timeout expiry"
        );

        // clearing consumes the level trigger...
        ef.clear();
        assert_eq!(ep.wait(&mut ready, 0).unwrap(), 0, "cleared counter is quiet");

        // ...and a ring from another thread while blocked wakes promptly
        let fd = ef.raw_fd();
        let ringer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            let one: u64 = 1;
            // SAFETY: `one` is a live 8-byte u64 and `fd` outlives the
            // thread (the EventFd is joined before drop); write(2) reads
            // exactly 8 bytes.
            let _ = unsafe {
                super::sys::write(fd, (&one as *const u64).cast(), 8)
            };
        });
        let t0 = std::time::Instant::now();
        let n = ep.wait(&mut ready, 5_000).unwrap();
        ringer.join().unwrap();
        assert_eq!(n, 1);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "blocked waiter must wake on the ring, not the timeout"
        );
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn wake_handle_clear_then_requeue_rearms() {
        // The clear-before-drain contract: clear, then anything enqueued
        // after the clear re-rings — the level trigger re-arms.
        let ep = Epoll::new().unwrap();
        let w = WakeHandle::armed();
        assert!(w.is_armed());
        let fd = w.raw_fd().unwrap();
        ep.add(fd, 7, Interest { read: true, write: false }).unwrap();
        let mut ready = Vec::new();

        w.wake();
        w.wake(); // counter accumulates; still one readiness report
        assert_eq!(ep.wait(&mut ready, 0).unwrap(), 1);
        w.clear();
        assert_eq!(ep.wait(&mut ready, 0).unwrap(), 0);
        w.wake(); // post-clear ring re-arms
        assert_eq!(ep.wait(&mut ready, 0).unwrap(), 1);
        assert_eq!(ready[0].token, 7);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn interest_rearming_gates_reports() {
        // A registered-but-interestless fd must not report plain readiness
        // (the reactor's "held client" state), and MOD re-arms it.
        let ep = Epoll::new().unwrap();
        let ef = EventFd::new().unwrap();
        ep.add(ef.raw_fd(), 3, Interest::none()).unwrap();
        ef.ring();
        let mut ready = Vec::new();
        assert_eq!(ep.wait(&mut ready, 0).unwrap(), 0, "no interest → no report");
        ep.modify(ef.raw_fd(), 3, Interest { read: true, write: false }).unwrap();
        assert_eq!(ep.wait(&mut ready, 0).unwrap(), 1, "re-armed interest reports");
        ep.del(ef.raw_fd());
        assert_eq!(ep.wait(&mut ready, 0).unwrap(), 0, "deregistered fd is silent");
        ep.del(ef.raw_fd()); // double-del is best-effort, not a panic
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn sighup_counter_advances_on_raise() {
        install_hangup_handler();
        let before = hangup_count();
        raise_hangup();
        // raise(3) delivers synchronously to this thread, so the handler has
        // run by now; `>=` tolerates other tests hanging up concurrently
        assert!(hangup_count() >= before + 1);
        raise_hangup();
        assert!(hangup_count() >= before + 2);
    }

    #[test]
    fn hangup_count_is_monotonic() {
        let a = hangup_count();
        let b = hangup_count();
        assert!(b >= a);
    }

    #[test]
    fn thread_cpu_clock_is_monotonic_where_available() {
        if let Some(a) = thread_cpu_time() {
            // burn a little CPU so the clock visibly advances
            let mut acc = 0u64;
            for i in 0..2_000_000u64 {
                acc = acc.wrapping_add(i ^ (acc >> 3));
            }
            std::hint::black_box(acc);
            let b = thread_cpu_time().expect("clock stays available");
            assert!(b >= a, "thread CPU clock went backwards: {a} -> {b}");
        }
    }
}
