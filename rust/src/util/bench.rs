//! Shared policy plumbing for the benchmark-regression gates.
//!
//! Two benches gate against the committed `BENCH_baseline.json` —
//! `benches/codec_hotpath.rs` (the `host/*` venues) and
//! `benches/reactor_scale.rs` (the `reactor/*` venues) — and their
//! warn-vs-fail policy must stay in lockstep: the tolerance knob and the
//! calibrated-baseline switch live HERE, once, so a policy change cannot
//! silently diverge the two gates.  The venue-schema-specific comparison
//! loops remain in each bench (the schemas legitimately differ).

use crate::util::json::Json;

/// The relative regression tolerance every bench gate applies: env
/// `C3SL_BENCH_GATE_TOL` (a fraction, e.g. `0.15`), defaulting to 15%.
///
/// Invalid values are rejected loudly (panic): a negative or NaN tolerance
/// silently inverts or disables the regression comparison, and a typo'd
/// value that fails to parse used to fall back to the default — both turn
/// the gate into a no-op exactly when someone is trying to tune it.
pub fn gate_tolerance() -> f64 {
    match std::env::var("C3SL_BENCH_GATE_TOL") {
        Err(_) => 0.15,
        Ok(v) => match parse_tolerance(&v) {
            Ok(t) => t,
            Err(e) => panic!("invalid C3SL_BENCH_GATE_TOL: {e}"),
        },
    }
}

/// Validate a tolerance string: a finite, non-negative fraction.  Split out
/// from the env lookup so the rejection policy is unit-testable without
/// mutating process environment.
pub fn parse_tolerance(v: &str) -> Result<f64, String> {
    let t: f64 = v
        .trim()
        .parse()
        .map_err(|_| format!("{v:?} does not parse as a number"))?;
    if !t.is_finite() {
        return Err(format!("{v:?} is not finite (NaN/inf disable the gate)"));
    }
    if t < 0.0 {
        return Err(format!("{v:?} is negative (a negative tolerance inverts the gate)"));
    }
    Ok(t)
}

/// Whether a committed baseline is calibrated — i.e. its absolute numbers
/// were measured on the reference runner class, arming the hard checks.
/// A baseline WITHOUT the flag reads as calibrated (a hand-written
/// baseline that omits it should block on its numbers, not silently
/// downgrade to warnings); the committed uncalibrated baselines say
/// `"calibrated": false` explicitly.
pub fn calibrated(baseline: &Json) -> bool {
    baseline.get("calibrated").and_then(|v| v.as_bool()).unwrap_or(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn calibrated_flag_policy() {
        assert!(!calibrated(&parse(r#"{"calibrated": false}"#).unwrap()));
        assert!(calibrated(&parse(r#"{"calibrated": true}"#).unwrap()));
        // absent flag = armed: hand-written baselines must not silently
        // downgrade themselves to warnings
        assert!(calibrated(&parse(r#"{"venues": {}}"#).unwrap()));
    }

    #[test]
    fn tolerance_defaults_to_fifteen_percent() {
        // (env-var override is exercised by the benches themselves; the
        // default is the contract both gates share)
        if std::env::var("C3SL_BENCH_GATE_TOL").is_err() {
            assert!((gate_tolerance() - 0.15).abs() < 1e-12);
        }
    }

    #[test]
    fn tolerance_parser_accepts_valid_fractions() {
        assert_eq!(parse_tolerance("0.15").unwrap(), 0.15);
        assert_eq!(parse_tolerance("0").unwrap(), 0.0);
        assert_eq!(parse_tolerance(" 0.5 ").unwrap(), 0.5);
        // permissive above 1: a deliberate 200% tolerance is loose but sane
        assert_eq!(parse_tolerance("2.0").unwrap(), 2.0);
    }

    #[test]
    fn tolerance_parser_rejects_gate_disabling_values() {
        // each of these used to silently fall back to 0.15 (parse failure)
        // or flow straight into the comparison (negative / NaN / inf)
        assert!(parse_tolerance("-0.1").unwrap_err().contains("negative"));
        assert!(parse_tolerance("NaN").unwrap_err().contains("finite"));
        assert!(parse_tolerance("inf").unwrap_err().contains("finite"));
        assert!(parse_tolerance("15%").unwrap_err().contains("parse"));
        assert!(parse_tolerance("").unwrap_err().contains("parse"));
    }
}
