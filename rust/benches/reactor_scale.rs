//! Bench: multi-edge serving scale — thread-per-client vs the nonblocking
//! reactor, the ROADMAP's "dozens → thousands of edges" axis.
//!
//!   cargo bench --bench reactor_scale
//!   C3SL_BENCH_QUICK=1 cargo bench --bench reactor_scale   # CI smoke
//!
//! For each N ∈ {8, 64, 256} (quick: {8, 32}) the full multi-edge scenario
//! runs end to end over localhost TCP — N in-process edge threads each
//! training `steps` probe steps through the C3 codec in both directions —
//! once against the thread-per-client cloud (N serving threads) and once
//! against the reactor cloud (1 I/O thread + a codec worker pool).  Reported:
//! wall time, edges/s (concurrent sessions brought to completion per second)
//! and steps/s.  The same run also cross-checks byte accounting between the
//! two serving styles: identical geometry must produce identical aggregate
//! traffic no matter how the cloud is scheduled.

use c3sl::config::TransportKind;
use c3sl::coordinator::{run_multi_edge, MultiEdgeSpec};

fn main() {
    let quick = std::env::var("C3SL_BENCH_QUICK").is_ok();
    let ns: &[usize] = if quick { &[8, 32] } else { &[8, 64, 256] };
    let steps: u64 = if quick { 2 } else { 4 };
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(2, 8);
    println!(
        "# reactor scale: N edges x {steps} steps over localhost TCP \
         (R=2, D=256, B=8, {workers} codec workers)\n"
    );
    println!(
        "{:>6} {:<18} {:>10} {:>10} {:>10} {:>14}",
        "edges", "cloud", "wall s", "edges/s", "steps/s", "agg bytes"
    );

    let mut port = 40510u16;
    for &n in ns {
        let mut agg = [0u64; 2];
        for (mi, (label, reactor)) in
            [("thread-per-client", false), ("reactor", true)].into_iter().enumerate()
        {
            let spec = MultiEdgeSpec {
                edges: n,
                steps,
                r: 2,
                d: 256,
                batch: 8,
                seed: 1,
                workers,
                transport: TransportKind::Tcp,
                tcp_addr: format!("127.0.0.1:{port}"),
                ..MultiEdgeSpec::default()
            };
            let spec = MultiEdgeSpec { reactor, ..spec };
            port += 1;
            let out = run_multi_edge(&spec).unwrap_or_else(|e| {
                panic!("{label} run with {n} edges failed: {e}");
            });
            assert_eq!(out.cloud.total_steps(), steps * n as u64, "{label}: steps served");
            agg[mi] = out.cloud.total_rx() + out.cloud.total_tx();
            let wall = out.wall_seconds.max(1e-9);
            println!(
                "{:>6} {:<18} {:>10.3} {:>10.1} {:>10.1} {:>14}",
                n,
                label,
                wall,
                n as f64 / wall,
                (steps * n as u64) as f64 / wall,
                agg[mi],
            );
        }
        assert_eq!(
            agg[0], agg[1],
            "serving style must not change the bytes on the wire at N={n}"
        );
        println!();
    }
    println!("reactor_scale OK — identical traffic, one I/O thread instead of N");
}
