//! Timing helpers for metrics and the bench harness.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Wall time since start (or the last [`Timer::restart`]).
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// [`Timer::elapsed`] as fractional seconds.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Return the elapsed time and restart the stopwatch (lap timing).
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Measure a closure's wall time, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Bench statistics over repeated runs (used by the criterion-free harness).
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Timed iterations (warmup excluded).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Fastest iteration, in seconds — the noise-robust throughput basis.
    pub min_s: f64,
    /// Slowest iteration, in seconds.
    pub max_s: f64,
    /// Population standard deviation, in seconds.
    pub stddev_s: f64,
}

impl BenchStats {
    /// Items processed per second at the mean iteration time.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

/// Run `f` repeatedly: a warmup pass, then `iters` timed passes.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        std::hint::black_box(f());
        times.push(t.elapsed_secs());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / iters as f64;
    BenchStats {
        iters,
        mean_s: mean,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
        stddev_s: var.sqrt(),
    }
}

/// Human-readable duration.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn bench_stats_consistent() {
        let st = bench(1, 10, || (0..1000u64).sum::<u64>());
        assert_eq!(st.iters, 10);
        assert!(st.min_s <= st.mean_s && st.mean_s <= st.max_s);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
    }
}
