//! Deterministic-interleaving harness for the waker protocol and the
//! ShardGate admission state machine.
//!
//! Review of the epoll PR caught three races by hand: a lost wakeup when
//! the pump drained before clearing the eventfd, a hang when the in-proc
//! endpoint's drop rang its doorbell before disconnecting, and a proof
//! replay against a reused accept slot.  This harness turns all three into
//! machine-checked properties: `util::sched` enumerates EVERY interleaving
//! of the per-thread operation sequences (each operation is individually
//! atomic — a syscall on the kernel counter, one mutation under the gate
//! lock — so sequential replay of an interleaving is equivalent to a real
//! concurrent schedule), and each test asserts its invariant over all of
//! them.  The buggy orderings the review fixed are kept as negative
//! controls: the harness must DETECT the race when the discipline is
//! deliberately inverted, proving it would have caught the original bugs.

use c3sl::util::sched::{for_each_interleaving, interleaving_count};

#[cfg(target_os = "linux")]
mod waker {
    use super::*;
    use c3sl::transport::readiness::{Epoll, Interest, Ready, WakeHandle, WAKER_TOKEN};
    use std::collections::VecDeque;

    /// True while the armed handle's eventfd counter is non-zero (a
    /// zero-timeout epoll poll — exactly how the pump discovers the bell).
    fn bell_ready(ep: &Epoll, ready: &mut Vec<Ready>) -> bool {
        ep.wait(ready, 0).expect("epoll poll") > 0
    }

    fn armed_bell() -> (WakeHandle, Epoll, Vec<Ready>) {
        let bell = WakeHandle::armed();
        assert!(bell.is_armed(), "eventfd must arm on Linux");
        let ep = Epoll::new().expect("epoll instance");
        ep.add(
            bell.raw_fd().expect("armed handle has an fd"),
            WAKER_TOKEN,
            Interest { read: true, write: false },
        )
        .expect("register bell");
        (bell, ep, Vec::new())
    }

    /// Replay one schedule of producer ops (thread 0) against consumer ops
    /// (thread 1) and report whether a queued item ended up STRANDED: still
    /// queued, with the bell no longer readable — the lost-wakeup state, in
    /// which an epoll-blocked pump would sleep forever.
    ///
    /// `producer` and `consumer` are the per-thread op sequences, invoked
    /// with (queue, bell) in program order as the schedule dictates.
    fn strands(
        schedule: &[usize],
        producer: &[fn(&mut VecDeque<u64>, &WakeHandle)],
        consumer: &[fn(&mut VecDeque<u64>, &WakeHandle)],
    ) -> bool {
        let (bell, ep, mut ready) = armed_bell();
        let mut queue: VecDeque<u64> = VecDeque::new();
        let mut next = [0usize; 2];
        for &t in schedule {
            let ops = if t == 0 { producer } else { consumer };
            ops[next[t]](&mut queue, &bell);
            next[t] += 1;
        }
        !queue.is_empty() && !bell_ready(&ep, &mut ready)
    }

    fn op_push(q: &mut VecDeque<u64>, _b: &WakeHandle) {
        q.push_back(77);
    }
    fn op_ring(_q: &mut VecDeque<u64>, b: &WakeHandle) {
        b.wake();
    }
    fn op_clear(_q: &mut VecDeque<u64>, b: &WakeHandle) {
        b.clear();
    }
    fn op_drain(q: &mut VecDeque<u64>, _b: &WakeHandle) {
        q.clear();
    }

    /// PR-5 lost-wakeup race, pinned: with the shipped discipline —
    /// workers publish THEN ring, the pump clears THEN drains — no
    /// interleaving of the four operations strands a completion.  Invert
    /// either half and the harness finds the losing schedule, which is
    /// exactly the review finding that forced the ordering.
    #[test]
    fn waker_clear_before_drain_never_strands_a_completion() {
        let lens = [2, 2];
        assert_eq!(interleaving_count(&lens), 6);

        // shipped discipline: publish→ring vs clear→drain — safe everywhere
        for_each_interleaving(&lens, |s| {
            assert!(
                !strands(s, &[op_push, op_ring], &[op_clear, op_drain]),
                "lost wakeup under the shipped discipline at schedule {s:?}"
            );
        });

        // negative control #1: drain-before-clear loses the completion
        // that lands between the drain and the clear
        let mut losing = Vec::new();
        for_each_interleaving(&lens, |s| {
            if strands(s, &[op_push, op_ring], &[op_drain, op_clear]) {
                losing.push(s.to_vec());
            }
        });
        assert!(
            !losing.is_empty(),
            "the harness must find the drain-before-clear lost-wakeup"
        );

        // negative control #2: ring-before-publish is just as racy — the
        // pump can clear-and-drain between the ring and the publish
        let mut losing = Vec::new();
        for_each_interleaving(&lens, |s| {
            if strands(s, &[op_ring, op_push], &[op_clear, op_drain]) {
                losing.push(s.to_vec());
            }
        });
        assert!(
            !losing.is_empty(),
            "the harness must find the ring-before-publish lost-wakeup"
        );
    }

    /// PR-5 drop-order race, pinned: the in-proc endpoint's Drop must
    /// disconnect BEFORE ringing the doorbell.  Replaying every
    /// interleaving of {disconnect, ring} against one pump pass
    /// {clear, poll} (the pump's clear-then-recheck discipline), then
    /// letting the pump run follow-up passes for as long as the bell is
    /// readable: with disconnect-first the hangup is always observed; with
    /// ring-first there is a schedule where the bell is spent before the
    /// disconnect lands and the pump would block forever on a dead peer.
    #[test]
    fn inproc_drop_disconnects_before_ringing() {
        use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

        // replay: dropper ops (thread 0) × one pump pass (thread 1);
        // returns true when the peer hangup went UNOBSERVED with no bell
        // readiness left to trigger another pass — the hang.
        fn hangs(schedule: &[usize], disconnect_first: bool) -> bool {
            let (bell, ep, mut ready) = armed_bell();
            let (tx, rx): (Sender<u64>, Receiver<u64>) = channel();
            let mut tx = Some(tx);
            let mut observed_hangup = false;
            let pump_pass = |rx: &Receiver<u64>, observed: &mut bool| {
                bell.clear();
                match rx.try_recv() {
                    Err(TryRecvError::Disconnected) => *observed = true,
                    Err(TryRecvError::Empty) | Ok(_) => {}
                }
            };
            let mut next = [0usize; 2];
            for &t in schedule {
                if t == 0 {
                    let disconnect_now =
                        (next[0] == 0) == disconnect_first;
                    if disconnect_now {
                        tx = None; // drop the sender: the disconnect
                    } else {
                        bell.wake();
                    }
                } else {
                    // the pump pass is clear-then-recheck; splitting it
                    // into two scheduled ops is covered by the follow-up
                    // loop below, which reruns passes on a readable bell
                    pump_pass(&rx, &mut observed_hangup);
                }
                next[t] += 1;
            }
            drop(tx);
            // event-driven follow-up: the pump reruns a pass whenever the
            // bell is readable — a hang is an unobserved hangup with a
            // quiet bell
            while !observed_hangup && bell_ready(&ep, &mut ready) {
                pump_pass(&rx, &mut observed_hangup);
            }
            !observed_hangup
        }

        // dropper contributes 2 ops, the pump 1 scheduled pass
        let lens = [2, 1];
        for_each_interleaving(&lens, |s| {
            assert!(
                !hangs(s, true),
                "disconnect-before-ring must always be observed; hung at {s:?}"
            );
        });
        let mut hanging = Vec::new();
        for_each_interleaving(&lens, |s| {
            if hangs(s, false) {
                hanging.push(s.to_vec());
            }
        });
        assert!(
            !hanging.is_empty(),
            "the harness must find the ring-before-disconnect hang"
        );
    }
}

mod gate {
    use super::*;
    use c3sl::coordinator::ShardGate;
    use c3sl::hdc::keyring::KeyRing;

    /// ShardGate claim/release/burn transitions under every interleaving of
    /// two connections racing for the same shard id.  Each connection runs,
    /// in program order: hello (challenge), claim (valid proof), replay
    /// (the identical recorded claim frame again), release.  70 schedules;
    /// after every operation the harness checks:
    ///
    /// * single ownership — both connections are never live at once;
    /// * claim outcomes match the model — a claim succeeds exactly when
    ///   the shard is free at that moment, and fails as "already claimed"
    ///   otherwise;
    /// * burn-on-verify — the replayed frame NEVER re-admits: its
    ///   challenge was burned when the proof first verified, whatever the
    ///   claim outcome (the PR-5 slot-reuse replay regression);
    /// * owner-matched release — after both connections finish (each
    ///   released in program order, including losers releasing claims they
    ///   never held), the shard is claimable by a fresh connection: no
    ///   leaked claim, and no loser ever freed the winner's.
    #[test]
    fn gate_claim_release_burn_invariants_hold_under_all_interleavings() {
        let lens = [4, 4];
        assert_eq!(interleaving_count(&lens), 70);
        for_each_interleaving(&lens, |schedule| {
            let ring = KeyRing::new(0x1B7E_2F01, 2, 64, 0);
            let gate = ShardGate::new(ring, 1);
            let mut proof: [Option<u64>; 2] = [None, None];
            let mut live = [false, false];
            let mut next = [0usize; 2];
            for &slot in schedule {
                match next[slot] {
                    // hello: fresh challenge, record the proof that
                    // answers it (what a wire observer would capture)
                    0 => {
                        let n = gate.issue_nonce(slot).expect("challenge");
                        proof[slot] = Some(ring.shard_proof(0, 0, n));
                    }
                    // claim: must succeed iff the shard is free right now
                    1 => {
                        let free = !live[0] && !live[1];
                        let res = gate.admit(slot, 0, 0, proof[slot].expect("after hello"));
                        match res {
                            Ok(_) => {
                                assert!(
                                    free,
                                    "claim by {slot} succeeded on a held shard at {schedule:?}"
                                );
                                live[slot] = true;
                            }
                            Err(e) => {
                                assert!(
                                    !free,
                                    "claim by {slot} failed on a free shard at \
                                     {schedule:?}: {e}"
                                );
                                assert!(
                                    e.to_string().contains("already claimed"),
                                    "unexpected rejection at {schedule:?}: {e}"
                                );
                            }
                        }
                    }
                    // replay: the recorded frame must never verify again —
                    // its challenge was burned the moment the proof first
                    // verified, regardless of the claim outcome
                    2 => {
                        let e = gate
                            .admit(slot, 0, 0, proof[slot].expect("after hello"))
                            .expect_err("replayed proof must never re-admit");
                        assert!(
                            e.to_string().contains("no challenge issued"),
                            "replay must die on the burned challenge at \
                             {schedule:?}: {e}"
                        );
                    }
                    // release: frees only this slot's own claim
                    _ => {
                        gate.release(slot, 0);
                        live[slot] = false;
                    }
                }
                next[slot] += 1;
                assert!(
                    !(live[0] && live[1]),
                    "both connections live after an op at {schedule:?}"
                );
            }
            // every op done and both released: the shard must be claimable
            // by a fresh connection — nothing leaked, nothing stolen
            let n = gate.issue_nonce(5).expect("fresh challenge");
            gate.admit(5, 0, 0, ring.shard_proof(0, 0, n))
                .expect("shard must be claimable after both connections released");
        });
    }
}
