//! Bench: Table 2 + the params/FLOPs columns of Table 1 — exact analytical
//! regeneration of the paper's overhead numbers.
//!
//!   cargo bench --bench table2_formulas
//!
//! Expected output (paper Table 1):
//!   VGG-16  C3 params: 4.1k/8.2k/16.4k/32.8k, FLOPs 0.54e9
//!   ResNet  C3 params: 8.2k/…/65.5k, FLOPs 2.15e9
//!   memory ratios 576×/1152× (R=2, published), compute 2.24×/2.25×

use c3sl::flops::{
    bottlenetpp_cost, bottlenetpp_cost_published, c3sl_cost, CutSpec,
};

fn main() {
    println!("# Table 2 formulas evaluated at the paper's operating points\n");
    println!("BottleNet++: params = (C·k²+1)(4C/R) + ((4C/R)k²+1)C");
    println!("             flops  = B(2Ck²+1)(4C/R)H'W' + B((8C/R)k²+1)CHW");
    println!("C3-SL:       params = R·D          flops = 2·B·D²\n");

    for (label, spec, paper_bnpp_params, paper_bnpp_gflops) in [
        (
            "Table 1 (left): VGG-16 on CIFAR-10 — C=512 H=W=2 D=2048 B=64 k=2",
            CutSpec::vgg16_cifar10(),
            [2_360_000u64, 2_098_200, 1_049_300, 524_900],
            [1.21f64, 0.67, 0.34, 0.17],
        ),
        (
            "Table 1 (right): ResNet-50 on CIFAR-100 — C=1024 H=W=2 D=4096 B=64 k=2",
            CutSpec::resnet50_cifar100(),
            [9_438_700, 8_390_700, 4_195_800, 2_098_400],
            [4.83, 2.68, 1.34, 0.67],
        ),
    ] {
        println!("== {label}");
        println!(
            "{:>4} | {:>12} {:>12} {:>7} | {:>10} {:>10} | {:>12} {:>10} | {:>7} {:>7}",
            "R", "BN++ params", "paper", "Δ%", "BN++ GF", "paper", "C3 params", "C3 GF",
            "mem x", "flop x"
        );
        for (i, r) in [2usize, 4, 8, 16].iter().enumerate() {
            let bn = bottlenetpp_cost_published(&spec, *r);
            let c3 = c3sl_cost(&spec, *r);
            let delta = 100.0 * (bn.params as f64 - paper_bnpp_params[i] as f64)
                / paper_bnpp_params[i] as f64;
            println!(
                "{:>4} | {:>12} {:>12} {:>6.1}% | {:>10.3} {:>10.2} | {:>12} {:>10.3} | {:>6.0}x {:>6.2}x",
                r,
                bn.params,
                paper_bnpp_params[i],
                delta,
                bn.flops as f64 / 1e9,
                paper_bnpp_gflops[i],
                c3.params,
                c3.flops as f64 / 1e9,
                bn.params as f64 / c3.params as f64,
                bn.flops as f64 / c3.flops as f64,
            );
        }
        let f2 = bottlenetpp_cost(&spec, 2);
        println!(
            "   note: R=2 published row implies C'=9C/8; Table-2 formula as printed gives {} params\n",
            f2.params
        );
    }

    println!("# Headline claims (paper abstract):");
    let rn = CutSpec::resnet50_cifar100();
    let bn2 = bottlenetpp_cost_published(&rn, 2);
    let c32 = c3sl_cost(&rn, 2);
    println!(
        "  memory  reduction @R=2 CIFAR-100: {:.0}x   (paper: 1152x)",
        bn2.params as f64 / c32.params as f64
    );
    println!(
        "  compute reduction @R=2 CIFAR-100: {:.2}x  (paper: 2.25x, published FLOPs 4.83e9/2.15e9)",
        4.83e9 / c32.flops as f64
    );
    let vg = CutSpec::vgg16_cifar10();
    let bn2v = bottlenetpp_cost_published(&vg, 2);
    let c32v = c3sl_cost(&vg, 2);
    println!(
        "  memory  reduction @R=2 CIFAR-10:  {:.0}x    (paper: 576x)",
        bn2v.params as f64 / c32v.params as f64
    );
    println!(
        "  compute reduction @R=2 CIFAR-10:  {:.2}x   (paper: 2.24x, published FLOPs 1.21e9/0.54e9)",
        1.21e9 / c32v.flops as f64
    );
}
