//! CloudWorker: owns f_psi and the cloud half of the codec.  Message-driven:
//! decodes uplink features, runs the cloud forward/backward, compresses the
//! cut-layer gradients with the SAME encoder (legal because decode = encodeᵀ,
//! DESIGN.md §1) and ships them back with the step statistics.

use super::edge::build_codec;
use super::run_codec::RunCodec;
use crate::bail;
use crate::config::ExperimentConfig;
use crate::metrics::Histogram;
use crate::runtime::xla_stub as xla;
use crate::runtime::{AdamState, Engine, ModelRuntime};
use crate::tensor::Tensor;
use crate::transport::{Msg, Transport};
use crate::util::error::{Context, Result};
use crate::util::timer::Timer;

/// The cloud actor: f_psi, its optimizer state, and the cloud half of the
/// codec.
pub struct CloudWorker {
    model: ModelRuntime,
    codec: RunCodec,
    params: Vec<xla::Literal>,
    adam: AdamState,
    lr: f32,
    /// Step-latency histogram (cloud-side compute only).
    pub step_latency: Histogram,
}

impl CloudWorker {
    /// Build the cloud side: engine, artifacts, params, codec.
    pub fn new(engine: &Engine, cfg: &ExperimentConfig) -> Result<Self> {
        let model = ModelRuntime::load(engine, cfg.model_dir())
            .context("loading cloud model artifacts")?;
        let codec = build_codec(engine, cfg, "cloud")?;
        // Different init stream than the edge (cfg.seed+1), as both parts are
        // independently randomly initialized in SL.
        let params = model.cloud_init(cfg.seed.wrapping_add(1))?;
        let adam = AdamState::zeros_like(&params)?;
        Ok(CloudWorker {
            model,
            codec,
            params,
            adam,
            lr: cfg.lr,
            step_latency: Histogram::latency(),
        })
    }

    /// Serve until the edge sends Shutdown.
    pub fn run(&mut self, transport: &mut dyn Transport) -> Result<()> {
        let mut pending: Option<(u64, Tensor)> = None;
        loop {
            match transport.recv()? {
                Msg::KeySeed { seed: _seed } => {
                    // Keys were already derived from the config seed at
                    // construction; a mismatched seed is a protocol error.
                    // (Kept as a message so TCP deployments can hand-shake.)
                }
                Msg::Features { step, tensor } => {
                    if pending.is_some() {
                        bail!("cloud got Features while a step is pending");
                    }
                    pending = Some((step, tensor));
                }
                Msg::TrainLabels { step, labels } => {
                    let (fstep, s) = pending
                        .take()
                        .context("cloud got labels before features")?;
                    if fstep != step {
                        bail!("label step mismatch: {step} != {fstep}");
                    }
                    let t = Timer::start();
                    let zhat = self.codec.decode(&s)?;
                    let out = self.model.cloud_step(&self.params, &zhat, &labels)?;
                    // Compress the cut-layer gradients for the downlink.
                    let gs = self.codec.encode(&out.gz)?;
                    let params = std::mem::take(&mut self.params);
                    self.params =
                        self.model
                            .cloud_adam(params, &out.grads, &mut self.adam, self.lr)?;
                    self.step_latency.observe(t.elapsed_secs());
                    transport.send(&Msg::Gradients { step, tensor: gs })?;
                    transport.send(&Msg::StepStats {
                        step,
                        loss: out.loss,
                        ncorrect: out.ncorrect,
                    })?;
                }
                Msg::EvalFeatures { step, tensor, labels } => {
                    let zhat = self.codec.decode(&tensor)?;
                    let (loss, ncorrect) =
                        self.model.cloud_eval(&self.params, &zhat, &labels)?;
                    transport.send(&Msg::EvalStats { step, loss, ncorrect })?;
                }
                Msg::Shutdown => return Ok(()),
                other => bail!("cloud got unexpected message {other:?}"),
            }
        }
    }
}
