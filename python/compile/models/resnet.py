# ResNet-50 (He et al.) split for SL at the output of the 3rd residual stage,
# as the paper's §4.1.  The paper's Table 2 numbers imply D = 4096 for the cut
# tensor, i.e. the ImageNet-style stem (7×7/2 conv + 3×3/2 max-pool) applied
# to 32×32 CIFAR: 32→16→8 after the stem, stage2 →4, stage3 →2, so the cut is
# (1024, 2, 2) → D = 4096.  We reproduce exactly that topology.

import math
from typing import List, Tuple

import jax

from .. import nn

BLOCKS = [3, 4, 6, 3]          # ResNet-50 bottleneck counts per stage
EXPANSION = 4


def _scale(c: int, w: float) -> int:
    return max(8, int(round(c * w)))


def _bottleneck(c_in: int, c_mid: int, stride: int, norm: bool) -> nn.Layer:
    """Standard bottleneck: 1×1 reduce → 3×3 → 1×1 expand, + skip."""
    c_out = c_mid * EXPANSION

    main = nn.Sequential(
        [nn.Conv2d(c_in, c_mid, k=1)]
        + ([nn.GroupNorm(c_mid)] if norm else []) + [nn.ReLU(),
           nn.Conv2d(c_mid, c_mid, k=3, stride=stride)]
        + ([nn.GroupNorm(c_mid)] if norm else []) + [nn.ReLU(),
           nn.Conv2d(c_mid, c_out, k=1)]
        + ([nn.GroupNorm(c_out)] if norm else []),
        name="bottleneck_main")

    needs_proj = stride != 1 or c_in != c_out
    proj = (nn.Sequential(
        [nn.Conv2d(c_in, c_out, k=1, stride=stride)]
        + ([nn.GroupNorm(c_out)] if norm else []), name="proj")
        if needs_proj else nn.Identity())

    def init(rng, in_shape):
        r1, r2 = jax.random.split(rng)
        pm, out_shape = main.init(r1, in_shape)
        pp, out_shape_p = proj.init(r2, in_shape)
        assert out_shape == out_shape_p or not needs_proj, (out_shape, out_shape_p)
        return [pm, pp], out_shape

    def apply(params, x):
        y = main.apply(params[0], x)
        s = proj.apply(params[1], x)
        return jax.nn.relu(y + s)

    return nn.Layer(f"bottleneck/{c_in}->{c_out}/s{stride}", init, apply)


def _stage(c_in: int, c_mid: int, n_blocks: int, stride: int, norm: bool):
    layers = [_bottleneck(c_in, c_mid, stride, norm)]
    c = c_mid * EXPANSION
    for _ in range(n_blocks - 1):
        layers.append(_bottleneck(c, c_mid, 1, norm))
    return layers, c


def resnet50_split(num_classes: int = 100, width: float = 1.0,
                   image: int = 32, norm: bool = True,
                   split_after_stage: int = 3) -> Tuple[nn.Layer, nn.Layer, int]:
    """ResNet-50 split after stage `split_after_stage` (paper: 3).

    Returns (edge, cloud, cut_dim D).
    """
    c64 = _scale(64, width)
    stem = [nn.Conv2d(3, c64, k=7, stride=2)] \
        + ([nn.GroupNorm(c64)] if norm else []) + [nn.ReLU(), nn.MaxPool2d(2, 2)]

    stages: List[List[nn.Layer]] = []
    c_in = c64
    for si, nb in enumerate(BLOCKS):
        c_mid = _scale(64 * (2 ** si), width)
        layers, c_in = _stage(c_in, c_mid, nb, stride=1 if si == 0 else 2, norm=norm)
        stages.append(layers)

    edge_layers = stem + [l for s in stages[:split_after_stage] for l in s]
    cloud_stages = [l for s in stages[split_after_stage:] for l in s]

    # Spatial size at the cut: stem /4, then one /2 per stage after stage 1.
    hw = image // 4
    for si in range(1, split_after_stage):
        hw //= 2
    cut_c = _scale(64 * (2 ** (split_after_stage - 1)), width) * EXPANSION
    d = cut_c * hw * hw

    edge = nn.Sequential(edge_layers + [nn.Flatten()], name="resnet50_edge")
    unflat = nn.Lambda(
        "unflatten",
        lambda x: x.reshape(x.shape[0], cut_c, hw, hw),
        lambda s: (cut_c, hw, hw))
    head_c = _scale(512, width) * EXPANSION
    cloud = nn.Sequential(
        [unflat] + cloud_stages + [nn.GlobalAvgPool(), nn.Dense(head_c, num_classes)],
        name="resnet50_cloud")
    return edge, cloud, d
