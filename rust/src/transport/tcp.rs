//! TCP transport: length-prefixed frames over a socket, so the edge and the
//! cloud can run as separate OS processes (or separate machines).
//!
//! Frame on the socket: [len u32 LE][frame bytes] where the inner frame is
//! wire::encode's output.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::{LinkStats, Msg, Transport, TransportError};
use crate::transport::wire;

pub struct Tcp {
    stream: TcpStream,
    stats: Arc<LinkStats>,
}

impl Tcp {
    /// Listen on `addr` and accept one peer (cloud side).
    pub fn listen(addr: &str) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let (stream, _peer) = listener.accept()?;
        stream.set_nodelay(true)?;
        Ok(Tcp { stream, stats: Arc::new(LinkStats::default()) })
    }

    /// Connect to a listening peer (edge side), retrying briefly while the
    /// server comes up.
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let mut last_err = None;
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(Tcp { stream, stats: Arc::new(LinkStats::default()) });
                }
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
        Err(last_err.unwrap())
    }
}

impl Transport for Tcp {
    fn send(&mut self, msg: &Msg) -> Result<(), TransportError> {
        let frame = wire::encode(msg);
        let len = frame.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(&frame)?;
        self.stats
            .tx_bytes
            .fetch_add(4 + frame.len() as u64, Ordering::Relaxed);
        self.stats.tx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn recv(&mut self) -> Result<Msg, TransportError> {
        let mut lenb = [0u8; 4];
        self.stream.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        let mut frame = vec![0u8; len];
        self.stream.read_exact(&mut frame)?;
        self.stats
            .rx_bytes
            .fetch_add(4 + len as u64, Ordering::Relaxed);
        self.stats.rx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(wire::decode(&frame)?)
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn tcp_roundtrip_between_threads() {
        let addr = "127.0.0.1:39381";
        let server = std::thread::spawn(move || {
            let mut t = Tcp::listen(addr).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
            t.recv().unwrap() // shutdown
        });
        let mut c = Tcp::connect(addr).unwrap();
        let m = Msg::Features {
            step: 9,
            tensor: Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
        };
        c.send(&m).unwrap();
        assert_eq!(c.recv().unwrap(), m);
        c.send(&Msg::Shutdown).unwrap();
        assert_eq!(server.join().unwrap(), Msg::Shutdown);
        assert!(c.stats().tx() > 0 && c.stats().rx() > 0);
    }
}
