//! Integration tests for the multi-client coordinator: N concurrent edges
//! training end to end against one cloud over the in-proc (+SimLink) and TCP
//! transports, with per-client and aggregate byte accounting.  No AOT
//! artifacts needed (host codec venue).

use c3sl::config::TransportKind;
use c3sl::coordinator::{run_multi_edge, MultiEdgeSpec, MultiRunOutput};
use c3sl::transport::sim::LinkModel;

fn spec(edges: usize, transport: TransportKind, addr: &str) -> MultiEdgeSpec {
    MultiEdgeSpec {
        edges,
        steps: 6,
        r: 2,
        d: 256,
        batch: 8,
        seed: 5,
        workers: 2,
        transport,
        tcp_addr: addr.into(),
        link: None,
    }
}

fn check_accounting(out: &MultiRunOutput, edges: usize) {
    assert_eq!(out.cloud.per_client.len(), edges);
    assert_eq!(out.edges.len(), edges);
    for c in &out.cloud.per_client {
        assert_eq!(c.steps, 6, "client {} steps", c.client);
        assert!(c.rx_bytes > 0 && c.tx_bytes > 0);
        // per step: Features + TrainLabels up, Gradients + StepStats down,
        // plus the KeySeed handshake and Shutdown
        assert_eq!(c.rx_msgs, 6 * 2 + 2, "client {} rx msgs", c.client);
        assert_eq!(c.tx_msgs, 6 * 2, "client {} tx msgs", c.client);
    }
    // the aggregate must be exactly the sum of the per-client halves
    let edge_tx: u64 = out.edges.iter().map(|e| e.tx_bytes).sum();
    let edge_rx: u64 = out.edges.iter().map(|e| e.rx_bytes).sum();
    assert_eq!(out.cloud.total_rx(), edge_tx, "cloud rx == sum of edge uplinks");
    assert_eq!(out.cloud.total_tx(), edge_rx, "cloud tx == sum of edge downlinks");
    assert_eq!(out.cloud.total_steps(), 6 * edges as u64);
    // and training must make progress through the lossy codec on every edge
    for (i, e) in out.edges.iter().enumerate() {
        assert!(
            e.last_loss < e.first_loss,
            "edge {i}: probe loss did not decrease ({} -> {})",
            e.first_loss,
            e.last_loss
        );
        assert!(e.first_loss.is_finite() && e.last_loss.is_finite());
    }
}

#[test]
fn two_inproc_edges_train_concurrently() {
    let out = run_multi_edge(&spec(2, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 2);
    // identical edges (different seeds) see byte-identical frame sizes:
    // same geometry → same serialized bytes per client
    let tx0 = out.cloud.per_client[0].rx_bytes;
    for c in &out.cloud.per_client {
        assert_eq!(c.rx_bytes, tx0, "uniform geometry → uniform per-client bytes");
    }
}

#[test]
fn four_inproc_edges_with_link_model() {
    let mut s = spec(4, TransportKind::InProc, "");
    s.link = Some(LinkModel::wifi());
    let out = run_multi_edge(&s).unwrap();
    check_accounting(&out, 4);
}

#[test]
fn two_tcp_edges_train_concurrently() {
    let out = run_multi_edge(&spec(2, TransportKind::Tcp, "127.0.0.1:39413")).unwrap();
    check_accounting(&out, 2);
}

#[test]
fn three_tcp_edges_aggregate_accounting() {
    let out = run_multi_edge(&spec(3, TransportKind::Tcp, "127.0.0.1:39414")).unwrap();
    check_accounting(&out, 3);
}

#[test]
fn single_edge_multi_path_still_works() {
    // edges=1 must behave exactly like a 1-client pool
    let out = run_multi_edge(&spec(1, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 1);
}

#[test]
fn rejects_bad_geometry() {
    let mut s = spec(2, TransportKind::InProc, "");
    s.batch = 7; // not divisible by r=2
    assert!(run_multi_edge(&s).is_err());
    let mut s = spec(2, TransportKind::InProc, "");
    s.edges = 0;
    assert!(run_multi_edge(&s).is_err());
}

#[test]
fn compression_shows_on_the_wire() {
    // R=4 halves-of-halves the uplink feature bytes vs R=1-equivalent:
    // features are (B/R, D) instead of (B, D).
    let mut s4 = spec(2, TransportKind::InProc, "");
    s4.r = 4;
    s4.batch = 8;
    let out4 = run_multi_edge(&s4).unwrap();
    let mut s1 = spec(2, TransportKind::InProc, "");
    s1.r = 1;
    s1.batch = 8;
    let out1 = run_multi_edge(&s1).unwrap();
    let up4 = out4.cloud.total_rx() as f64;
    let up1 = out1.cloud.total_rx() as f64;
    assert!(
        up1 / up4 > 3.0,
        "R=4 should cut uplink ~4x: {up1} vs {up4}"
    );
}
