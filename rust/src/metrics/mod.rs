//! Metrics substrate: counters, gauges, EWMA, histograms, and a run recorder
//! that writes loss curves / throughput as CSV for EXPERIMENTS.md.

pub mod prom;

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::csv::CsvWriter;

/// Monotonic counter.
#[derive(Clone, Debug, Default)]
pub struct Counter(pub u64);

impl Counter {
    /// Add one.
    pub fn inc(&mut self) {
        self.0 += 1;
    }

    /// Add `n`.
    pub fn add(&mut self, n: u64) {
        self.0 += n;
    }
}

/// Exponentially weighted moving average (for smoothed loss display).
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// Smoother with weight `alpha` ∈ [0, 1] on the newest sample (1 =
    /// no smoothing, 0 = frozen at the first sample).
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    /// Fold in a sample and return the updated average (the first sample
    /// seeds the average directly).
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    /// Current average, or `None` before the first sample.
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Fixed-bucket histogram (log-ish bounds supplied by the caller).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    /// Total number of observations.
    pub total: u64,
    /// Sum of all observed values (for [`Histogram::mean`]).
    pub sum: f64,
    /// Smallest observation so far (+∞ before the first).
    pub min: f64,
    /// Largest observation so far (−∞ before the first).
    pub max: f64,
}

impl Histogram {
    /// Histogram with bucket upper bounds `bounds` (ascending) plus an
    /// implicit overflow bucket above the last bound.
    pub fn new(bounds: Vec<f64>) -> Self {
        let n = bounds.len() + 1;
        Histogram {
            bounds,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Latency-style default buckets (µs → s).
    pub fn latency() -> Self {
        Self::new(vec![1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0])
    }

    /// Record one observation into its bucket and the min/max/sum stats.
    pub fn observe(&mut self, x: f64) {
        let idx = self.bounds.iter().position(|b| x <= *b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Arithmetic mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    ///
    /// `q` is clamped to `[0, 1]` (NaN reads as 0): the extremes return the
    /// exact observed `min`/`max` rather than a bucket bound — in particular
    /// `quantile(0.0)` must not return `bounds[0]` just because a `target`
    /// of zero is satisfied by the first (possibly empty) bucket.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        if q <= 0.0 {
            return self.min;
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }

    /// The bucket upper bounds this histogram was built with (ascending).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket observation counts; one longer than [`Histogram::bounds`]
    /// (the final entry is the overflow bucket above the last bound).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
}

/// A step record in a training run.
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// Global training step index.
    pub step: usize,
    /// Training loss at this step.
    pub loss: f64,
    /// Training accuracy at this step (fraction in [0, 1]).
    pub acc: f64,
    /// Bytes the edge sent up (activations / compressed carriers).
    pub uplink_bytes: u64,
    /// Bytes the cloud sent down (gradients / compressed carriers).
    pub downlink_bytes: u64,
    /// Wall-clock duration of the step, in seconds.
    pub step_seconds: f64,
}

/// Collects per-step records and writes them out as CSV.
#[derive(Debug, Default)]
pub struct RunRecorder {
    /// Every recorded training step, in order.
    pub records: Vec<StepRecord>,
    /// Eval checkpoints as `(step, eval_loss, eval_acc)` tuples.
    pub evals: Vec<(usize, f64, f64)>,
    /// Free-form named scalars (hyperparameters, derived summaries).
    pub scalars: BTreeMap<String, f64>,
}

impl RunRecorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one training-step record.
    pub fn record(&mut self, rec: StepRecord) {
        self.records.push(rec);
    }

    /// Append one eval checkpoint.
    pub fn record_eval(&mut self, step: usize, loss: f64, acc: f64) {
        self.evals.push((step, loss, acc));
    }

    /// Set (or overwrite) a named scalar.
    pub fn set_scalar(&mut self, key: &str, v: f64) {
        self.scalars.insert(key.to_string(), v);
    }

    /// Total uplink bytes across all recorded steps.
    pub fn total_uplink(&self) -> u64 {
        self.records.iter().map(|r| r.uplink_bytes).sum()
    }

    /// Total downlink bytes across all recorded steps.
    pub fn total_downlink(&self) -> u64 {
        self.records.iter().map(|r| r.downlink_bytes).sum()
    }

    /// Mean wall-clock seconds per recorded step (0 when empty).
    pub fn mean_step_seconds(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.step_seconds).sum::<f64>() / self.records.len() as f64
    }

    /// Loss of the last recorded step, if any.
    pub fn final_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Write the step records to `path` as CSV (one row per step, header
    /// included) — the format EXPERIMENTS.md plots are generated from.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["step", "loss", "acc", "uplink_bytes", "downlink_bytes", "step_seconds"],
        )?;
        for r in &self.records {
            w.row(&[
                r.step.to_string(),
                format!("{:.6}", r.loss),
                format!("{:.4}", r.acc),
                r.uplink_bytes.to_string(),
                r.downlink_bytes.to_string(),
                format!("{:.6}", r.step_seconds),
            ])?;
        }
        w.flush()
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "steps={} final_loss={:.4} up={}B down={}B mean_step={:.3}s",
            self.records.len(),
            self.final_loss().unwrap_or(f64::NAN),
            self.total_uplink(),
            self.total_downlink(),
            self.mean_step_seconds(),
        );
        if let Some((step, loss, acc)) = self.evals.last() {
            let _ = write!(s, " eval@{step}: loss={loss:.4} acc={:.2}%", acc * 100.0);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_ewma() {
        let mut c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.0, 5);
        let mut e = Ewma::new(0.5);
        assert_eq!(e.update(2.0), 2.0);
        assert_eq!(e.update(4.0), 3.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        for x in [0.5, 1.5, 1.7, 3.0, 8.0] {
            h.observe(x);
        }
        assert_eq!(h.total, 5);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert_eq!(h.min, 0.5);
        assert_eq!(h.max, 8.0);
    }

    #[test]
    fn quantile_extremes_return_observed_min_max() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        for x in [0.5, 1.5, 1.7, 3.0, 8.0] {
            h.observe(x);
        }
        // the regression: q=0 used to compute target=0, which the first
        // (possibly empty) bucket trivially satisfies, returning bounds[0]
        assert_eq!(h.quantile(0.0), 0.5);
        assert_eq!(h.quantile(1.0), 8.0);
        // out-of-range q clamps to the extremes; NaN reads as 0
        assert_eq!(h.quantile(-3.0), 0.5);
        assert_eq!(h.quantile(7.0), 8.0);
        assert_eq!(h.quantile(f64::NAN), 0.5);
        // interior quantiles still report bucket bounds and stay monotone
        assert_eq!(h.quantile(0.2), 1.0);
        assert!(h.quantile(0.2) <= h.quantile(0.6));
    }

    #[test]
    fn quantile_zero_with_empty_first_bucket() {
        // nothing lands in the first bucket: q=0 must still be the true min,
        // not the first bound whose cumulative count (0) matched target 0
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        h.observe(1.5);
        h.observe(3.0);
        assert_eq!(h.quantile(0.0), 1.5);
        assert_eq!(h.quantile(1.0), 3.0);
    }

    #[test]
    fn quantile_of_empty_histogram_is_zero() {
        let h = Histogram::new(vec![1.0]);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
    }

    #[test]
    fn recorder_accumulates_and_writes() {
        let mut r = RunRecorder::new();
        for step in 0..3 {
            r.record(StepRecord {
                step,
                loss: 2.0 - step as f64 * 0.1,
                acc: 0.1 * step as f64,
                uplink_bytes: 100,
                downlink_bytes: 50,
                step_seconds: 0.01,
            });
        }
        r.record_eval(2, 1.5, 0.3);
        assert_eq!(r.total_uplink(), 300);
        assert_eq!(r.total_downlink(), 150);
        assert!(r.summary().contains("steps=3"));
        let path = std::env::temp_dir().join("c3sl_run_test.csv");
        r.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4);
        std::fs::remove_file(&path).ok();
    }
}
