//! ModelRuntime: typed wrappers over one model artifact set.
//!
//! Holds the compiled executables and exposes the split-learning step
//! functions with rust signatures.  Parameter/optimizer state lives in
//! `Vec<xla::Literal>` ordered exactly as the manifest's leaf lists.
use std::path::PathBuf;

use crate::ensure;
use crate::runtime::xla_stub as xla;
use crate::util::error::{Context, Result};

use super::convert::{
    labels_to_literal, literal_scalar, literal_to_tensor, scalar_literal, seed_literal,
    tensor_to_literal,
};
use super::engine::{Engine, Executable};
use super::manifest::ModelManifest;
use crate::tensor::{Labels, Tensor};

/// Adam moment state for one parameter list.
pub struct AdamState {
    /// First-moment estimates, one literal per parameter leaf.
    pub m: Vec<xla::Literal>,
    /// Second-moment estimates, one literal per parameter leaf.
    pub v: Vec<xla::Literal>,
    /// Update count (drives the bias-correction schedule).
    pub step: usize,
}

impl AdamState {
    /// Zero-initialized moments matching `params`.
    pub fn zeros_like(params: &[xla::Literal]) -> Result<Self> {
        let zero = |p: &xla::Literal| -> Result<xla::Literal> {
            let t = literal_to_tensor(p)?;
            tensor_to_literal(&Tensor::zeros(t.shape()))
        };
        Ok(AdamState {
            m: params.iter().map(zero).collect::<Result<Vec<_>>>()?,
            v: params.iter().map(zero).collect::<Result<Vec<_>>>()?,
            step: 0,
        })
    }
}

/// Output of one cloud training step.
pub struct StepOutput {
    /// Mean cross-entropy loss over the batch.
    pub loss: f32,
    /// Number of correct top-1 predictions in the batch.
    pub ncorrect: f32,
    /// Cloud-side parameter gradients, in leaf order.
    pub grads: Vec<xla::Literal>,
    /// dL/dẑ — gradient w.r.t. the (decoded) transmitted features.
    pub gz: Tensor,
}

/// Compiled artifact set for one model (edge side + cloud side).
pub struct ModelRuntime {
    /// The artifact set's manifest (geometry, parameter leaves, file map).
    pub manifest: ModelManifest,
    dir: PathBuf,
    edge_init: std::sync::Arc<Executable>,
    cloud_init: std::sync::Arc<Executable>,
    edge_fwd: std::sync::Arc<Executable>,
    edge_bwd: std::sync::Arc<Executable>,
    cloud_step: std::sync::Arc<Executable>,
    cloud_eval: std::sync::Arc<Executable>,
    edge_adam: std::sync::Arc<Executable>,
    cloud_adam: std::sync::Arc<Executable>,
}

impl ModelRuntime {
    /// Load and compile every artifact in `dir` (model_key directory).
    pub fn load(engine: &Engine, dir: impl Into<PathBuf>) -> Result<Self> {
        let dir: PathBuf = dir.into();
        let manifest = ModelManifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let load = |name: &str| -> Result<std::sync::Arc<Executable>> {
            let file = &manifest.artifact(name)?.file;
            engine.load(dir.join(file))
        };
        Ok(ModelRuntime {
            edge_init: load("edge_init")?,
            cloud_init: load("cloud_init")?,
            edge_fwd: load("edge_fwd")?,
            edge_bwd: load("edge_bwd")?,
            cloud_step: load("cloud_step")?,
            cloud_eval: load("cloud_eval")?,
            edge_adam: load("edge_adam")?,
            cloud_adam: load("cloud_adam")?,
            manifest,
            dir,
        })
    }

    /// The artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    // ---- initialization ----------------------------------------------------

    /// Fresh edge-side parameter leaves, seeded deterministically.
    pub fn edge_init(&self, seed: u64) -> Result<Vec<xla::Literal>> {
        let s = seed_literal(seed)?;
        self.edge_init.run(&[&s])
    }

    /// Fresh cloud-side parameter leaves, seeded deterministically.
    pub fn cloud_init(&self, seed: u64) -> Result<Vec<xla::Literal>> {
        let s = seed_literal(seed)?;
        self.cloud_init.run(&[&s])
    }

    // ---- edge side -----------------------------------------------------------

    /// z = f_theta(x): (B,3,H,W) → (B, d_tx).
    pub fn edge_fwd(&self, params: &[xla::Literal], x: &Tensor) -> Result<Tensor> {
        ensure!(
            params.len() == self.manifest.edge_params.len(),
            "edge param arity"
        );
        let xl = tensor_to_literal(x)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&xl);
        let outs = self.edge_fwd.run(&args)?;
        literal_to_tensor(&outs[0])
    }

    /// dL/dθ_edge given x and the (decoded) gradient gz at the cut.
    pub fn edge_bwd(
        &self,
        params: &[xla::Literal],
        x: &Tensor,
        gz: &Tensor,
    ) -> Result<Vec<xla::Literal>> {
        let xl = tensor_to_literal(x)?;
        let gl = tensor_to_literal(gz)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&xl);
        args.push(&gl);
        self.edge_bwd.run(&args)
    }

    // ---- cloud side -----------------------------------------------------------

    /// Forward + backward through f_psi; returns loss/acc/grads/gẑ.
    pub fn cloud_step(
        &self,
        params: &[xla::Literal],
        zhat: &Tensor,
        y: &Labels,
    ) -> Result<StepOutput> {
        ensure!(
            params.len() == self.manifest.cloud_params.len(),
            "cloud param arity"
        );
        let zl = tensor_to_literal(zhat)?;
        let yl = labels_to_literal(y)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&zl);
        args.push(&yl);
        let mut outs = self.cloud_step.run(&args)?;
        // outputs: loss, ncorrect, grads..., gz
        ensure!(outs.len() == 2 + params.len() + 1, "cloud_step arity");
        let gz = literal_to_tensor(&outs.pop().unwrap())?;
        let grads = outs.split_off(2);
        let ncorrect = literal_scalar(&outs[1])?;
        let loss = literal_scalar(&outs[0])?;
        Ok(StepOutput { loss, ncorrect, grads, gz })
    }

    /// Evaluation-only pass: (loss, ncorrect).
    pub fn cloud_eval(
        &self,
        params: &[xla::Literal],
        zhat: &Tensor,
        y: &Labels,
    ) -> Result<(f32, f32)> {
        let zl = tensor_to_literal(zhat)?;
        let yl = labels_to_literal(y)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&zl);
        args.push(&yl);
        let outs = self.cloud_eval.run(&args)?;
        Ok((literal_scalar(&outs[0])?, literal_scalar(&outs[1])?))
    }

    // ---- optimizer ---------------------------------------------------------------

    fn adam(
        exe: &Executable,
        params: Vec<xla::Literal>,
        grads: &[xla::Literal],
        state: &mut AdamState,
        lr: f32,
    ) -> Result<Vec<xla::Literal>> {
        let n = params.len();
        ensure!(grads.len() == n && state.m.len() == n && state.v.len() == n);
        let step_l = scalar_literal(state.step as f32)?;
        let lr_l = scalar_literal(lr)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(4 * n + 2);
        args.extend(params.iter());
        args.extend(grads.iter());
        args.extend(state.m.iter());
        args.extend(state.v.iter());
        args.push(&step_l);
        args.push(&lr_l);
        let mut outs = exe.run(&args)?;
        ensure!(outs.len() == 3 * n, "adam output arity");
        let v = outs.split_off(2 * n);
        let m = outs.split_off(n);
        state.m = m;
        state.v = v;
        state.step += 1;
        Ok(outs)
    }

    /// In-place Adam update of the edge parameters.
    pub fn edge_adam(
        &self,
        params: Vec<xla::Literal>,
        grads: &[xla::Literal],
        state: &mut AdamState,
        lr: f32,
    ) -> Result<Vec<xla::Literal>> {
        Self::adam(&self.edge_adam, params, grads, state, lr)
    }

    /// In-place Adam update of the cloud parameters.
    pub fn cloud_adam(
        &self,
        params: Vec<xla::Literal>,
        grads: &[xla::Literal],
        state: &mut AdamState,
        lr: f32,
    ) -> Result<Vec<xla::Literal>> {
        Self::adam(&self.cloud_adam, params, grads, state, lr)
    }
}
