//! FFT substrate (from scratch): iterative radix-2 Cooley–Tukey over
//! interleaved complex buffers, plus real-input convolution helpers used by
//! the rust-native C3 codec hot path.
//!
//! Only power-of-two lengths go through the FFT; the `hdc` module falls back
//! to the direct O(D²) path otherwise (real workloads here have D = 2^k).
// Doc debt, explicitly tracked: this module predates the missing_docs
// push (ROADMAP "docs completion").  The CI doc job denies warnings, so
// remove this allow as part of documenting every public item here.
#![allow(missing_docs)]

use std::f64::consts::PI;

/// Complex number as (re, im) over f64 for accumulation accuracy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    #[inline]
    pub fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline]
    pub fn conj(self) -> C64 {
        C64::new(self.re, -self.im)
    }

    #[inline]
    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

/// Twiddle-factor table for a given power-of-two length, reused across calls.
///
/// Two kernels share the tables:
/// * [`forward`](FftPlan::forward) / [`inverse`](FftPlan::inverse) — the seed
///   reference transform, kept verbatim as the numerics oracle and the
///   allocating-path baseline in `benches/codec_hotpath.rs`.
/// * [`forward_into`](FftPlan::forward_into) /
///   [`inverse_into`](FftPlan::inverse_into) — the scratch kernel: same
///   butterfly schedule and twiddle values (so it is **bit-identical** to the
///   reference), but with a precomputed bit-reversal table, a separate
///   exact-conjugate inverse twiddle table (no per-butterfly branch), and
///   iterator-driven inner loops (no bounds checks).
#[derive(Clone, Debug)]
pub struct FftPlan {
    pub n: usize,
    /// twiddles[k] = exp(-2πi k / n) for k < n/2
    twiddles: Vec<C64>,
    /// conj(twiddles) — exact sign flips, so the scratch kernel's inverse
    /// matches the reference's per-butterfly `w.conj()` bit for bit.
    itwiddles: Vec<C64>,
    /// Precomputed bit-reversal permutation for the scratch kernel.
    bitrev: Vec<u32>,
}

impl FftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "FftPlan requires power-of-two n, got {n}");
        let twiddles: Vec<C64> = (0..n / 2)
            .map(|k| {
                let ang = -2.0 * PI * k as f64 / n as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        let itwiddles = twiddles.iter().map(|w| w.conj()).collect();
        let bits = n.trailing_zeros();
        let bitrev = (0..n)
            .map(|i| {
                if bits == 0 {
                    0
                } else {
                    (i.reverse_bits() >> (usize::BITS - bits)) as u32
                }
            })
            .collect();
        FftPlan { n, twiddles, itwiddles, bitrev }
    }

    /// In-place forward FFT (decimation in time, bit-reversal permutation).
    pub fn forward(&self, buf: &mut [C64]) {
        self.transform(buf, false);
    }

    /// In-place inverse FFT (includes the 1/n normalization).
    pub fn inverse(&self, buf: &mut [C64]) {
        self.transform(buf, true);
        let inv = 1.0 / self.n as f64;
        for c in buf.iter_mut() {
            c.re *= inv;
            c.im *= inv;
        }
    }

    fn transform(&self, buf: &mut [C64], inverse: bool) {
        let n = self.n;
        assert_eq!(buf.len(), n);
        // bit reversal
        let bits = n.trailing_zeros();
        for i in 0..n {
            let j = i.reverse_bits() >> (usize::BITS - bits);
            if j > i {
                buf.swap(i, j);
            }
        }
        // butterflies
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            for start in (0..n).step_by(len) {
                for k in 0..half {
                    let mut w = self.twiddles[k * step];
                    if inverse {
                        w = w.conj();
                    }
                    let a = buf[start + k];
                    let b = buf[start + k + half].mul(w);
                    buf[start + k] = a.add(b);
                    buf[start + k + half] = a.sub(b);
                }
            }
            len <<= 1;
        }
    }

    /// In-place forward FFT through the scratch kernel.  Bit-identical to
    /// [`FftPlan::forward`]; no allocation, no per-butterfly branches, no
    /// bounds checks in the butterfly loop.
    pub fn forward_into(&self, buf: &mut [C64]) {
        self.transform_into(buf, &self.twiddles);
    }

    /// In-place inverse FFT (with the 1/n normalization) through the scratch
    /// kernel.  Bit-identical to [`FftPlan::inverse`].
    pub fn inverse_into(&self, buf: &mut [C64]) {
        self.transform_into(buf, &self.itwiddles);
        let inv = 1.0 / self.n as f64;
        for c in buf.iter_mut() {
            c.re *= inv;
            c.im *= inv;
        }
    }

    fn transform_into(&self, buf: &mut [C64], twiddles: &[C64]) {
        let n = self.n;
        assert_eq!(buf.len(), n);
        for (i, &j) in self.bitrev.iter().enumerate() {
            let j = j as usize;
            if j > i {
                buf.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            for chunk in buf.chunks_exact_mut(len) {
                let (lo, hi) = chunk.split_at_mut(half);
                for ((a, b), &w) in
                    lo.iter_mut().zip(hi.iter_mut()).zip(twiddles.iter().step_by(step))
                {
                    let t = b.mul(w);
                    let u = *a;
                    *a = u.add(t);
                    *b = u.sub(t);
                }
            }
            len <<= 1;
        }
    }
}

/// Forward FFT of a real f32 signal → full complex spectrum.
pub fn rfft(plan: &FftPlan, x: &[f32]) -> Vec<C64> {
    assert_eq!(x.len(), plan.n);
    let mut buf: Vec<C64> = x.iter().map(|&v| C64::new(v as f64, 0.0)).collect();
    plan.forward(&mut buf);
    buf
}

/// Inverse FFT → real part as f32 (imaginary parts must be ~0 for our uses).
pub fn irfft(plan: &FftPlan, mut spec: Vec<C64>) -> Vec<f32> {
    plan.inverse(&mut spec);
    spec.iter().map(|c| c.re as f32).collect()
}

/// Forward FFT of a real signal into caller-owned scratch — the
/// zero-allocation twin of [`rfft`] (bit-identical output).
pub fn rfft_into(plan: &FftPlan, x: &[f32], out: &mut [C64]) {
    assert_eq!(x.len(), plan.n);
    assert_eq!(out.len(), plan.n);
    for (o, &v) in out.iter_mut().zip(x) {
        *o = C64::new(v as f64, 0.0);
    }
    plan.forward_into(out);
}

/// Inverse FFT of `spec` (consumed in place) writing the real part into
/// `out` — the zero-allocation twin of [`irfft`] (bit-identical output).
pub fn irfft_into(plan: &FftPlan, spec: &mut [C64], out: &mut [f32]) {
    assert_eq!(spec.len(), plan.n);
    assert_eq!(out.len(), plan.n);
    plan.inverse_into(spec);
    for (o, c) in out.iter_mut().zip(spec.iter()) {
        *o = c.re as f32;
    }
}

/// Circular convolution via the convolution theorem (power-of-two n).
pub fn circular_convolve_fft(plan: &FftPlan, a: &[f32], b: &[f32]) -> Vec<f32> {
    let fa = rfft(plan, a);
    let fb = rfft(plan, b);
    let prod: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| x.mul(*y)).collect();
    irfft(plan, prod)
}

/// Circular correlation via conj(F(a))·F(b) (power-of-two n).
pub fn circular_correlate_fft(plan: &FftPlan, a: &[f32], b: &[f32]) -> Vec<f32> {
    let fa = rfft(plan, a);
    let fb = rfft(plan, b);
    let prod: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| x.conj().mul(*y)).collect();
    irfft(plan, prod)
}

/// Naive O(n²) DFT — test oracle for the FFT itself.
#[allow(dead_code)]
pub fn dft_naive(x: &[C64], inverse: bool) -> Vec<C64> {
    let n = x.len();
    let sign = if inverse { 2.0 } else { -2.0 };
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let mut acc = C64::new(0.0, 0.0);
        for (j, &v) in x.iter().enumerate() {
            let ang = sign * PI * (k * j) as f64 / n as f64;
            acc = acc.add(v.mul(C64::new(ang.cos(), ang.sin())));
        }
        if inverse {
            acc.re /= n as f64;
            acc.im /= n as f64;
        }
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;
    use crate::util::rng::Rng;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn fft_matches_naive_dft() {
        let mut rng = Rng::new(1);
        for &n in &[2usize, 4, 8, 64, 256] {
            let plan = FftPlan::new(n);
            let x: Vec<C64> = (0..n)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect();
            let mut got = x.clone();
            plan.forward(&mut got);
            let want = dft_naive(&x, false);
            for (g, w) in got.iter().zip(&want) {
                assert!(close(g.re, w.re, 1e-9) && close(g.im, w.im, 1e-9));
            }
        }
    }

    #[test]
    fn inverse_roundtrips() {
        Prop::new("ifft(fft(x)) == x", 30).run(|g| {
            let n = g.pow2_in(1, 10);
            let plan = FftPlan::new(n);
            let x = g.vec_normal(n, 0.0, 1.0);
            let spec = rfft(&plan, &x);
            let back = irfft(&plan, spec);
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn convolution_theorem_matches_direct() {
        Prop::new("fft conv == direct conv", 20).run(|g| {
            let n = g.pow2_in(2, 8);
            let plan = FftPlan::new(n);
            let a = g.vec_normal(n, 0.0, 1.0);
            let b = g.vec_normal(n, 0.0, 1.0);
            let got = circular_convolve_fft(&plan, &a, &b);
            // direct: out[k] = Σ_m a[m] b[(k−m) mod n]
            for k in 0..n {
                let want: f32 = (0..n)
                    .map(|m| a[m] * b[(k + n - m) % n])
                    .sum();
                assert!((got[k] - want).abs() < 1e-3, "n={n} k={k}: {} vs {want}", got[k]);
            }
        });
    }

    #[test]
    fn correlation_matches_direct() {
        Prop::new("fft corr == direct corr", 20).run(|g| {
            let n = g.pow2_in(2, 8);
            let plan = FftPlan::new(n);
            let a = g.vec_normal(n, 0.0, 1.0);
            let b = g.vec_normal(n, 0.0, 1.0);
            let got = circular_correlate_fft(&plan, &a, &b);
            // direct: out[k] = Σ_m a[m] b[(k+m) mod n]
            for k in 0..n {
                let want: f32 = (0..n).map(|m| a[m] * b[(k + m) % n]).sum();
                assert!((got[k] - want).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn delta_convolution_is_identity() {
        let n = 16;
        let plan = FftPlan::new(n);
        let mut delta = vec![0.0f32; n];
        delta[0] = 1.0;
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let y = circular_convolve_fft(&plan, &delta, &x);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        FftPlan::new(12);
    }

    #[test]
    fn scratch_kernel_bit_identical_to_reference() {
        // The whole point of the scratch kernel: same floats, fewer cycles.
        Prop::new("forward_into == forward (bits)", 20).run(|g| {
            let n = g.pow2_in(1, 11);
            let plan = FftPlan::new(n);
            let x: Vec<C64> = g
                .vec_normal(2 * n, 0.0, 1.0)
                .chunks_exact(2)
                .map(|p| C64::new(p[0] as f64, p[1] as f64))
                .collect();
            let mut a = x.clone();
            let mut b = x.clone();
            plan.forward(&mut a);
            plan.forward_into(&mut b);
            for (u, v) in a.iter().zip(&b) {
                assert_eq!(u.re.to_bits(), v.re.to_bits());
                assert_eq!(u.im.to_bits(), v.im.to_bits());
            }
            let mut a = x.clone();
            let mut b = x;
            plan.inverse(&mut a);
            plan.inverse_into(&mut b);
            for (u, v) in a.iter().zip(&b) {
                assert_eq!(u.re.to_bits(), v.re.to_bits());
                assert_eq!(u.im.to_bits(), v.im.to_bits());
            }
        });
    }

    #[test]
    fn rfft_into_matches_rfft_bitwise() {
        Prop::new("rfft_into == rfft (bits)", 20).run(|g| {
            let n = g.pow2_in(1, 10);
            let plan = FftPlan::new(n);
            let x = g.vec_normal(n, 0.0, 1.0);
            let want = rfft(&plan, &x);
            let mut spec = vec![C64::new(0.0, 0.0); n];
            rfft_into(&plan, &x, &mut spec);
            for (u, v) in want.iter().zip(&spec) {
                assert_eq!(u.re.to_bits(), v.re.to_bits());
                assert_eq!(u.im.to_bits(), v.im.to_bits());
            }
            let back_want = irfft(&plan, want);
            let mut back = vec![0.0f32; n];
            irfft_into(&plan, &mut spec, &mut back);
            for (u, v) in back_want.iter().zip(&back) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        });
    }

    #[test]
    fn scratch_buffers_are_reusable() {
        // Steady state: the same scratch buffer across many transforms must
        // not leak state between calls.
        let n = 64;
        let plan = FftPlan::new(n);
        let mut rng = Rng::new(17);
        let mut spec = vec![C64::new(0.0, 0.0); n];
        let mut out = vec![0.0f32; n];
        for _ in 0..5 {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            rfft_into(&plan, &x, &mut spec);
            irfft_into(&plan, &mut spec, &mut out);
            for (a, b) in x.iter().zip(&out) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let n = 128;
        let plan = FftPlan::new(n);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let spec = rfft(&plan, &x);
        let time_e: f64 = x.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        let freq_e: f64 =
            spec.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / n as f64;
        assert!(close(time_e, freq_e, 1e-9));
    }
}
