//! Chaos scenario driver: scripted multi-edge fleets over real TCP with
//! per-edge fault injection ([`crate::transport::faulty`]), runnable through
//! BOTH serving styles (thread-per-client and reactor) and both readiness
//! backends.  Everything is deterministic from one fleet seed: per-edge link
//! seeds and data seeds are derived by a splitmix64 stream, so a failing
//! scenario replays bit-for-bit from the seed printed on failure
//! (`C3SL_CHAOS_SEED=<seed>` reruns any [`ChaosCtx`]-driven test with it).
//!
//! The driver owns only the *mechanics* — bind, accept, wrap, serve, join,
//! final gate accounting.  What a scenario asserts stays in the test, via
//! [`ChaosCtx::check`]-style assertions that embed the seed in every failure
//! message.

use std::time::Duration;

use crate::coordinator::multi::{
    self, CloudCodec, EdgeCodec, EdgeReport, MultiStats, ShardGate,
};
use crate::hdc::keyring::KeyRing;
use crate::hdc::FftBackend;
use crate::transport::faulty::{FaultEvent, FaultyLink, Impairments};
use crate::transport::reactor::{NbTcp, ReactorConfig, ReactorConn};
use crate::transport::readiness::ReadinessBackend;
use crate::transport::tcp::Tcp;

/// Environment variable that pins every [`ChaosCtx`] seed for a rerun.
pub const SEED_ENV: &str = "C3SL_CHAOS_SEED";

/// splitmix64 — the standard 64-bit seed scrambler.  Pure, so every derived
/// seed is a function of (fleet seed, stream tag, index) and nothing else.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive a deterministic sub-seed for stream `tag`, element `i`.
pub fn sub_seed(seed: u64, tag: u64, i: u64) -> u64 {
    splitmix64(seed ^ splitmix64(tag) ^ splitmix64(i.wrapping_mul(0xA5A5_A5A5)))
}

fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Per-test chaos context: owns the scenario seed (default, or overridden by
/// `C3SL_CHAOS_SEED` for a replay) and stamps it into every assertion
/// failure, so a red chaos test is reproducible from its output alone.
#[derive(Clone, Copy, Debug)]
pub struct ChaosCtx {
    name: &'static str,
    seed: u64,
}

impl ChaosCtx {
    /// Create a context for scenario `name` with `default_seed`, announcing
    /// the effective seed (env override included) on stderr up front.
    pub fn new(name: &'static str, default_seed: u64) -> Self {
        let seed = std::env::var(SEED_ENV)
            .ok()
            .as_deref()
            .and_then(parse_seed)
            .unwrap_or(default_seed);
        eprintln!("chaos[{name}]: seed = {seed:#018x} (rerun: {SEED_ENV}={seed})");
        ChaosCtx { name, seed }
    }

    /// The effective scenario seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Assert `cond`, failing with the scenario name, `what`, and the seed.
    #[track_caller]
    pub fn check(&self, cond: bool, what: &str) {
        if !cond {
            self.fail(what);
        }
    }

    /// Assert `a == b`, failing with both values, `what`, and the seed.
    #[track_caller]
    pub fn check_eq<T: std::fmt::Debug + PartialEq>(&self, a: &T, b: &T, what: &str) {
        if a != b {
            self.fail(&format!("{what}: {a:?} != {b:?}"));
        }
    }

    /// Unconditional failure carrying the replay seed.
    #[track_caller]
    pub fn fail(&self, what: &str) -> ! {
        panic!(
            "chaos[{}] FAILED: {what} (seed = {:#018x}; rerun with {SEED_ENV}={})",
            self.name, self.seed, self.seed
        );
    }
}

/// One edge of a scripted fleet: its uplink and downlink impairment
/// matrices (from the edge wrapper's perspective — `tx` shapes what the
/// edge sends toward the cloud).
#[derive(Clone, Copy, Debug, Default)]
pub struct ChaosEdge {
    /// Uplink (edge → cloud) impairments.
    pub tx: Impairments,
    /// Downlink (cloud → edge) impairments.
    pub rx: Impairments,
}

impl ChaosEdge {
    /// A fully healthy edge (both directions all-off).
    pub fn clean() -> Self {
        Self::default()
    }
}

/// Which serving loop the cloud runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeStyle {
    /// One blocking thread per accepted client ([`multi::serve_clients`]).
    Threaded,
    /// One nonblocking I/O thread on the given readiness backend
    /// ([`multi::serve_clients_reactor`], 2 codec workers).
    Reactor(ReadinessBackend),
}

/// A scripted chaos scenario: N sharded edges (edge `i` claims shard `i`)
/// training over real TCP against one cloud, each edge behind its own
/// seeded fault injector.
#[derive(Clone, Debug)]
pub struct ChaosFleet {
    /// Scenario label (seed banners and failure messages).
    pub name: &'static str,
    /// Fleet seed — the only entropy; link and data seeds derive from it.
    pub seed: u64,
    /// Which serving loop the cloud runs.
    pub serve: ServeStyle,
    /// Listen/connect address, e.g. `"127.0.0.1:39440"` (one port per
    /// scenario, like every TCP test in this repo).
    pub addr: String,
    /// Per-edge impairment matrices; `edges.len()` is the fleet size.
    pub edges: Vec<ChaosEdge>,
    /// Training steps per edge.
    pub steps: u64,
    /// Key-rotation cadence in steps (0 = fixed keys).
    pub rotation_steps: u64,
    /// Compression ratio R (must divide `batch`).
    pub r: usize,
    /// Feature dimensionality D.
    pub d: usize,
    /// Batch size B.
    pub batch: usize,
}

impl ChaosFleet {
    /// A fleet of `n` healthy edges at the default chaos geometry
    /// (R=4, D=128, B=8, 3 steps, fixed keys) — the baseline scenarios
    /// mutate individual edges from here.
    pub fn clean(
        name: &'static str,
        seed: u64,
        serve: ServeStyle,
        addr: &str,
        n: usize,
    ) -> Self {
        ChaosFleet {
            name,
            seed,
            serve,
            addr: addr.to_string(),
            edges: vec![ChaosEdge::clean(); n],
            steps: 3,
            rotation_steps: 0,
            r: 4,
            d: 128,
            batch: 8,
        }
    }

    /// The key ring this fleet's gate and edges share (derived from the
    /// fleet seed, so two fleets with equal seeds share key material).
    pub fn ring(&self) -> KeyRing {
        KeyRing::new(
            sub_seed(self.seed, 0x4B45_5952, 0), // "KEYR"
            self.r,
            self.d,
            self.rotation_steps,
        )
    }

    /// Edge `i`'s fault-injector seed.
    pub fn link_seed(&self, i: usize) -> u64 {
        sub_seed(self.seed, 0x4C49_4E4B, i as u64) // "LINK"
    }

    /// Edge `i`'s probe-data seed.
    pub fn data_seed(&self, i: usize) -> u64 {
        sub_seed(self.seed, 0x4441_5441, i as u64) // "DATA"
    }
}

/// Everything a finished fleet run produced, for exact accounting.
#[derive(Debug)]
pub struct ChaosRun {
    /// The cloud's aggregate outcome (first failing client's error when any
    /// connection died — healthy accounting lives in `edges`).
    pub cloud: Result<MultiStats, String>,
    /// Per-edge outcome, in fleet order (edge `i` = shard `i`).
    pub edges: Vec<Result<EdgeReport, String>>,
    /// Per-edge fault-injector logs, in fleet order — the deterministic
    /// schedule artifact the reproducibility tests compare bit-for-bit.
    pub events: Vec<Vec<FaultEvent>>,
    /// Shard ids still claimed after every connection ended — MUST be empty
    /// (the gate releases claims on every exit path, rude or clean).
    pub unreleased: Vec<u64>,
}

/// Run a scripted fleet to completion: bind, accept, serve through the
/// scripted style, drive every edge through its own seeded injector, join
/// everything, and snapshot the gate's final accounting.  Edges connect
/// sequentially so accept slot `i` is edge `i` on every platform.
pub fn run_fleet(fleet: &ChaosFleet) -> ChaosRun {
    let n = fleet.edges.len();
    let ring = fleet.ring();
    let gate = ShardGate::new(ring, n);
    let listener = Tcp::bind(&fleet.addr).expect("bind chaos listener");
    eprintln!(
        "chaos fleet '{}': {n} edge(s), seed = {:#018x} (rerun: {SEED_ENV}={})",
        fleet.name, fleet.seed, fleet.seed
    );

    let (cloud, per_edge) = std::thread::scope(|sc| {
        let gate = &gate;
        let serve = fleet.serve;
        let cloud = sc.spawn(move || -> Result<MultiStats, String> {
            let streams = Tcp::accept_streams(&listener, n, Duration::from_secs(30))
                .map_err(|e| format!("chaos accept: {e}"))?;
            match serve {
                ServeStyle::Threaded => {
                    let tps = streams
                        .into_iter()
                        .map(Tcp::from_stream)
                        .collect::<std::io::Result<Vec<_>>>()
                        .map_err(|e| format!("chaos wrap: {e}"))?;
                    multi::serve_clients(CloudCodec::Sharded(gate), tps)
                        .map_err(|e| e.to_string())
                }
                ServeStyle::Reactor(backend) => {
                    let conns = streams
                        .into_iter()
                        .map(|s| {
                            NbTcp::from_stream(s)
                                .map(|c| Box::new(c) as Box<dyn ReactorConn>)
                        })
                        .collect::<std::io::Result<Vec<_>>>()
                        .map_err(|e| format!("chaos wrap: {e}"))?;
                    let cfg = ReactorConfig { backend, ..ReactorConfig::default() };
                    multi::serve_clients_reactor(CloudCodec::Sharded(gate), conns, 2, cfg)
                        .map_err(|e| e.to_string())
                }
            }
        });

        // sequential connects pin accept order: slot i == edge i == shard i
        let mut links = Vec::with_capacity(n);
        for (i, e) in fleet.edges.iter().enumerate() {
            let tp = Tcp::connect(&fleet.addr).expect("connect chaos edge");
            links.push(FaultyLink::new(tp, fleet.link_seed(i), e.tx, e.rx));
        }
        let handles: Vec<_> = links
            .into_iter()
            .enumerate()
            .map(|(i, mut link)| {
                let (steps, batch, d) = (fleet.steps, fleet.batch, fleet.d);
                let data_seed = fleet.data_seed(i);
                sc.spawn(move || {
                    let rec = link.recorder();
                    let res = multi::run_edge(
                        EdgeCodec::Sharded {
                            shard: ring.edge_shard(i as u64),
                            workers: 1,
                            fft: FftBackend::default(),
                        },
                        &mut link,
                        steps,
                        data_seed,
                        batch,
                        d,
                    )
                    .map_err(|e| e.to_string());
                    (res, rec.events())
                })
            })
            .collect();
        let per_edge: Vec<_> = handles
            .into_iter()
            .map(|h| h.join().expect("chaos edge thread panicked"))
            .collect();
        (cloud.join().expect("chaos cloud thread panicked"), per_edge)
    });

    let (edges, events) = per_edge.into_iter().unzip();
    let unreleased =
        (0..n as u64).filter(|&id| gate.claimant(id).is_some()).collect();
    ChaosRun { cloud, edges, events, unreleased }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_parsing_takes_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 0x2A "), Some(42));
        assert_eq!(parse_seed("0XfF"), Some(255));
        assert_eq!(parse_seed("nope"), None);
    }

    #[test]
    fn sub_seeds_are_deterministic_and_distinct_per_stream() {
        assert_eq!(sub_seed(7, 1, 0), sub_seed(7, 1, 0));
        assert_ne!(sub_seed(7, 1, 0), sub_seed(7, 1, 1));
        assert_ne!(sub_seed(7, 1, 0), sub_seed(7, 2, 0));
        assert_ne!(sub_seed(7, 1, 0), sub_seed(8, 1, 0));
        let f = ChaosFleet::clean("t", 9, ServeStyle::Threaded, "unused", 2);
        assert_ne!(f.link_seed(0), f.data_seed(0), "streams must not collide");
    }

    #[test]
    fn chaos_failures_always_carry_the_replay_seed() {
        let ctx = ChaosCtx { name: "carrier", seed: 0xABCD };
        ctx.check(true, "fine");
        ctx.check_eq(&1, &1, "fine");
        let err = std::panic::catch_unwind(|| ctx.check(false, "boom"))
            .expect_err("check(false) must panic");
        let msg = err
            .downcast_ref::<String>()
            .expect("chaos panics carry a formatted String");
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("C3SL_CHAOS_SEED=43981"), "{msg}");
        assert!(msg.contains("0x000000000000abcd"), "{msg}");
    }

    #[test]
    fn clean_threaded_fleet_smoke() {
        // the driver's own mechanics: 2 healthy edges, exact accounting
        let fleet = ChaosFleet::clean(
            "driver-smoke",
            0x5D0C,
            ServeStyle::Threaded,
            "127.0.0.1:39430",
            2,
        );
        let run = run_fleet(&fleet);
        let stats = run.cloud.expect("healthy fleet serves cleanly");
        assert_eq!(stats.per_client.len(), 2);
        let mut edge_tx = 0u64;
        for (i, e) in run.edges.iter().enumerate() {
            let e = e.as_ref().expect("healthy edge finishes");
            assert_eq!(e.steps, fleet.steps, "edge {i}");
            edge_tx += e.tx_bytes;
        }
        assert_eq!(stats.total_rx(), edge_tx, "cloud rx == sum of edge uplinks");
        assert!(run.unreleased.is_empty(), "{:?}", run.unreleased);
        // a clean fleet's schedule is all zero-delay deliveries
        for log in &run.events {
            for ev in log {
                assert!(
                    matches!(
                        ev.action,
                        crate::transport::faulty::FaultAction::Delivered { delay_us: 0 }
                    ),
                    "{ev:?}"
                );
            }
        }
    }
}
