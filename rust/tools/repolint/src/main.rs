//! repolint — repo-invariant lints for the c3sl tree.
//!
//! The serving stack rests on hand-rolled concurrency and raw `unsafe` FFI
//! whose correctness contracts live in comments and conventions.  This tool
//! turns those conventions into mechanical CI checks (std-only, no deps):
//!
//! * **safety-comment** — every `unsafe` keyword in code must carry a
//!   `// SAFETY:` comment on the same line or within the six lines above it.
//! * **ffi-containment** — raw `extern` blocks, the epoll/eventfd syscall
//!   identifiers (`epoll_create1`, `epoll_ctl`, `epoll_wait`, `eventfd`) and
//!   the signal-handling identifiers (`signal`, `raise`) may appear only
//!   inside `rust/src/transport/readiness.rs`; every other module goes
//!   through that safe wrapper.
//! * **simd-containment** — `std::arch` SIMD surface (the `x86_64`/`aarch64`
//!   arch-module names, `is_x86_feature_detected`, `_mm*` x86 intrinsics and
//!   `v*q_f32`/`v*q_f64` NEON intrinsics) may appear only inside
//!   `rust/src/fft/kernels.rs`; every other module dispatches through the
//!   safe `Kernels` wrapper there.
//! * **read-gate** — the reactor read-gate (a comparison against
//!   `max_outbox_frames`) may only be expressed inside `Slot::wants_read` in
//!   `rust/src/transport/reactor.rs`; inline re-derivations of the gate are
//!   how the sweep and epoll backends drift apart.
//! * **doc-debt** — `#![allow(missing_docs)]` markers must exactly match the
//!   allowlist in `rust/tools/repolint/doc_debt_allowlist.txt` (currently
//!   empty): new debt fails CI, and a paid-off entry must be removed from
//!   the allowlist so it cannot silently return.
//! * **hot-path-unwrap** — no `.unwrap()` / `.expect(` outside `#[cfg(test)]`
//!   code in the reactor I/O thread hot path
//!   (`rust/src/transport/reactor.rs`, `rust/src/transport/readiness.rs`):
//!   a panic there takes down every connection the pump owns.
//!
//! All lints run on *stripped* source — comments and string/char literals
//! are blanked first (same length, newlines preserved), so prose mentioning
//! `epoll_wait` or a venue label containing `"epoll"` never trips a lint.
//!
//! Usage: `cargo run -p repolint [-- ROOT]` (ROOT defaults to the current
//! directory; it must contain `rust/src`).  Exit status 0 = clean, 1 =
//! violations (printed one per line as `file:line: [lint] message`).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// One lint finding, addressed `file:line` (1-based) for editor jumps.
#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    lint: &'static str,
    msg: String,
}

impl Violation {
    fn new(file: &str, line: usize, lint: &'static str, msg: String) -> Self {
        Violation { file: file.to_string(), line, lint, msg }
    }
}

// ---------------------------------------------------------------------------
// Source stripping: blank comments and string/char literals so lints only
// ever match real code.  Output has the same line structure as the input.
// ---------------------------------------------------------------------------

fn blank(out: &mut String, ch: char) {
    if ch == '\n' {
        out.push('\n');
    } else {
        out.push(' ');
    }
}

/// Replace comments, string literals (plain / raw / byte), and char
/// literals with spaces, preserving newlines so line numbers survive.
fn strip_code(src: &str) -> String {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');

        // line comment
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            while i < n && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }

        // block comment (nested)
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            blank(&mut out, chars[i]);
            blank(&mut out, chars[i + 1]);
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    blank(&mut out, chars[i]);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    blank(&mut out, chars[i]);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                } else {
                    blank(&mut out, chars[i]);
                    i += 1;
                }
            }
            continue;
        }

        // raw string r"..." / r#"..."# (optionally byte: br"...")
        if !prev_ident && (c == 'r' || (c == 'b' && i + 1 < n && chars[i + 1] == 'r')) {
            let r_at = if c == 'r' { i } else { i + 1 };
            let mut j = r_at + 1;
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                // scan for the closing quote followed by `hashes` hash marks
                let mut k = j + 1;
                let end;
                loop {
                    if k >= n {
                        end = n;
                        break;
                    }
                    if chars[k] == '"' {
                        let mut h = 0usize;
                        let mut m = k + 1;
                        while m < n && h < hashes && chars[m] == '#' {
                            h += 1;
                            m += 1;
                        }
                        if h == hashes {
                            end = m;
                            break;
                        }
                    }
                    k += 1;
                }
                while i < end {
                    blank(&mut out, chars[i]);
                    i += 1;
                }
                continue;
            }
            // not actually a raw string ("r" was an identifier head) — fall through
        }

        // byte-string prefix: blank the `b` and let the `"` arm take over
        if !prev_ident && c == 'b' && i + 1 < n && chars[i + 1] == '"' {
            out.push(' ');
            i += 1;
            continue;
        }

        // plain string with escapes
        if c == '"' {
            blank(&mut out, chars[i]);
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    blank(&mut out, chars[i]);
                    i += 1;
                    if i < n {
                        blank(&mut out, chars[i]);
                        i += 1;
                    }
                } else if chars[i] == '"' {
                    blank(&mut out, chars[i]);
                    i += 1;
                    break;
                } else {
                    blank(&mut out, chars[i]);
                    i += 1;
                }
            }
            continue;
        }

        // char literal vs lifetime tick
        if c == '\'' {
            let is_char_lit = if i + 1 < n && chars[i + 1] == '\\' {
                true
            } else {
                i + 2 < n && chars[i + 2] == '\''
            };
            if is_char_lit {
                blank(&mut out, chars[i]);
                i += 1;
                while i < n && chars[i] != '\'' {
                    if chars[i] == '\\' {
                        blank(&mut out, chars[i]);
                        i += 1;
                        if i < n {
                            blank(&mut out, chars[i]);
                            i += 1;
                        }
                    } else {
                        blank(&mut out, chars[i]);
                        i += 1;
                    }
                }
                if i < n {
                    blank(&mut out, chars[i]);
                    i += 1;
                }
            } else {
                // lifetime (`'a`, `'static`) — plain code
                out.push(c);
                i += 1;
            }
            continue;
        }

        out.push(c);
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Small text helpers shared by the lints.
// ---------------------------------------------------------------------------

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Word-boundary substring search (ASCII identifier boundaries).
fn contains_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0usize;
    while start <= line.len() {
        let Some(pos) = line[start..].find(word) else { return false };
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_byte(bytes[p - 1]);
        let after = p + word.len();
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        start = p + word.len();
    }
    false
}

/// 0-based (start, end) line range of the body of the first function whose
/// signature line contains `needle`, found by brace counting on stripped
/// source.  `None` when the function is absent.
fn function_body_range(stripped: &str, needle: &str) -> Option<(usize, usize)> {
    let lines: Vec<&str> = stripped.lines().collect();
    let start = lines.iter().position(|l| l.contains(needle))?;
    let mut depth: i64 = 0;
    let mut opened = false;
    for (i, line) in lines.iter().enumerate().skip(start) {
        for ch in line.chars() {
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if opened && depth <= 0 {
            return Some((start, i));
        }
    }
    None
}

/// 0-based line index of the first `#[cfg(test)]` attribute, if any.  In
/// the hot-path files the test module is the final item, so everything from
/// the attribute down is test-only code.
fn first_cfg_test_line(stripped: &str) -> Option<usize> {
    stripped
        .lines()
        .position(|l| l.replace(' ', "").contains("#[cfg(test)]"))
}

// ---------------------------------------------------------------------------
// The lints.  Each takes the repo-relative path plus raw and stripped text
// so unit tests can feed fixture sources directly.
// ---------------------------------------------------------------------------

/// How many lines above an `unsafe` keyword may hold its `// SAFETY:` tag.
const SAFETY_LOOKBACK: usize = 6;

/// Lint: every `unsafe` in code carries a nearby `// SAFETY:` comment.
fn check_safety_comments(rel: &str, raw: &str, stripped: &str) -> Vec<Violation> {
    let raw_lines: Vec<&str> = raw.lines().collect();
    let mut out = Vec::new();
    for (i, line) in stripped.lines().enumerate() {
        if !contains_word(line, "unsafe") {
            continue;
        }
        let lo = i.saturating_sub(SAFETY_LOOKBACK);
        let documented = raw_lines[lo..=i.min(raw_lines.len() - 1)]
            .iter()
            .any(|l| l.contains("SAFETY:"));
        if !documented {
            out.push(Violation::new(
                rel,
                i + 1,
                "safety-comment",
                format!(
                    "`unsafe` without a `// SAFETY:` comment on the same line or \
                     within {SAFETY_LOOKBACK} lines above"
                ),
            ));
        }
    }
    out
}

/// The only file allowed to contain raw FFI.
const FFI_HOME: &str = "src/transport/readiness.rs";

/// Identifiers that mark raw epoll/eventfd/signal FFI usage.  `signal` and
/// `raise` cover the SIGHUP reload surface: an async-signal handler installed
/// anywhere else could never be audited for signal-safety in one place.
const FFI_WORDS: [&str; 7] =
    ["extern", "epoll_create1", "epoll_ctl", "epoll_wait", "eventfd", "signal", "raise"];

/// Lint: raw `extern` / epoll / eventfd FFI only inside transport::readiness.
fn check_ffi_containment(rel: &str, stripped: &str) -> Vec<Violation> {
    if rel.ends_with(FFI_HOME) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in stripped.lines().enumerate() {
        for word in FFI_WORDS {
            if contains_word(line, word) {
                out.push(Violation::new(
                    rel,
                    i + 1,
                    "ffi-containment",
                    format!(
                        "`{word}` outside transport::readiness — raw FFI lives only in \
                         rust/{FFI_HOME}"
                    ),
                ));
            }
        }
    }
    out
}

/// The only file allowed to touch `std::arch` SIMD intrinsics.
const KERNELS_HOME: &str = "src/fft/kernels.rs";

/// Exact identifiers that mark direct `std::arch` SIMD usage.  The arch
/// module names only ever appear in code as `std::arch::x86_64` /
/// `std::arch::aarch64` paths (cfg attributes quote them as strings, which
/// stripping blanks), and the CPUID probe macro is the detection surface.
const SIMD_WORDS: [&str; 3] = ["x86_64", "aarch64", "is_x86_feature_detected"];

/// True when the line holds an identifier starting with `_mm` (the x86
/// intrinsic families `_mm_*` / `_mm256_*` / `_mm512_*`).
fn has_mm_intrinsic(line: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = line[start..].find("_mm") {
        let p = start + pos;
        if p == 0 || !is_ident_byte(bytes[p - 1]) {
            return true;
        }
        start = p + "_mm".len();
    }
    false
}

/// True when the line holds a NEON-shaped identifier: starts with `v` and
/// embeds the `q_f32`/`q_f64` vector-type suffix (`vfmaq_f64`, `vld1q_f32`).
fn has_neon_intrinsic(line: &str) -> bool {
    let bytes = line.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        if is_ident_byte(bytes[i]) {
            let start = i;
            while i < bytes.len() && is_ident_byte(bytes[i]) {
                i += 1;
            }
            let ident = &line[start..i];
            if ident.starts_with('v') && (ident.contains("q_f32") || ident.contains("q_f64")) {
                return true;
            }
        } else {
            i += 1;
        }
    }
    false
}

/// Lint: `std::arch` SIMD intrinsics only inside fft::kernels.
fn check_simd_containment(rel: &str, stripped: &str) -> Vec<Violation> {
    if rel.ends_with(KERNELS_HOME) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in stripped.lines().enumerate() {
        let hit = SIMD_WORDS
            .iter()
            .find(|w| contains_word(line, w))
            .map(|w| (*w).to_string())
            .or_else(|| has_mm_intrinsic(line).then(|| "_mm* intrinsic".to_string()))
            .or_else(|| {
                has_neon_intrinsic(line).then(|| "v*q_f32/v*q_f64 intrinsic".to_string())
            });
        if let Some(what) = hit {
            out.push(Violation::new(
                rel,
                i + 1,
                "simd-containment",
                format!(
                    "`{what}` outside fft::kernels — std::arch SIMD lives only in \
                     rust/{KERNELS_HOME} (dispatch through its Kernels wrapper)"
                ),
            ));
        }
    }
    out
}

/// File that owns the reactor read-gate.
const GATE_HOME: &str = "src/transport/reactor.rs";

/// Lint: the read-gate comparison against `max_outbox_frames` may only be
/// written inside `Slot::wants_read` — everywhere else must call it.
fn check_read_gate(rel: &str, stripped: &str) -> Vec<Violation> {
    let body = if rel.ends_with(GATE_HOME) {
        function_body_range(stripped, "fn wants_read")
    } else {
        None
    };
    let mut out = Vec::new();
    for (i, line) in stripped.lines().enumerate() {
        if !contains_word(line, "max_outbox_frames") {
            continue;
        }
        // comparison heuristic: `<`, `<=`, `>=`, or a standalone `>` — plain
        // reads (field init, clamp, docs) carry none of these
        let compares = line.contains('<') || line.contains(">=") || line.contains(" > ");
        if !compares {
            continue;
        }
        let allowed = matches!(body, Some((s, e)) if i >= s && i <= e);
        if !allowed {
            out.push(Violation::new(
                rel,
                i + 1,
                "read-gate",
                "read-gate re-derivation: comparisons against `max_outbox_frames` may \
                 only appear inside `Slot::wants_read` (call it instead)"
                    .to_string(),
            ));
        }
    }
    out
}

/// Lint (per-file half): report the 1-based lines of `#![allow(missing_docs)]`
/// markers.  `main` cross-checks the collected set against the allowlist.
fn doc_debt_markers(stripped: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, line) in stripped.lines().enumerate() {
        if line.replace(' ', "").contains("#![allow(missing_docs)]") {
            out.push(i + 1);
        }
    }
    out
}

/// Files whose non-test code is the reactor I/O thread hot path.
const HOT_PATH_FILES: [&str; 2] =
    ["src/transport/reactor.rs", "src/transport/readiness.rs"];

/// Lint: no `.unwrap()` / `.expect(` outside `#[cfg(test)]` in hot-path files.
fn check_hot_path_unwrap(rel: &str, stripped: &str) -> Vec<Violation> {
    if !HOT_PATH_FILES.iter().any(|f| rel.ends_with(f)) {
        return Vec::new();
    }
    let test_start = first_cfg_test_line(stripped).unwrap_or(usize::MAX);
    let mut out = Vec::new();
    for (i, line) in stripped.lines().enumerate() {
        if i >= test_start {
            break;
        }
        for pat in [".unwrap()", ".expect("] {
            if line.contains(pat) {
                out.push(Violation::new(
                    rel,
                    i + 1,
                    "hot-path-unwrap",
                    format!(
                        "`{pat}` on the reactor I/O thread hot path — a panic here kills \
                         every connection the pump owns; return/propagate an error instead"
                    ),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Driver: walk the tree, run every lint, cross-check doc debt.
// ---------------------------------------------------------------------------

/// Directories (relative to the repo root) whose `.rs` files are linted.
const SCAN_ROOTS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// Allowlist of files permitted to carry `#![allow(missing_docs)]`.
const DOC_DEBT_ALLOWLIST: &str = "rust/tools/repolint/doc_debt_allowlist.txt";

fn walk(dir: &Path, files: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(&path, files)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            files.push(path);
        }
    }
    Ok(())
}

fn main() {
    let root = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    let root = PathBuf::from(root);
    if !root.join("rust/src").is_dir() {
        eprintln!(
            "repolint: {} does not look like the repo root (no rust/src); \
             run from the repo root or pass it as the first argument",
            root.display()
        );
        std::process::exit(2);
    }

    let mut files = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if dir.is_dir() {
            if let Err(e) = walk(&dir, &mut files) {
                eprintln!("repolint: walking {}: {e}", dir.display());
                std::process::exit(2);
            }
        }
    }

    let mut violations: Vec<Violation> = Vec::new();
    let mut debt_files: BTreeSet<String> = BTreeSet::new();
    for path in &files {
        let raw = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("repolint: reading {}: {e}", path.display());
                std::process::exit(2);
            }
        };
        let stripped = strip_code(&raw);
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        violations.extend(check_safety_comments(&rel, &raw, &stripped));
        violations.extend(check_ffi_containment(&rel, &stripped));
        violations.extend(check_simd_containment(&rel, &stripped));
        violations.extend(check_read_gate(&rel, &stripped));
        violations.extend(check_hot_path_unwrap(&rel, &stripped));
        for line in doc_debt_markers(&stripped) {
            debt_files.insert(rel.clone());
            violations.push(Violation::new(
                &rel,
                line,
                "doc-debt",
                "marker recorded; allowed only when listed in the allowlist".to_string(),
            ));
        }
    }

    // doc-debt cross-check: markers must exactly match the allowlist.  The
    // per-file marker violations above are provisional — drop the ones the
    // allowlist covers, then flag stale allowlist entries.
    let allow: BTreeSet<String> = std::fs::read_to_string(root.join(DOC_DEBT_ALLOWLIST))
        .map(|text| {
            text.lines()
                .map(|l| l.trim())
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .map(|l| l.to_string())
                .collect()
        })
        .unwrap_or_default();
    violations.retain(|v| v.lint != "doc-debt" || !allow.contains(&v.file));
    for entry in &allow {
        if !debt_files.contains(entry) {
            violations.push(Violation::new(
                entry,
                0,
                "doc-debt",
                "stale allowlist entry: file no longer carries \
                 #![allow(missing_docs)] — remove it from the allowlist"
                    .to_string(),
            ));
        }
    }

    if violations.is_empty() {
        println!("repolint: OK ({} files clean)", files.len());
    } else {
        violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
        for v in &violations {
            println!("{}:{}: [{}] {}", v.file, v.line, v.lint, v.msg);
        }
        eprintln!(
            "repolint: FAIL — {} violation(s) across {} file(s) scanned",
            violations.len(),
            files.len()
        );
        std::process::exit(1);
    }
}

// ---------------------------------------------------------------------------
// Self-tests: each lint must fire on a seeded violation and stay quiet on
// the compliant spelling.  Fixture sources are built by joining lines so the
// fixtures themselves never appear as code to a scanner.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn src(lines: &[&str]) -> String {
        lines.join("\n")
    }

    #[test]
    fn stripper_blanks_comments_and_strings() {
        let s = src(&[
            "let a = 1; // epoll_ctl in prose",
            "let b = \"epoll_wait inside a string\";",
            "/* block extern comment */ let c = 2;",
            "let d = r#\"raw eventfd string\"#;",
            "let e = 'x'; let f: &'static str = \"y\";",
        ]);
        let out = strip_code(&s);
        assert!(!out.contains("epoll_ctl"));
        assert!(!out.contains("epoll_wait"));
        assert!(!out.contains("extern"));
        assert!(!out.contains("eventfd"));
        assert!(out.contains("let a = 1;"));
        assert!(out.contains("let c = 2;"));
        assert!(out.contains("'static"), "lifetimes survive stripping");
        assert_eq!(s.lines().count(), out.lines().count(), "line structure preserved");
    }

    #[test]
    fn stripper_handles_nested_block_comments() {
        let s = "/* outer /* inner extern */ still comment */ let x = 1;";
        let out = strip_code(s);
        assert!(!out.contains("extern"));
        assert!(out.contains("let x = 1;"));
    }

    #[test]
    fn safety_lint_fires_on_undocumented_unsafe() {
        let bad = src(&["fn f() {", "    let x = unsafe { danger() };", "}"]);
        let v = check_safety_comments("src/x.rs", &bad, &strip_code(&bad));
        assert_eq!(v.len(), 1, "undocumented unsafe must fail");
        assert_eq!(v[0].lint, "safety-comment");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn safety_lint_accepts_documented_unsafe() {
        let good = src(&[
            "fn f() {",
            "    // SAFETY: danger() upholds its contract because reasons.",
            "    let x = unsafe { danger() };",
            "}",
        ]);
        let v = check_safety_comments("src/x.rs", &good, &strip_code(&good));
        assert!(v.is_empty(), "documented unsafe must pass: {v:?}");
    }

    #[test]
    fn safety_lint_ignores_unsafe_in_prose_and_strings() {
        let s = src(&[
            "// this comment says unsafe but there is no unsafe code",
            "let s = \"unsafe\";",
        ]);
        let v = check_safety_comments("src/x.rs", &s, &strip_code(&s));
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn ffi_lint_fires_outside_readiness() {
        let bad = src(&["extern \"C\" {", "    fn close(fd: i32) -> i32;", "}"]);
        let v = check_ffi_containment("src/transport/reactor.rs", &strip_code(&bad));
        assert_eq!(v.len(), 1, "extern outside readiness must fail");
        assert_eq!(v[0].lint, "ffi-containment");

        let call = "let rc = epoll_ctl(ep, op, fd, &mut ev);";
        let v = check_ffi_containment("src/coordinator/multi.rs", &strip_code(call));
        assert_eq!(v.len(), 1, "raw epoll syscall outside readiness must fail");

        let sig = "let old = signal(1, handler as usize);";
        let v = check_ffi_containment("src/coordinator/driver.rs", &strip_code(sig));
        assert_eq!(v.len(), 1, "raw signal(2) outside readiness must fail");

        let rse = "let rc = raise(1);";
        let v = check_ffi_containment("src/transport/reactor.rs", &strip_code(rse));
        assert_eq!(v.len(), 1, "raw raise(3) outside readiness must fail");
    }

    #[test]
    fn ffi_lint_allows_readiness_and_prose() {
        let ok = src(&["extern \"C\" {", "    fn eventfd(i: u32, f: i32) -> i32;", "}"]);
        let v = check_ffi_containment("src/transport/readiness.rs", &strip_code(&ok));
        assert!(v.is_empty(), "{v:?}");

        let prose = "// the epoll_wait loop is documented here; \"eventfd\" label";
        let v = check_ffi_containment("src/coordinator/multi.rs", &strip_code(prose));
        assert!(v.is_empty(), "comments and strings never trip the lint: {v:?}");

        // the safe wrappers' *names* embed the words but are distinct
        // identifiers — word-boundary matching must not flag them
        let wrapped = "raise_hangup(); let n = hangup_count(); signal_strength();";
        let v = check_ffi_containment("src/coordinator/multi.rs", &strip_code(wrapped));
        assert!(v.is_empty(), "wrapper identifiers never trip the lint: {v:?}");
    }

    #[test]
    fn simd_lint_fires_outside_kernels() {
        let v = check_simd_containment(
            "src/hdc/mod.rs",
            &strip_code("let v = _mm256_loadu_pd(p);"),
        );
        assert_eq!(v.len(), 1, "x86 intrinsic outside kernels must fail");
        assert_eq!(v[0].lint, "simd-containment");
        assert_eq!(v[0].line, 1);

        let v = check_simd_containment(
            "src/fft/mod.rs",
            &strip_code("let t = vfmaq_f64(acc, a, b);"),
        );
        assert_eq!(v.len(), 1, "NEON intrinsic outside kernels must fail");

        let v = check_simd_containment("src/main.rs", &strip_code("use std::arch::x86_64::*;"));
        assert_eq!(v.len(), 1, "std::arch imports outside kernels must fail");

        let v = check_simd_containment(
            "src/config/mod.rs",
            &strip_code("if std::arch::is_x86_feature_detected!(\"avx2\") {}"),
        );
        assert_eq!(v.len(), 1, "ad-hoc CPUID probes outside kernels must fail");
    }

    #[test]
    fn simd_lint_allows_kernels_prose_and_boundary_words() {
        let ok = src(&[
            "use std::arch::x86_64::*;",
            "let v = _mm256_fmaddsub_pd(a, b, c);",
            "let t = vdupq_laneq_f64::<0>(kv);",
        ]);
        let v = check_simd_containment("src/fft/kernels.rs", &strip_code(&ok));
        assert!(v.is_empty(), "the kernels module is the one home: {v:?}");

        let prose = "// _mm256_loadu_pd and vfmaq_f64 live in kernels; \"x86_64\" label";
        let v = check_simd_containment("src/hdc/mod.rs", &strip_code(prose));
        assert!(v.is_empty(), "comments and strings never trip the lint: {v:?}");

        // cfg attributes quote the arch names as strings — stripped away
        let cfg = "#[cfg_attr(target_arch = \"x86_64\", repr(C, packed))]";
        let v = check_simd_containment("src/transport/readiness.rs", &strip_code(cfg));
        assert!(v.is_empty(), "cfg strings are stripped: {v:?}");

        // distinct identifiers embedding the patterns must not trip
        let near = "let freq_f32 = 1.0; let my_aarch64_flag = m.arch.clone();";
        let v = check_simd_containment("src/runtime/manifest.rs", &strip_code(near));
        assert!(v.is_empty(), "word boundaries respected: {v:?}");
    }

    #[test]
    fn read_gate_lint_fires_on_inline_rederivation() {
        let bad = src(&[
            "fn service(&mut self) {",
            "    if self.pending() < cfg.max_outbox_frames {",
            "        self.read();",
            "    }",
            "}",
        ]);
        let v = check_read_gate("src/coordinator/multi.rs", &strip_code(&bad));
        assert_eq!(v.len(), 1, "inline gate re-derivation must fail");
        assert_eq!(v[0].lint, "read-gate");

        // even inside reactor.rs, outside wants_read it still fails
        let v = check_read_gate("src/transport/reactor.rs", &strip_code(&bad));
        assert_eq!(v.len(), 1, "re-derivation outside wants_read must fail");
    }

    #[test]
    fn read_gate_lint_allows_wants_read_and_plain_reads() {
        let good = src(&[
            "impl Slot {",
            "    fn wants_read(&self, cfg: &ReactorConfig) -> bool {",
            "        self.link.is_some() && self.pending() < cfg.max_outbox_frames",
            "    }",
            "}",
        ]);
        let v = check_read_gate("src/transport/reactor.rs", &strip_code(&good));
        assert!(v.is_empty(), "the one true gate definition must pass: {v:?}");

        let plain = src(&[
            "let cfg = ReactorConfig { max_outbox_frames: 2, ..Default::default() };",
            "let b = other.max_outbox_frames.max(1);",
        ]);
        let v = check_read_gate("src/main.rs", &strip_code(&plain));
        assert!(v.is_empty(), "non-comparison uses must pass: {v:?}");
    }

    #[test]
    fn doc_debt_markers_are_detected_not_in_comments() {
        let s = src(&[
            "//! module docs",
            "#![allow(missing_docs)]",
            "// a comment naming #![allow(missing_docs)] is not a marker",
        ]);
        assert_eq!(doc_debt_markers(&strip_code(&s)), vec![2]);
        assert!(doc_debt_markers(&strip_code("fn f() {}")).is_empty());
    }

    #[test]
    fn hot_path_unwrap_fires_before_tests_only() {
        let bad = src(&[
            "fn poll(&mut self) {",
            "    let x = self.q.pop().unwrap();",
            "    let y = self.q.pop().expect(\"boom\");",
            "}",
            "#[cfg(test)]",
            "mod tests {",
            "    fn t() { Some(1).unwrap(); }",
            "}",
        ]);
        let v = check_hot_path_unwrap("src/transport/reactor.rs", &strip_code(&bad));
        assert_eq!(v.len(), 2, "non-test unwrap/expect must fail: {v:?}");
        assert!(v.iter().all(|v| v.lint == "hot-path-unwrap"));
        assert!(v.iter().all(|v| v.line <= 3), "test code is exempt");
    }

    #[test]
    fn hot_path_unwrap_scopes_to_hot_files() {
        let s = "fn f() { Some(1).unwrap(); }";
        let v = check_hot_path_unwrap("src/coordinator/multi.rs", &strip_code(s));
        assert!(v.is_empty(), "only the reactor hot-path files are in scope");
    }

    #[test]
    fn function_body_range_tracks_braces() {
        let s = src(&[
            "fn other() { 1 }",
            "fn wants_read(&self) -> bool {",
            "    if x {",
            "        true",
            "    } else {",
            "        false",
            "    }",
            "}",
            "fn after() {}",
        ]);
        assert_eq!(function_body_range(&s, "fn wants_read"), Some((1, 7)));
        assert_eq!(function_body_range(&s, "fn missing"), None);
    }

    #[test]
    fn contains_word_respects_identifier_boundaries() {
        assert!(contains_word("let x = eventfd(0, 0);", "eventfd"));
        assert!(!contains_word("let my_eventfd_count = 1;", "eventfd"));
        assert!(!contains_word("external linkage", "extern"));
        assert!(contains_word("extern \"C\"", "extern"));
    }
}
