//! Quickstart: one full C3-SL round trip (the paper's Fig. 2 pipeline).
//!
//!   edge_fwd → c3_encode → [uplink] → c3_decode → cloud_step
//!           → c3_encode(gẑ) → [downlink] → c3_decode → edge_bwd → adam
//!
//! Run `make artifacts` first, then:
//!   cargo run --release --example quickstart
//!
//! Everything below executes AOT-compiled XLA artifacts through PJRT —
//! python is not involved.

use c3sl::util::error::Result;

use c3sl::runtime::{AdamState, CodecRuntime, Engine, ModelRuntime};
use c3sl::tensor::{Labels, Tensor};
use c3sl::transport::wire;
use c3sl::util::rng::Rng;

fn main() -> Result<()> {
    if !std::path::Path::new("artifacts/vggt_b32/manifest.json").exists() {
        println!("SKIP quickstart: artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());

    // ---- load AOT artifacts (L2 model + L1 Pallas codec) -------------------
    let model = ModelRuntime::load(&engine, "artifacts/vggt_b32")?;
    let m = &model.manifest;
    println!(
        "model {}: {} image={} classes={} batch={} D={}",
        m.key, m.arch, m.image, m.classes, m.batch, m.d_tx
    );

    let mut codec = CodecRuntime::load(&engine, "artifacts/vggt_b32/codec_c3_r4")?;
    codec.init_keys(0xC3)?; // both sides derive keys from a shared seed
    println!(
        "codec: R={} G={} kernel={} (Pallas, AOT)",
        codec.r(),
        codec.manifest.g,
        codec.manifest.kernel
    );

    // ---- init both halves ---------------------------------------------------
    let mut edge_params = model.edge_init(1)?;
    let cloud_params = model.cloud_init(2)?;
    let mut edge_adam = AdamState::zeros_like(&edge_params)?;

    // ---- a synthetic batch ---------------------------------------------------
    let mut rng = Rng::new(7);
    let mut xdata = vec![0.0f32; m.batch * 3 * m.image * m.image];
    rng.fill_normal(&mut xdata, 0.0, 1.0);
    let x = Tensor::from_vec(&[m.batch, 3, m.image, m.image], xdata);
    let y = Labels((0..m.batch as i32).map(|i| i % m.classes as i32).collect());

    // ---- Fig. 2, uplink -----------------------------------------------------
    let z = model.edge_fwd(&edge_params, &x)?;
    let s = codec.encode(&z)?;
    let up_full = wire::tensor_msg_bytes(&z);
    let up_c3 = wire::tensor_msg_bytes(&s);
    println!(
        "\nuplink:   z {:?} ({} B) → S {:?} ({} B) — {:.2}x smaller",
        z.shape(),
        up_full,
        s.shape(),
        up_c3,
        up_full as f64 / up_c3 as f64
    );

    // ---- cloud side ------------------------------------------------------------
    let zhat = codec.decode(&s)?;
    let recon = zhat.rel_err(&z);
    let out = model.cloud_step(&cloud_params, &zhat, &y)?;
    println!(
        "cloud:    decode rel-err {:.3} → loss {:.4}, acc {:.1}%",
        recon,
        out.loss,
        100.0 * out.ncorrect / m.batch as f32
    );

    // ---- Fig. 2, downlink (gradients compressed with the SAME codec) -------
    let gs = codec.encode(&out.gz)?;
    let down_full = wire::tensor_msg_bytes(&out.gz);
    let down_c3 = wire::tensor_msg_bytes(&gs);
    println!(
        "downlink: gẑ ({} B) → encoded ({} B) — {:.2}x smaller",
        down_full,
        down_c3,
        down_full as f64 / down_c3 as f64
    );

    // ---- edge backward + Adam ------------------------------------------------
    let gz = codec.decode(&gs)?;
    let grads = model.edge_bwd(&edge_params, &x, &gz)?;
    edge_params = model.edge_adam(edge_params, &grads, &mut edge_adam, 1e-4)?;
    let z2 = model.edge_fwd(&edge_params, &x)?;
    println!(
        "edge:     adam step applied; features moved by rel {:.5}",
        z2.rel_err(&z)
    );

    println!("\nquickstart OK — full Fig. 2 round trip through AOT artifacts");
    Ok(())
}
