//! Integration tests over the AOT artifacts + runtime + coordinator.
//!
//! These need `make artifacts` to have produced artifacts/vggt_b32 (the tiny
//! preset).  They are skipped (with a loud message) if the artifacts are
//! missing, so `cargo test` stays green on a fresh checkout; CI runs
//! `make test` which builds artifacts first.

use c3sl::config::{CodecVenue, ExperimentConfig, SchemeKind, TransportKind};
use c3sl::coordinator::run_experiment;
use c3sl::hdc::{Backend, KeySet, C3};
use c3sl::runtime::{CodecRuntime, Engine, ModelRuntime};
use c3sl::tensor::{Labels, Tensor};
use c3sl::util::rng::Rng;

const MODEL_DIR: &str = "artifacts/vggt_b32";
const CODEC_DIR: &str = "artifacts/vggt_b32/codec_c3_r4";

fn have_artifacts() -> bool {
    let ok = std::path::Path::new(MODEL_DIR).join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts` first");
    }
    ok
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let mut d = vec![0.0f32; shape.iter().product()];
    rng.fill_normal(&mut d, 0.0, 1.0);
    Tensor::from_vec(shape, d)
}

#[test]
fn model_runtime_shapes_and_init() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = ModelRuntime::load(&engine, MODEL_DIR).unwrap();
    let m = &model.manifest;
    assert_eq!(m.batch, 32);
    assert_eq!(m.classes, 10);

    let params = model.edge_init(7).unwrap();
    assert_eq!(params.len(), m.edge_params.len());

    let mut rng = Rng::new(1);
    let x = rand_tensor(&mut rng, &[m.batch, 3, m.image, m.image]);
    let z = model.edge_fwd(&params, &x).unwrap();
    assert_eq!(z.shape(), &[m.batch, m.d_tx]);
    assert!(z.data().iter().all(|v| v.is_finite()));

    // determinism: same seed → same init → same forward
    let params2 = model.edge_init(7).unwrap();
    let z2 = model.edge_fwd(&params2, &x).unwrap();
    assert_eq!(z, z2);
    // different seed → different params
    let params3 = model.edge_init(8).unwrap();
    let z3 = model.edge_fwd(&params3, &x).unwrap();
    assert!(z.rel_err(&z3) > 1e-3);
}

#[test]
fn cloud_step_produces_grads_and_finite_loss() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = ModelRuntime::load(&engine, MODEL_DIR).unwrap();
    let m = &model.manifest;
    let cparams = model.cloud_init(3).unwrap();
    let mut rng = Rng::new(2);
    let zhat = rand_tensor(&mut rng, &[m.batch, m.d_tx]);
    let y = Labels((0..m.batch as i32).map(|i| i % m.classes as i32).collect());
    let out = model.cloud_step(&cparams, &zhat, &y).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert!((0.0..=m.batch as f32).contains(&out.ncorrect));
    assert_eq!(out.grads.len(), cparams.len());
    assert_eq!(out.gz.shape(), &[m.batch, m.d_tx]);
    // eval on the same inputs gives the same loss (no dropout/bn-state drift)
    let (eloss, enc) = model.cloud_eval(&cparams, &zhat, &y).unwrap();
    assert!((eloss - out.loss).abs() < 1e-4);
    assert_eq!(enc, out.ncorrect);
}

#[test]
fn artifact_codec_matches_host_codec_on_same_keys() {
    // The Pallas kernel artifacts (L1) and the rust-native hdc codec (L3)
    // must agree when fed identical keys — a cross-layer numerics check.
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut codec = CodecRuntime::load(&engine, CODEC_DIR).unwrap();
    codec.init_keys(42).unwrap();
    let keys = codec.keys_tensor().unwrap().clone();
    let host = C3::new(KeySet::from_tensor(&keys).unwrap(), Backend::Fft);

    let mut rng = Rng::new(5);
    let z = rand_tensor(&mut rng, &[codec.manifest.batch, codec.manifest.d]);
    let s_artifact = codec.encode(&z).unwrap();
    let s_host = host.encode(&z);
    assert!(
        s_artifact.rel_err(&s_host) < 1e-4,
        "encode mismatch {}",
        s_artifact.rel_err(&s_host)
    );

    let zh_artifact = codec.decode(&s_artifact).unwrap();
    let zh_host = host.decode(&s_host);
    assert!(zh_artifact.rel_err(&zh_host) < 1e-4);
}

#[test]
fn artifact_codec_adjointness() {
    // <E(z), s> == <z, D(s)> through the AOT Pallas kernels — the identity
    // that makes compressed downlink gradients exact (DESIGN.md §1).
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut codec = CodecRuntime::load(&engine, CODEC_DIR).unwrap();
    codec.init_keys(43).unwrap();
    let (b, g, d) = (codec.manifest.batch, codec.manifest.g, codec.manifest.d);
    let mut rng = Rng::new(6);
    let z = rand_tensor(&mut rng, &[b, d]);
    let s = rand_tensor(&mut rng, &[g, d]);
    let lhs = codec.encode(&z).unwrap().dot(&s);
    let rhs = z.dot(&codec.decode(&s).unwrap());
    assert!(
        (lhs - rhs).abs() < 1e-2 * (1.0 + lhs.abs()),
        "{lhs} vs {rhs}"
    );
}

fn quick_cfg(scheme: SchemeKind, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: "itest".into(),
        model_key: "vggt_b32".into(),
        artifacts_root: "artifacts".into(),
        scheme,
        codec_venue: CodecVenue::Artifact,
        transport: TransportKind::InProc,
        steps,
        lr: 1e-3,
        seed: 11,
        eval_every: steps,
        eval_batches: 2,
        synth_train: 256,
        synth_test: 64,
        ..Default::default()
    }
}

#[test]
fn e2e_vanilla_training_reduces_loss() {
    if !have_artifacts() {
        return;
    }
    let out = run_experiment(&quick_cfg(SchemeKind::Vanilla, 12)).unwrap();
    let rec = &out.recorder;
    assert_eq!(rec.records.len(), 12);
    let first = rec.records[0].loss;
    let last_avg: f64 =
        rec.records[8..].iter().map(|r| r.loss).sum::<f64>() / 4.0;
    assert!(
        last_avg < first,
        "loss did not decrease: first={first} last_avg={last_avg}"
    );
    assert!(out.wire_tx > 0 && out.wire_rx > 0);
    assert!(!rec.evals.is_empty());
}

#[test]
fn e2e_c3_training_runs_and_compresses() {
    if !have_artifacts() {
        return;
    }
    let vanilla = run_experiment(&quick_cfg(SchemeKind::Vanilla, 6)).unwrap();
    let c3 = run_experiment(&quick_cfg(SchemeKind::C3 { r: 4 }, 6)).unwrap();
    // features+gradients dominate the wire; C3 r=4 must cut uplink ~4×
    let up_ratio = vanilla.recorder.total_uplink() as f64
        / c3.recorder.total_uplink() as f64;
    assert!(up_ratio > 3.0, "uplink ratio {up_ratio}");
    let down_ratio = vanilla.recorder.total_downlink() as f64
        / c3.recorder.total_downlink() as f64;
    assert!(down_ratio > 3.5, "downlink ratio {down_ratio}");
    // training still makes progress through the lossy codec
    assert!(c3.recorder.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn e2e_host_venue_matches_wire_ratio() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(SchemeKind::C3 { r: 8 }, 4);
    cfg.codec_venue = CodecVenue::Host;
    let out = run_experiment(&cfg).unwrap();
    assert!(out.recorder.records.iter().all(|r| r.loss.is_finite()));
    // 8× fewer feature bytes than vanilla would send per step
    let m = c3sl::runtime::ModelManifest::load(MODEL_DIR).unwrap();
    let payload = (m.batch / 8) * m.d_tx * 4;
    let up = out.recorder.records[0].uplink_bytes as usize;
    assert!(up < payload * 2, "uplink {up} vs payload {payload}");
}
