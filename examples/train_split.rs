//! End-to-end driver: split-learning training runs over the two-actor
//! coordinator for every compression scheme, on SynthCIFAR (or real CIFAR if
//! binaries are present under data/).  This is the run recorded in
//! EXPERIMENTS.md: loss curves per scheme, accuracy after N steps, and the
//! measured wire traffic.
//!
//!   cargo run --release --example train_split             # default 150 steps
//!   C3SL_STEPS=400 cargo run --release --example train_split
//!
//! Loss curves land in runs/train_split_<scheme>.csv.

use c3sl::util::error::Result;

use c3sl::config::{CodecVenue, ExperimentConfig, SchemeKind, TransportKind};
use c3sl::coordinator::run_experiment;

fn cfg(scheme: SchemeKind, steps: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "train_split".into(),
        model_key: "vggt_b32".into(),
        artifacts_root: "artifacts".into(),
        scheme,
        codec_venue: CodecVenue::Artifact,
        transport: TransportKind::InProc,
        steps,
        lr: 1e-3,
        seed,
        augment: false,
        eval_every: steps / 3,
        eval_batches: 8,
        synth_train: 2048,
        synth_test: 512,
        ..Default::default()
    }
}

fn main() -> Result<()> {
    if !std::path::Path::new("artifacts/vggt_b32/manifest.json").exists() {
        println!("SKIP train_split: artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let steps: usize = std::env::var("C3SL_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150);
    let seed: u64 = std::env::var("C3SL_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);

    let schemes: Vec<SchemeKind> = vec![
        SchemeKind::Vanilla,
        SchemeKind::C3 { r: 2 },
        SchemeKind::C3 { r: 4 },
        SchemeKind::C3 { r: 8 },
        SchemeKind::C3 { r: 16 },
        SchemeKind::BottleNetPP { r: 4 },
    ];

    println!(
        "train_split: vggt_b32 on synthcifar10, {steps} steps, seed {seed}\n"
    );
    println!(
        "{:<12} {:>10} {:>10} {:>12} {:>12} {:>9} {:>8}",
        "scheme", "final loss", "eval acc", "uplink B", "downlink B", "vs van.", "wall s"
    );

    let mut vanilla_up = 0u64;
    for scheme in schemes {
        let c = cfg(scheme, steps, seed);
        let out = run_experiment(&c)?;
        let rec = &out.recorder;
        let eval_acc = rec.evals.last().map(|e| e.2).unwrap_or(f64::NAN);
        let up = rec.total_uplink();
        if scheme == SchemeKind::Vanilla {
            vanilla_up = up;
        }
        let reduction = if up > 0 { vanilla_up as f64 / up as f64 } else { 0.0 };
        println!(
            "{:<12} {:>10.4} {:>9.1}% {:>12} {:>12} {:>8.2}x {:>8.1}",
            scheme.name(),
            rec.final_loss().unwrap_or(f64::NAN),
            eval_acc * 100.0,
            up,
            rec.total_downlink(),
            reduction,
            out.wall_seconds
        );
        let csv = format!("runs/train_split_{}.csv", scheme.name());
        rec.write_csv(&csv)?;
    }
    println!("\nloss curves written to runs/train_split_<scheme>.csv");
    Ok(())
}
