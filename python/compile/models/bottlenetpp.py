# BottleNet++ (Shao & Zhang, ICC 2020) — the paper's dimension-wise baseline.
#
# Encoder (edge side):  conv k=2×2 stride (2,2) C → C′, BatchNorm, Sigmoid.
# Decoder (cloud side): deconv k=2×2 stride (2,2) C′ → C, BatchNorm, ReLU.
# Channel-condition layers are removed, as in the paper's §4.1 setup.
#
# Overall compression R combines 4× spatial (k=s=2) with channel scaling:
# C′ = 4C/R (paper Table 2), so bytes shrink by exactly R.
#
# In this reproduction BottleNet++ is *model composition*: the encoder is
# appended to f_theta (trained on the edge) and the decoder prepended to
# f_psi (trained on the cloud), so the standard split-SL gradient path trains
# the codec end-to-end, exactly like the original.

from typing import Tuple

from .. import nn


def bottlenetpp_codec(c: int, h: int, w: int, ratio: int,
                      k: int = 2, stride: int = 2) -> Tuple[nn.Layer, nn.Layer, int]:
    """Return (encoder, decoder, d_tx) for a cut tensor (c, h, w).

    encoder: (c,h,w) → flat (d_tx,);  decoder: flat (d_tx,) → (c,h,w).
    d_tx = C′·(H/2)·(W/2) = (C·H·W)/ratio.
    """
    spatial = stride * stride
    assert ratio >= 1 and (ratio * h * w) % (spatial * h * w) == 0 or True
    c_prime = max(1, (spatial * c) // ratio)          # C′ = 4C/R
    h2, w2 = h // stride, w // stride
    d_tx = c_prime * h2 * w2

    encoder = nn.Sequential([
        nn.Conv2d(c, c_prime, k=k, stride=stride, padding="SAME"),
        nn.BatchNormStatic(c_prime),
        nn.Sigmoid(),
        nn.Flatten(),
    ], name=f"bnpp_enc/{c}->{c_prime}")

    unflat = nn.Lambda(
        "unflatten",
        lambda x: x.reshape(x.shape[0], c_prime, h2, w2),
        lambda s: (c_prime, h2, w2))
    decoder = nn.Sequential([
        unflat,
        nn.Deconv2d(c_prime, c, k=k, stride=stride),
        nn.BatchNormStatic(c),
        nn.ReLU(),
        nn.Flatten(),
    ], name=f"bnpp_dec/{c_prime}->{c}")

    return encoder, decoder, d_tx
