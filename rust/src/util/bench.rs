//! Shared policy plumbing for the benchmark-regression gates.
//!
//! Two benches gate against the committed `BENCH_baseline.json` —
//! `benches/codec_hotpath.rs` (the `host/*` venues) and
//! `benches/reactor_scale.rs` (the `reactor/*` venues) — and their
//! warn-vs-fail policy must stay in lockstep: the tolerance knob and the
//! calibrated-baseline switch live HERE, once, so a policy change cannot
//! silently diverge the two gates.  The venue-schema-specific comparison
//! loops remain in each bench (the schemas legitimately differ).

use crate::util::json::Json;

/// The relative regression tolerance every bench gate applies: env
/// `C3SL_BENCH_GATE_TOL` (a fraction, e.g. `0.15`), defaulting to 15%.
pub fn gate_tolerance() -> f64 {
    std::env::var("C3SL_BENCH_GATE_TOL")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.15)
}

/// Whether a committed baseline is calibrated — i.e. its absolute numbers
/// were measured on the reference runner class, arming the hard checks.
/// A baseline WITHOUT the flag reads as calibrated (a hand-written
/// baseline that omits it should block on its numbers, not silently
/// downgrade to warnings); the committed uncalibrated baselines say
/// `"calibrated": false` explicitly.
pub fn calibrated(baseline: &Json) -> bool {
    baseline.get("calibrated").and_then(|v| v.as_bool()).unwrap_or(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn calibrated_flag_policy() {
        assert!(!calibrated(&parse(r#"{"calibrated": false}"#).unwrap()));
        assert!(calibrated(&parse(r#"{"calibrated": true}"#).unwrap()));
        // absent flag = armed: hand-written baselines must not silently
        // downgrade themselves to warnings
        assert!(calibrated(&parse(r#"{"venues": {}}"#).unwrap()));
    }

    #[test]
    fn tolerance_defaults_to_fifteen_percent() {
        // (env-var override is exercised by the benches themselves; the
        // default is the contract both gates share)
        if std::env::var("C3SL_BENCH_GATE_TOL").is_err() {
            assert!((gate_tolerance() - 0.15).abs() < 1e-12);
        }
    }
}
