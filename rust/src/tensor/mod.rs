//! Dense row-major f32 tensor substrate.
//!
//! Deliberately small: the heavy math runs inside AOT-compiled XLA
//! executables; this type exists for host-side plumbing (datasets, codecs,
//! oracles for tests, metrics) and for the rust-native C3 hot path.

use std::fmt;

/// Dense row-major f32 tensor: a shape vector plus a flat data buffer of
/// `shape.iter().product()` elements.  Shape/length agreement is an
/// invariant enforced at every constructor and reshape; accessors can
/// therefore index without bounds arithmetic surprises.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(len={})", self.shape, self.data.len())
    }
}

impl Tensor {
    // ---- construction ----------------------------------------------------
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Wrap an existing buffer; panics unless `data.len()` matches the
    /// shape's element count.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Rank-0 tensor holding one value (read back with [`Tensor::item`]).
    pub fn scalar(x: f32) -> Self {
        Tensor { shape: vec![], data: vec![x] }
    }

    /// Tensor of the given shape with every element set to `x`.
    pub fn filled(shape: &[usize], x: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![x; n] }
    }

    // ---- accessors --------------------------------------------------------
    /// The shape vector (empty for a scalar).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of axes (0 for a scalar).
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total element count (the flat buffer's length).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements (some axis is 0).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The flat row-major element buffer, read-only.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// The flat row-major element buffer, mutable (shape is fixed; only
    /// values may change).
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor and take its flat buffer (drops the shape).
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// The single element of a one-element tensor; panics otherwise.
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    // ---- shape ops ---------------------------------------------------------
    /// Reinterpret the buffer under a new shape with the same element
    /// count (no data movement); panics on a count mismatch.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Concatenate along axis 0 (all other dims must match).
    pub fn cat0(parts: &[&Tensor]) -> Self {
        assert!(!parts.is_empty());
        let tail = &parts[0].shape[1..];
        let mut rows = 0;
        for p in parts {
            assert_eq!(&p.shape[1..], tail);
            rows += p.shape[0];
        }
        let mut shape = vec![rows];
        shape.extend_from_slice(tail);
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }

    /// Slice rows [lo, hi) along axis 0.
    pub fn slice0(&self, lo: usize, hi: usize) -> Tensor {
        let stride: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor::from_vec(&shape, self.data[lo * stride..hi * stride].to_vec())
    }

    // ---- math (host-side oracles / codecs) ---------------------------------
    /// Element-wise sum; shapes must match exactly.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Element-wise difference `self − other`; shapes must match exactly.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Every element multiplied by the scalar `s`.
    pub fn scale(&self, s: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    /// Flat inner product Σ aᵢ·bᵢ over all elements; shapes must match.
    pub fn dot(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Euclidean (L2) norm over all elements.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|a| a * a).sum::<f32>().sqrt()
    }

    /// Largest element-wise absolute difference (L∞ distance); shapes must
    /// match.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ‖a−b‖/‖b‖ (0 if both zero).
    pub fn rel_err(&self, other: &Tensor) -> f32 {
        let d = self.sub(other).norm();
        let n = other.norm();
        if n == 0.0 {
            d
        } else {
            d / n
        }
    }

    /// argmax along the last axis of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        (0..self.shape[0])
            .map(|i| {
                let row = self.row(i);
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap()
            })
            .collect()
    }
}

/// Integer label vector (class targets).
#[derive(Clone, Debug, PartialEq)]
pub struct Labels(pub Vec<i32>);

impl Labels {
    /// Number of labels (the batch size it pairs with).
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// True when the label vector is empty.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = t.reshape(&[3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.row(1), &[3., 4.]);
    }

    #[test]
    #[should_panic]
    fn reshape_wrong_size_panics() {
        Tensor::zeros(&[2, 2]).reshape(&[3]);
    }

    #[test]
    fn cat_and_slice_roundtrip() {
        let a = Tensor::from_vec(&[1, 2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2, 2], vec![3., 4., 5., 6.]);
        let c = Tensor::cat0(&[&a, &b]);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.slice0(1, 3), b);
        assert_eq!(c.slice0(0, 1), a);
    }

    #[test]
    fn math_ops() {
        let a = Tensor::from_vec(&[2], vec![3., 4.]);
        let b = Tensor::from_vec(&[2], vec![1., 1.]);
        assert_eq!(a.add(&b).data(), &[4., 5.]);
        assert_eq!(a.sub(&b).data(), &[2., 3.]);
        assert_eq!(a.scale(2.0).data(), &[6., 8.]);
        assert_eq!(a.dot(&b), 7.0);
        assert!((a.norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn rel_err_zero_for_equal() {
        let a = Tensor::from_vec(&[3], vec![1., -2., 3.]);
        assert_eq!(a.rel_err(&a), 0.0);
    }

    #[test]
    fn argmax_rows_works() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }
}
