//! Deterministic PRNG substrate: xoshiro256++ with splitmix64 seeding.
//!
//! No `rand` crate in this environment, and the reproduction owns its
//! substrates anyway.  xoshiro256++ is the reference generator of Blackman &
//! Vigna; splitmix64 expands a 64-bit seed into the 256-bit state, which is
//! the initialization the authors recommend.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box–Muller pair.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a 64-bit value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next 64 uniformly random bits (the core xoshiro256++ step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 uniformly random bits (the generator's top half, which has
    /// the better equidistribution properties).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits → double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).  Lemire-style rejection-free for our needs.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal f32 with mean/std.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(mean, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Rng::new(11);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
