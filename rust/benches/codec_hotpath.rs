//! Bench: codec hot-path microbenchmarks — the perf-pass instrument.
//!
//!   cargo bench --bench codec_hotpath
//!
//! Sweeps the codec venues:
//!   host/direct       — paper-faithful O(D²) loops (seed allocating path)
//!   host/fft          — seed allocating convolution-theorem path (encode_ref:
//!                       3+ fresh Vec<C64> per group, reference-kernel FFT)
//!   host/fft-scratch  — the zero-allocation engine: caller-owned C3Scratch,
//!                       table-driven branchless FFT kernel (bit-identical to
//!                       host/fft — the property tests prove it)
//!   host/fft-parallel — the scratch engine fanned out group-parallel across
//!                       scoped worker threads
//!   artifact          — AOT Pallas kernels through PJRT (includes runtime
//!                       dispatch + literal marshalling), when artifacts exist
//! across D ∈ {512..4096} at B=32, and reports per-batch time + effective
//! throughput.  Results and the optimization log live in EXPERIMENTS.md §Perf.

use c3sl::hdc::{Backend, C3Scratch, KeySet, C3};
use c3sl::runtime::{CodecRuntime, Engine};
use c3sl::tensor::Tensor;
use c3sl::util::rng::Rng;
use c3sl::util::timer::{bench, fmt_secs, BenchStats};

fn row(venue: &str, d: usize, enc: &BenchStats, dec: &BenchStats, bytes: f64) {
    println!(
        "{:<18} {:>6} | {:>12} {:>12} | {:>14.1}",
        venue,
        d,
        fmt_secs(enc.mean_s),
        fmt_secs(dec.mean_s),
        bytes / (enc.mean_s + dec.mean_s) / 1e6,
    );
}

fn main() {
    let quick = std::env::var("C3SL_BENCH_QUICK").is_ok();
    let iters = if quick { 3 } else { 10 };
    let b = 32usize;
    let r = 4usize;
    let par_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(2, 8);
    println!(
        "# codec hot path: encode+decode per batch (B={b}, R={r}, {iters} iters, \
         parallel workers={par_workers})\n"
    );
    println!(
        "{:<18} {:>6} | {:>12} {:>12} | {:>14}",
        "venue", "D", "encode", "decode", "batch MB/s"
    );

    // (alloc_total_s, scratch_total_s, parallel_total_s) at D=2048 for the
    // acceptance summary printed at the end.
    let mut at2048 = (0.0f64, 0.0f64, 0.0f64);

    let mut rng = Rng::new(9);
    for d in [512usize, 1024, 2048, 4096] {
        let mut zdata = vec![0.0f32; b * d];
        rng.fill_normal(&mut zdata, 0.0, 1.0);
        let z = Tensor::from_vec(&[b, d], zdata);
        let bytes = (b * d * 4) as f64;
        let g = b / r;

        for backend in [Backend::Direct, Backend::Fft] {
            let keys = KeySet::generate(&mut rng, r, d);
            let c3 = C3::new(keys, backend);
            let it = if backend == Backend::Direct && d >= 2048 { 2 } else { iters };
            let enc = bench(1, it, || c3.encode_ref(&z));
            let s = c3.encode_ref(&z);
            let dec = bench(1, it, || c3.decode_ref(&s));
            let venue = format!("host/{backend:?}").to_lowercase();
            row(&venue, d, &enc, &dec, bytes);
            if backend == Backend::Fft && d == 2048 {
                at2048.0 = enc.mean_s + dec.mean_s;
            }
        }

        // scratch venue: zero allocations in steady state
        let keys = KeySet::generate(&mut rng, r, d);
        let c3 = C3::new(keys.clone(), Backend::Fft);
        let mut scratch = C3Scratch::new(d);
        let mut out_e = vec![0.0f32; g * d];
        let mut out_d = vec![0.0f32; b * d];
        let enc = bench(1, iters, || c3.encode_into(&z, &mut out_e, &mut scratch));
        let s = c3.encode(&z);
        let dec = bench(1, iters, || c3.decode_into(&s, &mut out_d, &mut scratch));
        row("host/fft-scratch", d, &enc, &dec, bytes);
        if d == 2048 {
            at2048.1 = enc.mean_s + dec.mean_s;
        }

        // parallel venue: groups fanned out across scoped worker threads
        let c3p = C3::with_workers(keys, Backend::Fft, par_workers);
        let enc = bench(1, iters, || c3p.par_encode_into(&z, &mut out_e, par_workers));
        let dec = bench(1, iters, || c3p.par_decode_into(&s, &mut out_d, par_workers));
        row("host/fft-parallel", d, &enc, &dec, bytes);
        if d == 2048 {
            at2048.2 = enc.mean_s + dec.mean_s;
        }
    }

    // Artifact venue at the tiny model's real geometry (D=1024, B=32, R=4).
    let dir = "artifacts/vggt_b32/codec_c3_r4";
    if std::path::Path::new(dir).join("manifest.json").exists() {
        match Engine::cpu() {
            Ok(engine) => {
                let mut codec = CodecRuntime::load(&engine, dir).expect("codec artifacts");
                codec.init_keys(1).expect("keys");
                let d = codec.d();
                let mut zdata = vec![0.0f32; b * d];
                rng.fill_normal(&mut zdata, 0.0, 1.0);
                let z = Tensor::from_vec(&[b, d], zdata);
                let enc = bench(1, iters, || codec.encode(&z).unwrap());
                let s = codec.encode(&z).unwrap();
                let dec = bench(1, iters, || codec.decode(&s).unwrap());
                row("artifact", d, &enc, &dec, (b * d * 4) as f64);
            }
            Err(e) => println!("(artifact venue skipped — {e})"),
        }
    } else {
        println!("(artifact venue skipped — run `make artifacts`)");
    }

    if at2048.1 > 0.0 {
        println!(
            "\nspeedup @D=2048: fft-scratch {:.2}x over allocating fft, \
             fft-parallel {:.2}x (x{par_workers} workers)",
            at2048.0 / at2048.1,
            at2048.0 / at2048.2,
        );
    }
    println!("\nreading: fft wins past D≈512; the scratch engine removes every per-group");
    println!("allocation AND swaps in the table-driven branchless FFT kernel (bit-identical");
    println!("outputs — see the to_bits property tests in hdc).  The artifact venue pays");
    println!("PJRT dispatch + interpret-mode Pallas gather cost — acceptable off the edge");
    println!("hot path, hence the coordinator defaults the HOST venue for gradient decode.");
}
