//! Transport substrate: the edge↔cloud channel of split learning.
//!
//! Every message is serialized to a length-prefixed wire frame even for the
//! in-process transport, so byte accounting (the paper's communication-cost
//! metric) measures real serialized bytes, not struct sizes.  A `SimLink`
//! wrapper adds a virtual bandwidth/latency cost model for the
//! communication-efficiency benches.
//!
//! Two serving styles share the same wire format: the blocking [`Transport`]
//! endpoints (`InProc`, `tcp::Tcp`) used by the edges and the thread-per-
//! client cloud, and the nonblocking [`reactor`] connections that let one
//! thread multiplex thousands of edges (event-driven via the [`readiness`]
//! epoll backend on Linux, or the portable poll sweep elsewhere).  Both
//! funnel every peer-announced length prefix through [`check_frame_len`]
//! before allocating.

pub mod faulty;
pub mod reactor;
pub mod readiness;
pub mod seq;
pub mod sim;
pub mod tcp;
pub mod wire;

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use crate::tensor::{Labels, Tensor};
use crate::util::error::C3Error;
use wire::WireError;

/// Protocol messages between edge and cloud.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Uplink: (possibly compressed) cut-layer features for step `step`.
    Features {
        /// Training step this uplink belongs to.
        step: u64,
        /// The (possibly compressed) feature batch.
        tensor: Tensor,
    },
    /// Uplink: labels for step `step` (paper: labels travel with features).
    TrainLabels {
        /// Training step these labels belong to.
        step: u64,
        /// The batch labels.
        labels: Labels,
    },
    /// Downlink: (possibly compressed) cut-layer gradients.
    Gradients {
        /// Training step these gradients answer.
        step: u64,
        /// The (possibly compressed) gradient batch.
        tensor: Tensor,
    },
    /// Downlink: per-step metrics from the cloud (loss, ncorrect).
    StepStats {
        /// Training step the stats describe.
        step: u64,
        /// Loss at this step.
        loss: f32,
        /// Correct predictions in the batch.
        ncorrect: f32,
    },
    /// Uplink: request evaluation on features (no gradient round trip).
    EvalFeatures {
        /// Evaluation step index.
        step: u64,
        /// The (possibly compressed) feature batch.
        tensor: Tensor,
        /// Ground-truth labels for the batch.
        labels: Labels,
    },
    /// Downlink: evaluation result.
    EvalStats {
        /// Evaluation step index.
        step: u64,
        /// Evaluation loss.
        loss: f32,
        /// Correct predictions in the batch.
        ncorrect: f32,
    },
    /// Leader → both: key seed for C3 key generation (keys are never sent!).
    KeySeed {
        /// The codec-construction seed both endpoints derive keys from.
        seed: u64,
    },
    /// Edge → cloud, first message on a sharded connection: request a
    /// challenge.  The edge speaks first in every mode (like
    /// [`Msg::KeySeed`]), so a mis-paired deployment — a sharded edge
    /// against a non-sharded cloud or vice versa — fails loudly at the
    /// first message instead of deadlocking with both sides in `recv`.
    ShardHello,
    /// Cloud → edge, answering [`Msg::ShardHello`]: a fresh challenge
    /// nonce the edge's `Msg::KeyShard` possession proof must bind.
    /// Freshness is what makes proofs single-use — a recorded proof
    /// answers exactly one challenge, so replaying it in a later serving
    /// session that reuses the same master no longer squats the shard id.
    ShardChallenge {
        /// The fresh challenge value; never reused across connections.
        nonce: u64,
    },
    /// Edge → cloud, completing the sharded handshake (after receiving
    /// [`Msg::ShardChallenge`]): claim the per-client key shard
    /// `client_id` at `epoch`, announcing a one-way possession proof
    /// (`hdc::keyring` — a PRF keyed by the shard's secret sub-seed over
    /// the public claim and the challenge nonce) that the cloud re-derives
    /// and compares.  Unlike [`Msg::KeySeed`], not even a seed crosses the
    /// wire: an observer of this frame can regenerate no key material.
    KeyShard {
        /// The shard (client) id being claimed.
        client_id: u64,
        /// The key epoch the edge starts at (must match the cloud's).
        epoch: u64,
        /// `KeyRing::shard_proof(client_id, epoch, nonce)` — verified
        /// against this connection's challenge, never trusted.
        proof: u64,
    },
    /// Orderly shutdown.
    Shutdown,
    /// Envelope carrying any data-plane message with a per-session monotonic
    /// sequence number.  Once a peer has seen one sequenced frame on a
    /// connection, every later data frame must arrive sequenced and in
    /// order — gaps, duplicates, and reorderings are then protocol errors
    /// detected loudly at the transport layer instead of silently
    /// mis-decoding downstream (see `transport::seq`).  Envelopes never
    /// nest: a `Sequenced` inside a `Sequenced` is a wire error.
    Sequenced {
        /// Position of this frame in the sender's per-session stream,
        /// starting at 0 and incrementing by exactly 1 per data frame.
        seq: u64,
        /// The enveloped data-plane message.
        inner: Box<Msg>,
    },
    /// Edge → cloud, replacing [`Msg::KeyShard`] when reconnecting to a
    /// session that already made progress: claim the shard *and* agree on
    /// the exact resume point.  Travels through the same challenge/nonce
    /// leg as a fresh claim (the proof binds this connection's
    /// [`Msg::ShardChallenge`] nonce), so a recorded resume is as
    /// unreplayable as a recorded claim.  The cloud validates
    /// `last_acked_step` against its `ShardGate` watermark and answers
    /// [`Msg::ResumeOk`] with the step training continues from.
    Resume {
        /// The shard (client) id being re-claimed.
        client_id: u64,
        /// The key epoch of the step the session resumes at.
        epoch: u64,
        /// Highest step whose `StepStats` the edge received before the
        /// connection died; the in-flight step (if any) is re-executed.
        last_acked_step: u64,
        /// `KeyRing::shard_proof(client_id, epoch, nonce)` over this
        /// connection's fresh challenge nonce.
        proof: u64,
    },
    /// Cloud → edge, answering an accepted [`Msg::Resume`]: the session
    /// continues at `resume_step` with fresh sequence counters.
    ResumeOk {
        /// First step of the resumed session (`last_acked_step + 1`).
        resume_step: u64,
    },
}

/// Byte counters shared between the two endpoints of a link.
#[derive(Debug, Default)]
pub struct LinkStats {
    /// Serialized bytes this endpoint sent (frames, incl. any TCP prefix).
    pub tx_bytes: AtomicU64,
    /// Serialized bytes this endpoint received.
    pub rx_bytes: AtomicU64,
    /// Messages this endpoint sent.
    pub tx_msgs: AtomicU64,
    /// Messages this endpoint received.
    pub rx_msgs: AtomicU64,
}

impl LinkStats {
    /// Total bytes sent by this endpoint.
    pub fn tx(&self) -> u64 {
        self.tx_bytes.load(Ordering::Relaxed)
    }

    /// Total bytes received by this endpoint.
    pub fn rx(&self) -> u64 {
        self.rx_bytes.load(Ordering::Relaxed)
    }
}

/// Anything that can go wrong on a transport endpoint.
#[derive(Debug)]
pub enum TransportError {
    /// The peer sent a frame that does not decode to a [`Msg`].
    Wire(WireError),
    /// The peer hung up (channel disconnected / socket closed).
    Closed,
    /// An OS-level socket failure.
    Io(std::io::Error),
    /// A peer announced a frame larger than [`wire::MAX_FRAME_BYTES`];
    /// rejected before any allocation happens.
    FrameTooLarge(usize),
    /// A peer announced a zero-length frame.  Every valid wire frame carries
    /// at least its 1-byte tag, so an empty frame is a protocol violation and
    /// is rejected at the transport layer rather than surfacing later as a
    /// confusing truncation error from the decoder.
    EmptyFrame,
    /// A read or write deadline elapsed before the peer made progress
    /// (see [`Transport::set_deadline`]).  Distinct from
    /// [`TransportError::Closed`]: the link may still be alive, merely
    /// stalled past the caller's patience.
    TimedOut,
}

/// Validate a peer-announced frame length *before* any allocation: rejects
/// zero-length frames (no valid [`Msg`] encodes to zero bytes — see
/// [`TransportError::EmptyFrame`]) and frames above [`wire::MAX_FRAME_BYTES`]
/// (see [`TransportError::FrameTooLarge`]).  Every transport — blocking
/// [`tcp::Tcp`] and the nonblocking reactor connections alike — runs its
/// length prefixes through this single gate.
pub fn check_frame_len(len: usize) -> Result<(), TransportError> {
    if len == 0 {
        return Err(TransportError::EmptyFrame);
    }
    if len > wire::MAX_FRAME_BYTES {
        return Err(TransportError::FrameTooLarge(len));
    }
    Ok(())
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Wire(e) => write!(f, "wire: {e}"),
            TransportError::Closed => write!(f, "channel closed"),
            TransportError::Io(e) => write!(f, "io: {e}"),
            TransportError::FrameTooLarge(n) => write!(
                f,
                "frame of {n} bytes exceeds MAX_FRAME_BYTES ({})",
                wire::MAX_FRAME_BYTES
            ),
            TransportError::EmptyFrame => {
                write!(f, "zero-length frame (every message carries at least its tag byte)")
            }
            TransportError::TimedOut => write!(f, "link deadline elapsed"),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Wire(e) => Some(e),
            TransportError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> Self {
        TransportError::Wire(e)
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        // A socket read/write timeout surfaces as WouldBlock (EAGAIN) or
        // TimedOut depending on platform; both mean "deadline elapsed",
        // not "link broken" — fold them into the dedicated variant so
        // callers can tell a stall from a hangup.
        match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                TransportError::TimedOut
            }
            _ => TransportError::Io(e),
        }
    }
}

impl From<TransportError> for C3Error {
    fn from(e: TransportError) -> Self {
        C3Error::msg(format!("transport: {e}"))
    }
}

/// A bidirectional message endpoint with byte accounting.
pub trait Transport: Send {
    /// Serialize and transmit one message (blocking until handed off).
    fn send(&mut self, msg: &Msg) -> Result<(), TransportError>;
    /// Block until the next message arrives and decode it.
    fn recv(&mut self) -> Result<Msg, TransportError>;
    /// Shared byte counters for this endpoint's half of the link.
    fn stats(&self) -> Arc<LinkStats>;
    /// Bound how long `recv` and `send` may block (`None` = forever); a
    /// breached deadline surfaces as [`TransportError::TimedOut`].  Returns
    /// `false` when the endpoint cannot enforce deadlines (the in-process
    /// channel, for one) so callers know the bound is advisory there.
    fn set_deadline(
        &mut self,
        read: Option<std::time::Duration>,
        write: Option<std::time::Duration>,
    ) -> bool {
        let _ = (read, write);
        false
    }
}

impl<T: Transport + ?Sized> Transport for Box<T> {
    fn send(&mut self, msg: &Msg) -> Result<(), TransportError> {
        (**self).send(msg)
    }

    fn recv(&mut self) -> Result<Msg, TransportError> {
        (**self).recv()
    }

    fn stats(&self) -> Arc<LinkStats> {
        (**self).stats()
    }

    fn set_deadline(
        &mut self,
        read: Option<std::time::Duration>,
        write: Option<std::time::Duration>,
    ) -> bool {
        (**self).set_deadline(read, write)
    }
}

// ---------------------------------------------------------------------------
// In-process transport: mpsc channels carrying serialized frames.
// ---------------------------------------------------------------------------

/// Blocking in-process endpoint: mpsc channels carrying serialized frames,
/// so byte accounting measures real serialized traffic even without sockets.
/// When paired with a reactor connection ([`inproc_reactor_pair`]) it also
/// rings the shared eventfd doorbell after every send — and once on drop —
/// so an epoll-driven peer observes frames (and the final hangup) without a
/// timed sweep.
pub struct InProc {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    stats: Arc<LinkStats>,
    /// Doorbell to the reactor peer; unarmed (a no-op) for plain pairs.
    notify: readiness::WakeHandle,
}

/// Create a connected pair of in-process endpoints.  Each endpoint has its
/// own counters: endpoint A's `tx` is what A sent (e.g. the edge's uplink),
/// its `rx` what it received (the downlink).
pub fn inproc_pair() -> (InProc, InProc) {
    let (txa, rxb) = mpsc::channel();
    let (txb, rxa) = mpsc::channel();
    (
        InProc {
            tx: txa,
            rx: rxa,
            stats: Arc::new(LinkStats::default()),
            notify: readiness::WakeHandle::none(),
        },
        InProc {
            tx: txb,
            rx: rxb,
            stats: Arc::new(LinkStats::default()),
            notify: readiness::WakeHandle::none(),
        },
    )
}

/// Create a mixed in-process pair: a blocking [`InProc`] endpoint for the
/// edge and a nonblocking [`reactor::NbInProc`] endpoint for a reactor-driven
/// cloud.  Used by the in-proc venue of the reactor multi-edge scenario.
/// The two halves share an eventfd doorbell (Linux) so the cloud side is
/// epoll-pollable; elsewhere the doorbell is unarmed and the reactor's
/// sweep backend covers the pair.
pub fn inproc_reactor_pair() -> (InProc, reactor::NbInProc) {
    // arm exactly when this platform's default reactor backend can wait on
    // the fd; on sweep-only platforms the bell would be pure overhead
    inproc_reactor_pair_with(
        readiness::ReadinessBackend::platform_default() == readiness::ReadinessBackend::Epoll,
    )
}

/// [`inproc_reactor_pair`] with an explicit doorbell choice: pass `false`
/// when the serving reactor will run the sweep backend anyway — an unarmed
/// pair costs no file descriptor and no per-send `write(2)`, which matters
/// at thousand-edge in-proc fan-in (N doorbells otherwise brush the common
/// 1024 soft fd limit for nothing).
pub fn inproc_reactor_pair_with(doorbell: bool) -> (InProc, reactor::NbInProc) {
    let (txa, rxb) = mpsc::channel();
    let (txb, rxa) = mpsc::channel();
    let bell = if doorbell {
        readiness::WakeHandle::armed()
    } else {
        readiness::WakeHandle::none()
    };
    (
        InProc {
            tx: txa,
            rx: rxa,
            stats: Arc::new(LinkStats::default()),
            notify: bell.clone(),
        },
        reactor::NbInProc::new(txb, rxb, bell),
    )
}

impl Drop for InProc {
    fn drop(&mut self) {
        // Disconnect FIRST, then ring.  The hangup signal the reactor peer
        // actually observes is the channel disconnect; ringing before the
        // Sender is gone would race the peer's clear-then-recheck (it could
        // consume the ring, find the sender still alive, clear the bell and
        // park — and the disconnect itself never re-rings), leaving an
        // epoll pump that services only OS-reported-ready tokens blind to
        // the hangup forever.  Swapping in a dummy Sender drops the real
        // one here and now; the wake that follows is then guaranteed to
        // happen-after the disconnect is observable.
        let (dummy, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dummy));
        self.notify.wake();
    }
}

impl Transport for InProc {
    fn send(&mut self, msg: &Msg) -> Result<(), TransportError> {
        let frame = wire::encode(msg);
        self.stats.tx_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.stats.tx_msgs.fetch_add(1, Ordering::Relaxed);
        self.tx.send(frame).map_err(|_| TransportError::Closed)?;
        // ring AFTER the frame is visible in the channel: the receiver's
        // clear-then-recheck discipline then guarantees no lost frame
        self.notify.wake();
        Ok(())
    }

    fn recv(&mut self) -> Result<Msg, TransportError> {
        let frame = self.rx.recv().map_err(|_| TransportError::Closed)?;
        self.stats.rx_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.stats.rx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(wire::decode(&frame)?)
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|i| i as f32 * 0.5).collect())
    }

    #[test]
    fn inproc_roundtrip_all_variants() {
        let (mut a, mut b) = inproc_pair();
        let msgs = vec![
            Msg::Features { step: 3, tensor: t(&[2, 4]) },
            Msg::TrainLabels { step: 3, labels: Labels(vec![1, -2, 7]) },
            Msg::Gradients { step: 4, tensor: t(&[8]) },
            Msg::StepStats { step: 4, loss: 1.25, ncorrect: 17.0 },
            Msg::EvalFeatures { step: 5, tensor: t(&[1, 2]), labels: Labels(vec![0]) },
            Msg::EvalStats { step: 5, loss: 0.5, ncorrect: 1.0 },
            Msg::KeySeed { seed: 0xDEAD_BEEF },
            Msg::ShardHello,
            Msg::ShardChallenge { nonce: 0xFEED_5EED },
            Msg::KeyShard { client_id: 4, epoch: 1, proof: 0xC0DE },
            Msg::Shutdown,
        ];
        for m in &msgs {
            a.send(m).unwrap();
        }
        for m in &msgs {
            assert_eq!(&b.recv().unwrap(), m);
        }
    }

    #[test]
    fn stats_count_serialized_bytes_per_endpoint() {
        let (mut a, mut b) = inproc_pair();
        let msg = Msg::Features { step: 0, tensor: t(&[4, 16]) };
        a.send(&msg).unwrap();
        b.recv().unwrap();
        // 4*16 f32 = 256 data bytes + header; a sent, b received
        assert!(a.stats().tx() >= 256);
        assert_eq!(a.stats().rx(), 0);
        assert_eq!(b.stats().rx(), a.stats().tx());
        assert_eq!(b.stats().tx(), 0);
        assert_eq!(a.stats().tx_msgs.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn closed_channel_errors() {
        let (mut a, b) = inproc_pair();
        drop(b);
        assert!(matches!(
            a.send(&Msg::Shutdown),
            Err(TransportError::Closed)
        ));
    }

    #[test]
    fn frame_len_gate_boundaries() {
        // 0 is a protocol violation, 1 is the smallest real frame (Shutdown),
        // MAX_FRAME_BYTES is the largest admissible prefix, +1 is rejected —
        // all judged WITHOUT allocating the announced length.
        assert!(matches!(check_frame_len(0), Err(TransportError::EmptyFrame)));
        assert!(check_frame_len(1).is_ok());
        assert!(check_frame_len(wire::MAX_FRAME_BYTES).is_ok());
        assert!(matches!(
            check_frame_len(wire::MAX_FRAME_BYTES + 1),
            Err(TransportError::FrameTooLarge(n)) if n == wire::MAX_FRAME_BYTES + 1
        ));
        // and the smallest real frame is exactly 1 byte, so the gate admits
        // every frame encode can produce
        assert_eq!(wire::encode(&Msg::Shutdown).len(), 1);
    }

    #[test]
    fn bidirectional() {
        let (mut a, mut b) = inproc_pair();
        a.send(&Msg::KeySeed { seed: 1 }).unwrap();
        b.send(&Msg::KeySeed { seed: 2 }).unwrap();
        assert_eq!(b.recv().unwrap(), Msg::KeySeed { seed: 1 });
        assert_eq!(a.recv().unwrap(), Msg::KeySeed { seed: 2 });
    }
}
