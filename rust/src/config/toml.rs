//! TOML-subset parser (from scratch — no serde/toml crates).
//!
//! Supported grammar: `[section]` headers, `key = value` with string,
//! integer, float, boolean and homogeneous-array values, `#` comments.
//! That covers every experiment config in configs/.

use std::collections::BTreeMap;

/// One parsed TOML value.  Accessors return `None` on a type mismatch so
/// callers can surface "wrong type" errors with their own context.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A double-quoted string (with `\"` and `\\` escapes).
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal (integers do NOT parse as floats; see
    /// [`Value::as_f64`] for the one-way coercion).
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A bracketed array (possibly nested).
    Arr(Vec<Value>),
}

impl Value {
    /// The string payload, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer payload, if this is a [`Value::Int`].
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The numeric payload as a float: floats as-is, integers coerced
    /// (`lr = 1` and `lr = 1.0` both read as 1.0).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean payload, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The array items, if this is a [`Value::Arr`].
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// section → key → value.  Root-level keys live under the "" section.
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// A parse failure, pinned to its 1-based source line.
#[derive(Debug)]
pub struct TomlError {
    /// 1-based line number the error was detected on.
    pub line: usize,
    /// What went wrong there.
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a complete document of the supported TOML subset into a [`Doc`].
/// Later duplicate keys overwrite earlier ones (last-wins), matching how
/// the config loader layers overrides.
pub fn parse(input: &str) -> Result<Doc, TomlError> {
    let mut doc: Doc = BTreeMap::new();
    doc.insert(String::new(), BTreeMap::new());
    let mut section = String::new();

    for (ln, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };

        if let Some(name) = line.strip_prefix('[') {
            let name = name.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }

        let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but fine: we don't allow '#' inside strings in our configs
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '[' => depth += 1,
            ']' => depth -= 1,
            ',' if depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = parse(
            r#"
            # experiment
            name = "tiny"
            [train]
            steps = 200
            lr = 0.0001
            augment = true
            ratios = [2, 4, 8, 16]
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"].as_str(), Some("tiny"));
        assert_eq!(doc["train"]["steps"].as_i64(), Some(200));
        assert_eq!(doc["train"]["lr"].as_f64(), Some(1e-4));
        assert_eq!(doc["train"]["augment"].as_bool(), Some(true));
        let rs: Vec<i64> = doc["train"]["ratios"]
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(rs, vec![2, 4, 8, 16]);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = parse("# hi\n\nx = 1 # trailing\n").unwrap();
        assert_eq!(doc[""]["x"].as_i64(), Some(1));
    }

    #[test]
    fn string_with_hash_kept() {
        let doc = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc[""]["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = parse("i = 3\nf = 3.5\n").unwrap();
        assert_eq!(doc[""]["i"].as_i64(), Some(3));
        assert_eq!(doc[""]["i"].as_f64(), Some(3.0)); // ints coerce to f64
        assert_eq!(doc[""]["f"].as_i64(), None);
        assert_eq!(doc[""]["f"].as_f64(), Some(3.5));
    }

    #[test]
    fn empty_array() {
        let doc = parse("a = []\n").unwrap();
        assert_eq!(doc[""]["a"].as_arr().unwrap().len(), 0);
    }
}
