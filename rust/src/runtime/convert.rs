//! Tensor / Literal conversions.

use crate::runtime::xla_stub as xla;
use crate::tensor::{Labels, Tensor};
use crate::util::error::{C3Error, Result};

/// f32 Tensor → XLA literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    // SAFETY: the view reinterprets the tensor's f32 storage as bytes —
    // same allocation, `len * 4` bytes, u8 has no alignment requirement,
    // and the borrow of `t` keeps the storage alive for the view's use.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        t.shape(),
        bytes,
    )?)
}

/// XLA literal → f32 Tensor (copies out).
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Labels → i32 literal of shape (B,).
pub fn labels_to_literal(l: &Labels) -> Result<xla::Literal> {
    // SAFETY: reinterprets the label i32 storage as bytes — same
    // allocation, `len * 4` bytes, u8 is alignment-free, and the borrow
    // of `l` keeps the storage alive for the view's use.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(l.0.as_ptr() as *const u8, l.0.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[l.0.len()],
        bytes,
    )?)
}

/// 64-bit seed → u32[2] literal (jax PRNG key data).
pub fn seed_literal(seed: u64) -> Result<xla::Literal> {
    let words = [(seed >> 32) as u32, seed as u32];
    // SAFETY: `words` is a live [u32; 2] on this stack frame — exactly 8
    // bytes, u8 is alignment-free, and the view ends before `words` does
    // (the literal constructor copies out of it).
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(words.as_ptr() as *const u8, 8) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32,
        &[2],
        bytes,
    )?)
}

/// f32 scalar literal (rank 0).
pub fn scalar_literal(x: f32) -> Result<xla::Literal> {
    let bytes = x.to_le_bytes();
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[],
        &bytes,
    )?)
}

/// Scalar f32 out of a literal (rank 0 or single element).
pub fn literal_scalar(l: &xla::Literal) -> Result<f32> {
    let v = l.to_vec::<f32>()?;
    v.first().copied().ok_or_else(|| C3Error::msg("empty literal"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn labels_literal_has_right_type() {
        let l = labels_to_literal(&Labels(vec![1, 2, 3])).unwrap();
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn seed_packs_hi_lo() {
        let l = seed_literal(0x0123_4567_89AB_CDEF).unwrap();
        assert_eq!(l.to_vec::<u32>().unwrap(), vec![0x0123_4567, 0x89AB_CDEF]);
    }

    #[test]
    fn scalar_roundtrip() {
        let l = scalar_literal(2.5).unwrap();
        assert_eq!(literal_scalar(&l).unwrap(), 2.5);
    }
}
