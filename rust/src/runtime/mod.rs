//! Runtime: loads the AOT HLO artifacts through the PJRT C API (the `xla`
//! crate) and exposes typed call wrappers for the coordinator.
//!
//! Python is only ever involved at build time (`make artifacts`); everything
//! here is pure rust + the XLA CPU plugin.  See /opt/xla-example/load_hlo for
//! the interchange pattern (HLO *text*, not serialized protos — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects).
//!
//! Offline builds: the real `xla` crate cannot be fetched here, so the
//! modules below compile against [`xla_stub`] (host literals work for real;
//! device paths error).  The artifact-venue integration tests skip when
//! artifacts/PJRT are unavailable.

pub mod codec;
pub mod convert;
pub mod engine;
pub mod manifest;
pub mod model;
pub mod xla_stub;

pub use codec::CodecRuntime;
pub use engine::{Engine, Executable};
pub use manifest::{ArtifactSpec, CodecManifest, ModelManifest, TensorSpec};
pub use model::{AdamState, ModelRuntime, StepOutput};
