# Concrete layers: conv / deconv / dense / norms / pools / activations.
#
# Data layout is NCHW throughout (matches the paper's (C, W, H) notation and
# the rust tensor module's row-major layout).  Shapes passed to init exclude
# the batch dimension: in_shape = (C, H, W) or (D,).

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .core import Layer, Lambda

_DIMNUMS = ("NCHW", "OIHW", "NCHW")


def _kaiming(rng, shape, fan_in):
    """He-normal init, the standard choice for ReLU conv stacks."""
    std = math.sqrt(2.0 / fan_in)
    return jax.random.normal(rng, shape, dtype=jnp.float32) * std


def Conv2d(c_in: int, c_out: int, k: int = 3, stride: int = 1,
           padding: str = "SAME", bias: bool = True) -> Layer:
    """2D convolution, NCHW, square kernel."""

    def init(rng, in_shape):
        c, h, w = in_shape
        assert c == c_in, (c, c_in)
        wkey, _ = jax.random.split(rng)
        weight = _kaiming(wkey, (c_out, c_in, k, k), fan_in=c_in * k * k)
        params = [weight] + ([jnp.zeros((c_out,))] if bias else [])
        if padding == "SAME":
            ho, wo = -(-h // stride), -(-w // stride)
        else:  # VALID
            ho, wo = (h - k) // stride + 1, (w - k) // stride + 1
        return params, (c_out, ho, wo)

    def apply(params, x):
        y = jax.lax.conv_general_dilated(
            x, params[0], window_strides=(stride, stride), padding=padding,
            dimension_numbers=_DIMNUMS)
        if bias:
            y = y + params[1][None, :, None, None]
        return y

    return Layer(f"conv{k}x{k}/{c_in}->{c_out}/s{stride}", init, apply)


def Deconv2d(c_in: int, c_out: int, k: int = 2, stride: int = 2,
             bias: bool = True) -> Layer:
    """Transposed convolution (BottleNet++ decoder restores W,H with stride)."""

    def init(rng, in_shape):
        c, h, w = in_shape
        assert c == c_in, (c, c_in)
        wkey, _ = jax.random.split(rng)
        # With transpose_kernel=True, lax.conv_transpose takes the kernel in
        # the FORWARD conv's layout: (O=c_in, I=c_out, H, W) under "OIHW" —
        # it swaps the feature axes internally.
        weight = _kaiming(wkey, (c_in, c_out, k, k), fan_in=c_in * k * k)
        params = [weight] + ([jnp.zeros((c_out,))] if bias else [])
        return params, (c_out, h * stride, w * stride)

    def apply(params, x):
        y = jax.lax.conv_transpose(
            x, params[0], strides=(stride, stride), padding="SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"), transpose_kernel=True)
        if bias:
            y = y + params[1][None, :, None, None]
        return y

    return Layer(f"deconv{k}x{k}/{c_in}->{c_out}/s{stride}", init, apply)


def Dense(d_in: int, d_out: int, bias: bool = True) -> Layer:
    def init(rng, in_shape):
        assert in_shape == (d_in,), (in_shape, d_in)
        wkey, _ = jax.random.split(rng)
        weight = _kaiming(wkey, (d_in, d_out), fan_in=d_in)
        params = [weight] + ([jnp.zeros((d_out,))] if bias else [])
        return params, (d_out,)

    def apply(params, x):
        y = x @ params[0]
        if bias:
            y = y + params[1]
        return y

    return Layer(f"dense/{d_in}->{d_out}", init, apply)


def ReLU() -> Layer:
    return Lambda("relu", jax.nn.relu)


def Sigmoid() -> Layer:
    return Lambda("sigmoid", jax.nn.sigmoid)


def MaxPool2d(k: int = 2, stride: int = 2) -> Layer:
    def shape_fn(s):
        c, h, w = s
        return (c, h // stride, w // stride)

    def fn(x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            window_dimensions=(1, 1, k, k), window_strides=(1, 1, stride, stride),
            padding="VALID")

    return Lambda(f"maxpool{k}", fn, shape_fn)


def GlobalAvgPool() -> Layer:
    return Lambda("gap", lambda x: x.mean(axis=(2, 3)), lambda s: (s[0],))


def Flatten() -> Layer:
    def shape_fn(s):
        n = 1
        for d in s:
            n *= d
        return (n,)

    return Lambda("flatten", lambda x: x.reshape(x.shape[0], -1), shape_fn)


def GroupNorm(c: int, groups: int = 8, eps: float = 1e-5) -> Layer:
    """GroupNorm: state-free normalization (deterministic at eval, batch-size
    independent).  Stands in for the paper's BatchNorm — see DESIGN.md §3;
    the compression claims are norm-agnostic."""
    g = math.gcd(groups, c)

    def init(rng, in_shape):
        return [jnp.ones((c,)), jnp.zeros((c,))], in_shape

    def apply(params, x):
        n, cc, h, w = x.shape
        xg = x.reshape(n, g, cc // g, h, w)
        mean = xg.mean(axis=(2, 3, 4), keepdims=True)
        var = xg.var(axis=(2, 3, 4), keepdims=True)
        xg = (xg - mean) * jax.lax.rsqrt(var + eps)
        x = xg.reshape(n, cc, h, w)
        return x * params[0][None, :, None, None] + params[1][None, :, None, None]

    return Layer(f"groupnorm/{c}g{g}", init, apply)


def BatchNormStatic(c: int, eps: float = 1e-5) -> Layer:
    """BatchNorm using current-batch statistics in both train and eval.

    Keeps artifact signatures state-free (no running stats threaded through
    the AOT boundary).  Used by the BottleNet++ codec blocks, matching the
    paper's encoder/decoder structure (conv + BN + act)."""

    def init(rng, in_shape):
        return [jnp.ones((c,)), jnp.zeros((c,))], in_shape

    def apply(params, x):
        mean = x.mean(axis=(0, 2, 3), keepdims=True)
        var = x.var(axis=(0, 2, 3), keepdims=True)
        xn = (x - mean) * jax.lax.rsqrt(var + eps)
        return xn * params[0][None, :, None, None] + params[1][None, :, None, None]

    return Layer(f"batchnorm/{c}", init, apply)
