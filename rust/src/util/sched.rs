//! Deterministic-interleaving scheduler for concurrency tests.
//!
//! The loom-style idea, std-only: instead of hammering a real race with
//! threads and hoping the scheduler finds the bad ordering, enumerate every
//! ordering and *replay* each one sequentially.  The protocols this repo
//! cares about (the eventfd waker's ring/clear/drain discipline, the
//! `ShardGate` claim/release/burn transitions) are built from operations
//! that are individually atomic — one syscall on a kernel counter, one
//! mutation under the gate's single lock — so any concurrent execution is
//! equivalent to SOME sequential interleaving of the per-thread operation
//! sequences.  Checking an invariant over all interleavings therefore
//! checks it over all real schedules, deterministically and exhaustively.
//!
//! [`interleavings`] enumerates the schedules: every merge of N per-thread
//! operation sequences that preserves each thread's program order — the
//! multinomial `(Σlenᵢ)! / Πlenᵢ!` of them.  A schedule is a vector of
//! thread indices; thread `t` appears exactly `lens[t]` times, and its
//! k-th appearance means "thread t executes its k-th operation now".
//! `rust/tests/interleave.rs` replays these against the real waker and
//! gate primitives and pins the races that review caught in the epoll PR.

/// Every interleaving of `lens.len()` threads where thread `t` contributes
/// `lens[t]` program-ordered operations.  Schedules come out in a stable
/// lexicographic order (thread 0 first), so failures reproduce exactly.
///
/// The count grows multinomially — [`interleaving_count`] — so keep the
/// per-thread op counts small (two threads of 4 ops each is 70 schedules;
/// three threads of 3 ops each is 1680).
pub fn interleavings(lens: &[usize]) -> Vec<Vec<usize>> {
    let total: usize = lens.iter().sum();
    let mut out = Vec::new();
    let mut schedule = Vec::with_capacity(total);
    let mut progress = vec![0usize; lens.len()];
    fill(lens, total, &mut progress, &mut schedule, &mut out);
    out
}

fn fill(
    lens: &[usize],
    total: usize,
    progress: &mut Vec<usize>,
    schedule: &mut Vec<usize>,
    out: &mut Vec<Vec<usize>>,
) {
    if schedule.len() == total {
        out.push(schedule.clone());
        return;
    }
    for t in 0..lens.len() {
        if progress[t] < lens[t] {
            progress[t] += 1;
            schedule.push(t);
            fill(lens, total, progress, schedule, out);
            schedule.pop();
            progress[t] -= 1;
        }
    }
}

/// The number of interleavings [`interleavings`] will produce: the
/// multinomial coefficient `(Σlenᵢ)! / Πlenᵢ!`, computed overflow-safely
/// by interleaving multiplications and divisions.
pub fn interleaving_count(lens: &[usize]) -> usize {
    let mut count: u128 = 1;
    let mut placed: u128 = 0;
    for &len in lens {
        // choose(placed + len, len), folded in incrementally
        for k in 1..=(len as u128) {
            placed += 1;
            count = count * placed / k;
        }
    }
    count as usize
}

/// Run `f` once per interleaving with the schedule as its argument —
/// the replay driver most harness tests want.  Equivalent to iterating
/// [`interleavings`] but without materializing all schedules when the
/// closure is the only consumer.
pub fn for_each_interleaving(lens: &[usize], mut f: impl FnMut(&[usize])) {
    for schedule in interleavings(lens) {
        f(&schedule);
    }
}

/// All `n!` orderings of `n` distinct single-operation actors — the
/// degenerate interleaving where every thread runs exactly one op.
pub fn permutations(n: usize) -> Vec<Vec<usize>> {
    interleavings(&vec![1; n])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_by_two_merge_is_exhaustive_and_ordered() {
        let all = interleavings(&[2, 2]);
        assert_eq!(all.len(), 6, "C(4,2) merges of two 2-op threads");
        assert_eq!(all.len(), interleaving_count(&[2, 2]));
        // every schedule uses each thread exactly lens[t] times…
        for s in &all {
            assert_eq!(s.iter().filter(|&&t| t == 0).count(), 2);
            assert_eq!(s.iter().filter(|&&t| t == 1).count(), 2);
        }
        // …and no schedule repeats
        let mut uniq = all.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), all.len());
        // lexicographic stability: the all-of-0-first schedule leads
        assert_eq!(all[0], vec![0, 0, 1, 1]);
    }

    #[test]
    fn counts_match_enumeration() {
        for lens in [vec![1usize], vec![3], vec![1, 1, 1], vec![2, 3], vec![2, 2, 2]] {
            assert_eq!(
                interleavings(&lens).len(),
                interleaving_count(&lens),
                "count mismatch for {lens:?}"
            );
        }
        assert_eq!(interleaving_count(&[4, 4]), 70);
        assert_eq!(interleaving_count(&[]), 1, "no threads, one empty schedule");
    }

    #[test]
    fn program_order_is_preserved() {
        // replay each schedule and record the per-thread op sequence seen:
        // it must always be 0,1,2,… in order
        for_each_interleaving(&[3, 2], |schedule| {
            let mut next = [0usize; 2];
            for &t in schedule {
                next[t] += 1;
            }
            assert_eq!(next, [3, 2]);
            let mut seen = [0usize; 2];
            for &t in schedule {
                // the k-th appearance of t is its k-th op — monotone by
                // construction; this is the property the harness relies on
                seen[t] += 1;
                assert!(seen[t] <= [3, 2][t]);
            }
        });
    }

    #[test]
    fn permutations_are_factorial_and_distinct() {
        let p = permutations(4);
        assert_eq!(p.len(), 24);
        let mut uniq = p.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 24);
    }
}
