//! Runtime-dispatched SIMD kernels for the packed codec hot path.
//!
//! The C3 codec's per-row cost is dominated by three inner loops: the
//! butterfly passes of the half-/full-size complex FFTs behind
//! [`RfftPlan`](super::RfftPlan), the `acc[j] += K[j]*Z[j]` pointwise
//! multiply-accumulate of the packed encode superposition, and the
//! `out[j] = conj(K[j])*S[j]` unbind multiplies of the packed decode.  This
//! module exposes exactly those three row primitives behind a [`Kernels`]
//! handle whose instruction set is selected ONCE, at plan/engine build time:
//!
//! | ISA      | register shape                                 | selected when |
//! |----------|------------------------------------------------|---------------|
//! | `scalar` | one `C64` at a time                            | always available; bit-identical to the seed loops |
//! | `avx2`   | 2 complex bins per 256-bit vector (AVX2 + FMA) | `x86_64` with a runtime CPUID proof |
//! | `neon`   | 1 complex bin per 128-bit vector               | every `aarch64` build (NEON is baseline there) |
//!
//! Dispatch policy:
//!
//! * [`Kernels::detect`] resolves the process-wide default once and caches
//!   it: the [`ENV_KNOB`] environment variable (`C3SL_SIMD`) wins when set
//!   (`scalar`/`avx2`/`neon`, panicking loudly when the named ISA is
//!   unavailable so a CI matrix run can never silently fall back to a path
//!   it did not mean to test), otherwise the best ISA the host proves at
//!   runtime.  The detection itself is cheap and cached.
//! * [`Kernels::scalar`] / [`Kernels::forced`] pin an ISA per engine — the
//!   bench harness uses this to keep the `host/fft-packed` venue on the
//!   pre-SIMD scalar kernels while `host/fft-simd` runs the detected set,
//!   so the venue delta measures exactly the vectorization win.
//! * The seed-reference transforms ([`FftPlan::forward`](super::FftPlan) /
//!   [`inverse`](super::FftPlan::inverse) and everything the `Reference` FFT
//!   backend in `hdc` touches) never route through this module: their
//!   outputs are pinned bit-for-bit by tests, and FMA contraction changes
//!   the last ulp.  The scalar kernels here replicate the seed inner loops
//!   operation-for-operation, so a forced-`scalar` packed engine stays
//!   bit-identical to the pre-SIMD packed path.
//!
//! Under Miri the dispatcher always picks `scalar` — vendor intrinsics sit
//! outside Miri's interpreter, and the portable scalar kernels are the ones
//! Miri is meant to vet.
//!
//! The raw `std::arch` surface is confined to this file by the repolint
//! `simd-containment` invariant; everything else in the crate speaks
//! [`Kernels`].

use super::C64;

/// Environment variable naming the kernel ISA to force: `scalar`, `avx2` or
/// `neon`.  Read once per process by [`Kernels::detect`]; unknown values and
/// unavailable ISAs abort loudly rather than silently falling back.
pub const ENV_KNOB: &str = "C3SL_SIMD";

/// Instruction-set choices for the packed-path row kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loops — bit-identical to the seed kernels, and the
    /// only ISA Miri interprets.
    Scalar,
    /// x86-64 AVX2 + FMA: four f64 lanes, two complex bins per register.
    Avx2,
    /// aarch64 NEON: two f64 lanes, one complex bin per register.
    Neon,
}

impl Isa {
    /// Stable lowercase name — the config/env spelling and the bench banner.
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse the config/env spelling (`scalar`, `avx2`, `neon`).  `None` for
    /// anything else — callers decide how loudly to fail.
    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Whether this ISA can actually run on the current host: compile-time
    /// architecture AND (for AVX2) a runtime CPUID check.  Always `false`
    /// for vector ISAs under Miri.
    pub fn available(&self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                cfg!(not(miri))
                    && std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 => false,
            Isa::Neon => cfg!(all(target_arch = "aarch64", not(miri))),
        }
    }
}

/// A resolved kernel set: the three row primitives of the packed hot path,
/// dispatching to the ISA chosen at construction.  Cheap to copy; engines
/// and plans embed one by value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Kernels {
    isa: Isa,
}

impl Kernels {
    /// The portable scalar kernel set — bit-identical to the seed loops.
    pub fn scalar() -> Self {
        Kernels { isa: Isa::Scalar }
    }

    /// Pin a specific ISA.  Panics loudly when the ISA is not available on
    /// this host — an explicitly requested path must never silently degrade
    /// (the CI dispatch matrix depends on this).
    pub fn forced(isa: Isa) -> Self {
        assert!(
            isa.available(),
            "SIMD kernel ISA {:?} was requested (e.g. via {ENV_KNOB} or --simd) but is \
             not available on this host (arch: {}); use \"scalar\" or drop the knob",
            isa.name(),
            std::env::consts::ARCH
        );
        Kernels { isa }
    }

    /// Resolve the process-wide default kernel set, once, and cache it:
    /// honor [`ENV_KNOB`] when set (panicking on unknown values or on an ISA
    /// the host cannot run), otherwise pick the best available ISA.  Under
    /// Miri this is always the scalar set.
    pub fn detect() -> Self {
        #[cfg(miri)]
        {
            // Miri interprets portable Rust only; the vector paths are
            // compiled but never taken there.
            Kernels::scalar()
        }
        #[cfg(not(miri))]
        {
            use std::sync::OnceLock;
            static CHOICE: OnceLock<Isa> = OnceLock::new();
            let isa = *CHOICE.get_or_init(|| match std::env::var(ENV_KNOB) {
                Ok(v) => match Isa::parse(&v) {
                    Some(isa) => Kernels::forced(isa).isa,
                    None => panic!(
                        "{ENV_KNOB} must be \"scalar\", \"avx2\" or \"neon\", got {v:?}"
                    ),
                },
                Err(_) => [Isa::Avx2, Isa::Neon]
                    .into_iter()
                    .find(Isa::available)
                    .unwrap_or(Isa::Scalar),
            });
            Kernels { isa }
        }
    }

    /// The ISA this kernel set dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Encode superposition row primitive: `acc[j] += k[j] * z[j]` (complex
    /// multiply-accumulate) over equal-length half-spectrum rows.
    ///
    /// Scalar replays the seed loop bit-for-bit; the vector paths may fuse
    /// multiplies and adds (FMA), shifting the last ulp.
    pub fn cmul_acc(&self, acc: &mut [C64], k: &[C64], z: &[C64]) {
        assert_eq!(acc.len(), k.len());
        assert_eq!(acc.len(), z.len());
        match self.isa {
            Isa::Scalar => cmul_acc_scalar(acc, k, z),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                // SAFETY: a `Kernels` carrying `Isa::Avx2` is only built after
                // `Isa::available` proved avx2+fma via CPUID, which is exactly
                // the #[target_feature] contract of `avx2::cmul_acc`; lengths
                // are asserted equal above.
                unsafe { avx2::cmul_acc(acc, k, z) }
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => {
                // SAFETY: NEON is a baseline feature of every aarch64 target
                // this crate builds for, and `Isa::available` admits
                // `Isa::Neon` only on aarch64; lengths are asserted above.
                unsafe { neon::cmul_acc(acc, k, z) }
            }
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 => unreachable!("Isa::Avx2 is gated by Isa::available on x86_64"),
            #[cfg(not(target_arch = "aarch64"))]
            Isa::Neon => unreachable!("Isa::Neon is gated by Isa::available on aarch64"),
        }
    }

    /// Decode unbind row primitive: `out[j] = conj(k[j]) * s[j]` (circular
    /// correlation in the frequency domain) over equal-length rows.
    pub fn cmul_conj(&self, out: &mut [C64], k: &[C64], s: &[C64]) {
        assert_eq!(out.len(), k.len());
        assert_eq!(out.len(), s.len());
        match self.isa {
            Isa::Scalar => cmul_conj_scalar(out, k, s),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                // SAFETY: `Isa::Avx2` implies the CPUID proof of avx2+fma
                // demanded by `avx2::cmul_conj`'s #[target_feature] contract;
                // lengths are asserted equal above.
                unsafe { avx2::cmul_conj(out, k, s) }
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => {
                // SAFETY: NEON is baseline on aarch64 and `Isa::Neon` is only
                // constructible there; lengths are asserted equal above.
                unsafe { neon::cmul_conj(out, k, s) }
            }
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 => unreachable!("Isa::Avx2 is gated by Isa::available on x86_64"),
            #[cfg(not(target_arch = "aarch64"))]
            Isa::Neon => unreachable!("Isa::Neon is gated by Isa::available on aarch64"),
        }
    }

    /// One radix-2 butterfly pass over a chunk: for each `j`,
    /// `t = hi[j] * twiddles[j*step]; hi[j] = lo[j] - t; lo[j] = lo[j] + t`.
    /// `lo`/`hi` are the two halves of one `chunks_exact_mut` chunk of the
    /// transform buffer; `twiddles` is the plan's full table, strided by
    /// `step` exactly as the seed loop's `iter().step_by(step)` walks it.
    pub fn butterfly(&self, lo: &mut [C64], hi: &mut [C64], twiddles: &[C64], step: usize) {
        assert_eq!(lo.len(), hi.len());
        assert!(lo.is_empty() || (lo.len() - 1) * step < twiddles.len());
        match self.isa {
            Isa::Scalar => butterfly_scalar(lo, hi, twiddles, step),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                // SAFETY: `Isa::Avx2` implies the CPUID proof of avx2+fma
                // demanded by `avx2::butterfly`; the asserts above pin equal
                // halves and an in-bounds strided twiddle walk.
                unsafe { avx2::butterfly(lo, hi, twiddles, step) }
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => {
                // SAFETY: NEON is baseline on aarch64 and `Isa::Neon` is only
                // constructible there; bounds are asserted above.
                unsafe { neon::butterfly(lo, hi, twiddles, step) }
            }
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 => unreachable!("Isa::Avx2 is gated by Isa::available on x86_64"),
            #[cfg(not(target_arch = "aarch64"))]
            Isa::Neon => unreachable!("Isa::Neon is gated by Isa::available on aarch64"),
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar kernels — operation-for-operation replicas of the seed inner loops,
// so a forced-scalar engine is bit-identical to the pre-SIMD packed path.
// ---------------------------------------------------------------------------

fn cmul_acc_scalar(acc: &mut [C64], k: &[C64], z: &[C64]) {
    for ((a, kv), zv) in acc.iter_mut().zip(k).zip(z) {
        *a = a.add(kv.mul(*zv));
    }
}

fn cmul_conj_scalar(out: &mut [C64], k: &[C64], s: &[C64]) {
    for ((o, kv), sv) in out.iter_mut().zip(k).zip(s) {
        *o = kv.conj().mul(*sv);
    }
}

fn butterfly_scalar(lo: &mut [C64], hi: &mut [C64], twiddles: &[C64], step: usize) {
    for ((a, b), &w) in lo.iter_mut().zip(hi.iter_mut()).zip(twiddles.iter().step_by(step))
    {
        let t = b.mul(w);
        let u = *a;
        *a = u.add(t);
        *b = u.sub(t);
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA kernels: 4 f64 lanes = 2 interleaved complex bins per register.
//
// Layout contract: `C64` is `#[repr(C)] { re: f64, im: f64 }`, so a `&[C64]`
// of length m is exactly 2m contiguous f64s — `[re0, im0, re1, im1, ...]` —
// and a 256-bit load at f64 offset 4i reads bins i and i+1.
//
// Complex products use the fmaddsub idiom: with `ar`/`ai` the broadcast
// real/imag parts of `a` and `bs` the re/im-swapped `b`,
//   a*b        = fmaddsub(ar, b, ai*bs)   → [ar*br - ai*bi, ar*bi + ai*br]
//   conj(a)*b  = fmsubadd(ar, b, ai*bs)   → [ar*br + ai*bi, ar*bi - ai*br]
// (fmaddsub subtracts in even lanes and adds in odd lanes; fmsubadd is the
// mirror).  Odd trailing bins fall through to the scalar kernels.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use crate::fft::C64;
    use std::arch::x86_64::*;

    /// `acc[j] += k[j] * z[j]`, two bins per iteration.
    ///
    /// # Safety
    /// The host must support `avx2` and `fma` (the dispatcher checks CPUID
    /// before ever selecting this path), and all three slices must have
    /// equal length (asserted by the dispatcher).
    // SAFETY: see the # Safety section — the #[target_feature] contract is
    // discharged by the CPUID check in `Isa::available`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn cmul_acc(acc: &mut [C64], k: &[C64], z: &[C64]) {
        let n = acc.len();
        let pairs = n / 2;
        // SAFETY: C64 is #[repr(C)] (re, im), so each slice is 2n contiguous
        // f64s; every load/store below touches f64s [4i, 4i+4) with
        // 4*pairs <= 2n, inside the allocations the slices borrow.
        let ap = acc.as_mut_ptr().cast::<f64>();
        let kp = k.as_ptr().cast::<f64>();
        let zp = z.as_ptr().cast::<f64>();
        for i in 0..pairs {
            let off = 4 * i;
            let kv = _mm256_loadu_pd(kp.add(off));
            let zv = _mm256_loadu_pd(zp.add(off));
            let av = _mm256_loadu_pd(ap.add(off));
            let kr = _mm256_movedup_pd(kv); // [kre, kre, kre', kre']
            let ki = _mm256_permute_pd(kv, 0b1111); // [kim, kim, kim', kim']
            let zs = _mm256_permute_pd(zv, 0b0101); // [zim, zre, zim', zre']
            let t = _mm256_mul_pd(ki, zs);
            let prod = _mm256_fmaddsub_pd(kr, zv, t);
            _mm256_storeu_pd(ap.add(off), _mm256_add_pd(av, prod));
        }
        let tail = 2 * pairs;
        for ((a, kv), zv) in acc[tail..].iter_mut().zip(&k[tail..]).zip(&z[tail..]) {
            *a = a.add(kv.mul(*zv));
        }
    }

    /// `out[j] = conj(k[j]) * s[j]`, two bins per iteration.
    ///
    /// # Safety
    /// Same contract as [`cmul_acc`]: avx2+fma proven by the dispatcher,
    /// equal slice lengths asserted by the dispatcher.
    // SAFETY: see the # Safety section — the #[target_feature] contract is
    // discharged by the CPUID check in `Isa::available`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn cmul_conj(out: &mut [C64], k: &[C64], s: &[C64]) {
        let n = out.len();
        let pairs = n / 2;
        // SAFETY: same repr(C) layout argument as `cmul_acc` — all accesses
        // stay inside the 2n f64s each slice owns.
        let op = out.as_mut_ptr().cast::<f64>();
        let kp = k.as_ptr().cast::<f64>();
        let sp = s.as_ptr().cast::<f64>();
        for i in 0..pairs {
            let off = 4 * i;
            let kv = _mm256_loadu_pd(kp.add(off));
            let sv = _mm256_loadu_pd(sp.add(off));
            let kr = _mm256_movedup_pd(kv);
            let ki = _mm256_permute_pd(kv, 0b1111);
            let ss = _mm256_permute_pd(sv, 0b0101);
            let t = _mm256_mul_pd(ki, ss);
            _mm256_storeu_pd(op.add(off), _mm256_fmsubadd_pd(kr, sv, t));
        }
        let tail = 2 * pairs;
        for ((o, kv), sv) in out[tail..].iter_mut().zip(&k[tail..]).zip(&s[tail..]) {
            *o = kv.conj().mul(*sv);
        }
    }

    /// One butterfly pass: `t = hi[j]*w[j*step]; lo[j] += t; hi[j] = lo - t`,
    /// two bins per iteration with a strided twiddle gather.
    ///
    /// # Safety
    /// avx2+fma proven by the dispatcher; `lo.len() == hi.len()` and
    /// `(lo.len()-1)*step < twiddles.len()` asserted by the dispatcher.
    // SAFETY: see the # Safety section — the #[target_feature] contract is
    // discharged by the CPUID check in `Isa::available`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn butterfly(lo: &mut [C64], hi: &mut [C64], twiddles: &[C64], step: usize) {
        let half = lo.len();
        let pairs = half / 2;
        // SAFETY: repr(C) layout as above; lo/hi accesses cover f64s
        // [4j, 4j+4) with 4*pairs <= 2*half, and the two 128-bit twiddle
        // loads read bins (2j)*step and (2j+1)*step, both < twiddles.len()
        // by the dispatcher's stride assert.
        let lp = lo.as_mut_ptr().cast::<f64>();
        let hp = hi.as_mut_ptr().cast::<f64>();
        let wp = twiddles.as_ptr().cast::<f64>();
        for j in 0..pairs {
            let off = 4 * j;
            let w0 = _mm_loadu_pd(wp.add(2 * (2 * j) * step));
            let w1 = _mm_loadu_pd(wp.add(2 * (2 * j + 1) * step));
            let wv = _mm256_insertf128_pd(_mm256_castpd128_pd256(w0), w1, 1);
            let bv = _mm256_loadu_pd(hp.add(off));
            let av = _mm256_loadu_pd(lp.add(off));
            let br = _mm256_movedup_pd(bv);
            let bi = _mm256_permute_pd(bv, 0b1111);
            let ws = _mm256_permute_pd(wv, 0b0101);
            let t = _mm256_mul_pd(bi, ws);
            let tv = _mm256_fmaddsub_pd(br, wv, t); // t = hi[j] * w
            _mm256_storeu_pd(lp.add(off), _mm256_add_pd(av, tv));
            _mm256_storeu_pd(hp.add(off), _mm256_sub_pd(av, tv));
        }
        let tail = 2 * pairs;
        for ((a, b), &w) in lo[tail..]
            .iter_mut()
            .zip(hi[tail..].iter_mut())
            .zip(twiddles.iter().step_by(step).skip(tail))
        {
            let t = b.mul(w);
            let u = *a;
            *a = u.add(t);
            *b = u.sub(t);
        }
    }
}

// ---------------------------------------------------------------------------
// NEON kernels (aarch64): 2 f64 lanes = 1 complex bin per register.
//
// Complex products use a sign-vector FMA: with `ar`/`ai` the broadcast
// real/imag lanes of `a` and `bs` the re/im-swapped `b`,
//   a*b       = fma(sign_mul  * (ai*bs), ar, b)   sign_mul  = [-1, +1]
//   conj(a)*b = fma(sign_conj * (ai*bs), ar, b)   sign_conj = [+1, -1]
// NEON is part of the aarch64 baseline, so there is no runtime probe — the
// dispatcher only constructs `Isa::Neon` on aarch64 builds.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use crate::fft::C64;
    use std::arch::aarch64::*;

    /// `acc[j] += k[j] * z[j]`, one bin per iteration.
    ///
    /// # Safety
    /// aarch64-only (NEON is baseline); all three slices must have equal
    /// length (asserted by the dispatcher).
    // SAFETY: see the # Safety section — NEON is statically present on every
    // aarch64 target this crate builds for.
    #[target_feature(enable = "neon")]
    pub unsafe fn cmul_acc(acc: &mut [C64], k: &[C64], z: &[C64]) {
        let sign = vld1q_f64([-1.0f64, 1.0].as_ptr());
        // SAFETY: C64 is #[repr(C)] (re, im), so each slice is 2n contiguous
        // f64s; every load/store below reads f64s [2i, 2i+2) with i < n.
        let ap = acc.as_mut_ptr().cast::<f64>();
        let kp = k.as_ptr().cast::<f64>();
        let zp = z.as_ptr().cast::<f64>();
        for i in 0..acc.len() {
            let off = 2 * i;
            let kv = vld1q_f64(kp.add(off));
            let zv = vld1q_f64(zp.add(off));
            let av = vld1q_f64(ap.add(off));
            let kr = vdupq_laneq_f64::<0>(kv);
            let ki = vdupq_laneq_f64::<1>(kv);
            let zs = vextq_f64::<1>(zv, zv);
            let t = vmulq_f64(vmulq_f64(ki, zs), sign); // [-ki*zi, ki*zr]
            let prod = vfmaq_f64(t, kr, zv); // [kr*zr - ki*zi, kr*zi + ki*zr]
            vst1q_f64(ap.add(off), vaddq_f64(av, prod));
        }
    }

    /// `out[j] = conj(k[j]) * s[j]`, one bin per iteration.
    ///
    /// # Safety
    /// Same contract as [`cmul_acc`]: aarch64-only, equal slice lengths
    /// asserted by the dispatcher.
    // SAFETY: see the # Safety section — NEON is statically present on every
    // aarch64 target this crate builds for.
    #[target_feature(enable = "neon")]
    pub unsafe fn cmul_conj(out: &mut [C64], k: &[C64], s: &[C64]) {
        let sign = vld1q_f64([1.0f64, -1.0].as_ptr());
        // SAFETY: same repr(C) layout argument as `cmul_acc`.
        let op = out.as_mut_ptr().cast::<f64>();
        let kp = k.as_ptr().cast::<f64>();
        let sp = s.as_ptr().cast::<f64>();
        for i in 0..out.len() {
            let off = 2 * i;
            let kv = vld1q_f64(kp.add(off));
            let sv = vld1q_f64(sp.add(off));
            let kr = vdupq_laneq_f64::<0>(kv);
            let ki = vdupq_laneq_f64::<1>(kv);
            let ss = vextq_f64::<1>(sv, sv);
            let t = vmulq_f64(vmulq_f64(ki, ss), sign); // [ki*si, -ki*sr]
            vst1q_f64(op.add(off), vfmaq_f64(t, kr, sv));
        }
    }

    /// One butterfly pass, one bin per iteration with strided twiddles.
    ///
    /// # Safety
    /// aarch64-only; `lo.len() == hi.len()` and the strided twiddle walk
    /// in-bounds, both asserted by the dispatcher.
    // SAFETY: see the # Safety section — NEON is statically present on every
    // aarch64 target this crate builds for.
    #[target_feature(enable = "neon")]
    pub unsafe fn butterfly(lo: &mut [C64], hi: &mut [C64], twiddles: &[C64], step: usize) {
        let sign = vld1q_f64([-1.0f64, 1.0].as_ptr());
        // SAFETY: repr(C) layout as above; lo/hi accesses cover f64s
        // [2j, 2j+2) with j < lo.len(), and the twiddle load reads bin
        // j*step < twiddles.len() by the dispatcher's stride assert.
        let lp = lo.as_mut_ptr().cast::<f64>();
        let hp = hi.as_mut_ptr().cast::<f64>();
        let wp = twiddles.as_ptr().cast::<f64>();
        for j in 0..lo.len() {
            let off = 2 * j;
            let wv = vld1q_f64(wp.add(2 * j * step));
            let bv = vld1q_f64(hp.add(off));
            let av = vld1q_f64(lp.add(off));
            let br = vdupq_laneq_f64::<0>(bv);
            let bi = vdupq_laneq_f64::<1>(bv);
            let ws = vextq_f64::<1>(wv, wv);
            let t = vmulq_f64(vmulq_f64(bi, ws), sign);
            let tv = vfmaq_f64(t, br, wv); // t = hi[j] * w
            vst1q_f64(lp.add(off), vaddq_f64(av, tv));
            vst1q_f64(hp.add(off), vsubq_f64(av, tv));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cvec(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn assert_bits(a: &[C64], b: &[C64], what: &str) {
        for (i, (u, v)) in a.iter().zip(b).enumerate() {
            assert_eq!(u.re.to_bits(), v.re.to_bits(), "{what}: re bin {i}");
            assert_eq!(u.im.to_bits(), v.im.to_bits(), "{what}: im bin {i}");
        }
    }

    fn assert_close(a: &[C64], b: &[C64], what: &str) {
        use crate::util::testing::close;
        for (i, (u, v)) in a.iter().zip(b).enumerate() {
            assert!(
                close(u.re, v.re, 1e-12, 1e-12) && close(u.im, v.im, 1e-12, 1e-12),
                "{what}: bin {i}: ({}, {}) vs ({}, {})",
                u.re,
                u.im,
                v.re,
                v.im
            );
        }
    }

    #[test]
    fn isa_parse_and_names_round_trip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("auto"), None);
        assert_eq!(Isa::parse("AVX2"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn scalar_is_always_available_and_detect_is_stable() {
        assert!(Isa::Scalar.available());
        assert_eq!(Kernels::scalar().isa(), Isa::Scalar);
        // detect() caches: two calls must agree.
        assert_eq!(Kernels::detect(), Kernels::detect());
        assert!(Kernels::detect().isa().available());
    }

    #[test]
    fn scalar_kernels_replicate_seed_loops_bitwise() {
        // The forced-scalar contract: exactly the seed inner loops, so the
        // outputs must match a direct transcription bit for bit.
        let mut rng = Rng::new(11);
        let ker = Kernels::scalar();
        for &n in &[1usize, 2, 7, 64, 129] {
            let k = cvec(&mut rng, n);
            let z = cvec(&mut rng, n);

            let mut acc = cvec(&mut rng, n);
            let mut want = acc.clone();
            ker.cmul_acc(&mut acc, &k, &z);
            for ((a, kv), zv) in want.iter_mut().zip(&k).zip(&z) {
                *a = a.add(kv.mul(*zv));
            }
            assert_bits(&acc, &want, "cmul_acc");

            let mut out = vec![C64::new(0.0, 0.0); n];
            let mut wout = out.clone();
            ker.cmul_conj(&mut out, &k, &z);
            for ((o, kv), zv) in wout.iter_mut().zip(&k).zip(&z) {
                *o = kv.conj().mul(*zv);
            }
            assert_bits(&out, &wout, "cmul_conj");

            for &step in &[1usize, 2, 4] {
                let mut lo = cvec(&mut rng, n);
                let mut hi = cvec(&mut rng, n);
                let tw = cvec(&mut rng, n * step);
                let (mut wlo, mut whi) = (lo.clone(), hi.clone());
                ker.butterfly(&mut lo, &mut hi, &tw, step);
                for ((a, b), &w) in
                    wlo.iter_mut().zip(whi.iter_mut()).zip(tw.iter().step_by(step))
                {
                    let t = b.mul(w);
                    let u = *a;
                    *a = u.add(t);
                    *b = u.sub(t);
                }
                assert_bits(&lo, &wlo, "butterfly lo");
                assert_bits(&hi, &whi, "butterfly hi");
            }
        }
    }

    #[test]
    #[cfg(not(miri))]
    fn detected_kernels_match_scalar_within_fma_tolerance() {
        // When the host offers a vector ISA (or the env knob pins one), its
        // kernels must agree with the scalar replicas to FMA rounding.
        let det = Kernels::detect();
        if det.isa() == Isa::Scalar {
            return; // nothing to compare on this host
        }
        let sc = Kernels::scalar();
        let mut rng = Rng::new(29);
        for &n in &[1usize, 2, 3, 8, 63, 64, 65, 129] {
            let k = cvec(&mut rng, n);
            let z = cvec(&mut rng, n);

            let mut a1 = cvec(&mut rng, n);
            let mut a2 = a1.clone();
            det.cmul_acc(&mut a1, &k, &z);
            sc.cmul_acc(&mut a2, &k, &z);
            assert_close(&a1, &a2, "cmul_acc");

            let mut o1 = vec![C64::new(0.0, 0.0); n];
            let mut o2 = o1.clone();
            det.cmul_conj(&mut o1, &k, &z);
            sc.cmul_conj(&mut o2, &k, &z);
            assert_close(&o1, &o2, "cmul_conj");

            for &step in &[1usize, 2, 4] {
                let lo0 = cvec(&mut rng, n);
                let hi0 = cvec(&mut rng, n);
                let tw = cvec(&mut rng, n * step);
                let (mut lo1, mut hi1) = (lo0.clone(), hi0.clone());
                let (mut lo2, mut hi2) = (lo0, hi0);
                det.butterfly(&mut lo1, &mut hi1, &tw, step);
                sc.butterfly(&mut lo2, &mut hi2, &tw, step);
                assert_close(&lo1, &lo2, "butterfly lo");
                assert_close(&hi1, &hi2, "butterfly hi");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    #[should_panic(expected = "not available on this host")]
    fn forcing_neon_on_x86_64_is_a_loud_error() {
        let _ = Kernels::forced(Isa::Neon);
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    #[should_panic(expected = "not available on this host")]
    fn forcing_avx2_on_aarch64_is_a_loud_error() {
        let _ = Kernels::forced(Isa::Avx2);
    }

    #[test]
    fn empty_and_unit_rows_are_handled() {
        // Degenerate shapes the tail/pair split must not trip over.
        let ker = Kernels::detect();
        let mut empty: Vec<C64> = Vec::new();
        ker.cmul_acc(&mut empty, &[], &[]);
        ker.cmul_conj(&mut empty, &[], &[]);
        ker.butterfly(&mut [], &mut [], &[], 1);
        let k = [C64::new(2.0, -1.0)];
        let z = [C64::new(0.5, 3.0)];
        let mut acc = [C64::new(1.0, 1.0)];
        ker.cmul_acc(&mut acc, &k, &z);
        let want = C64::new(1.0, 1.0).add(k[0].mul(z[0]));
        assert!((acc[0].re - want.re).abs() < 1e-12);
        assert!((acc[0].im - want.im).abs() < 1e-12);
    }
}

