//! Shared tolerance-based assertion helpers for numeric tests.
//!
//! The packed real-FFT kernels ([`crate::fft::RfftPlan`]) are numerically
//! equal but **not bit-identical** to the reference transforms, so the
//! `to_bits` equality style the scratch-kernel tests use cannot pin them.
//! This module is the one shared definition of "close enough": a combined
//! relative + absolute bound
//!
//! ```text
//!   |a − b| <= abs + rel · max(|a|, |b|)
//! ```
//!
//! used by the fft/hdc property tests, the integration suite and the bench
//! gate alike, so every parity claim in the tree means the same thing.

/// Default relative tolerance for packed-vs-reference parity: the acceptance
/// bound the packed backend is held to on encode/decode round-trips.
pub const DEFAULT_REL: f64 = 1e-5;

/// Default absolute floor, for values near zero where a relative bound is
/// meaningless (f32 signals of unit scale).
pub const DEFAULT_ABS: f64 = 1e-6;

/// `|a − b| <= abs + rel · max(|a|, |b|)` — the shared closeness predicate.
pub fn close(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    (a - b).abs() <= abs + rel * a.abs().max(b.abs())
}

/// Assert two scalars are close under the combined rel+abs bound; the
/// failure message names `what` and both values.
#[track_caller]
pub fn assert_close(a: f64, b: f64, rel: f64, abs: f64, what: &str) {
    assert!(
        close(a, b, rel, abs),
        "{what}: {a} vs {b} (|Δ| = {}, rel tol {rel}, abs tol {abs})",
        (a - b).abs()
    );
}

/// Assert two f32 slices match element-wise under the combined rel+abs
/// bound; the failure message names `what`, the first offending index and
/// both values there.
#[track_caller]
pub fn assert_close_slice(a: &[f32], b: &[f32], rel: f64, abs: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            close(*x as f64, *y as f64, rel, abs),
            "{what}: elem {i}: {x} vs {y} (|Δ| = {}, rel tol {rel}, abs tol {abs})",
            (*x as f64 - *y as f64).abs()
        );
    }
}

/// [`assert_close_slice`] at the packed-parity defaults
/// ([`DEFAULT_REL`], [`DEFAULT_ABS`]).
#[track_caller]
pub fn assert_close_default(a: &[f32], b: &[f32], what: &str) {
    assert_close_slice(a, b, DEFAULT_REL, DEFAULT_ABS, what);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predicate_boundaries() {
        // pure relative: 1e6 vs 1e6·(1+5e-6) is inside 1e-5, outside 1e-7
        assert!(close(1e6, 1e6 * (1.0 + 5e-6), 1e-5, 0.0));
        assert!(!close(1e6, 1e6 * (1.0 + 5e-6), 1e-7, 0.0));
        // pure absolute: near-zero values need the abs floor
        assert!(close(0.0, 5e-7, 0.0, 1e-6));
        assert!(!close(0.0, 5e-7, 1e-5, 0.0));
        // symmetric in its arguments
        assert!(close(5e-7, 0.0, 0.0, 1e-6));
        // exact equality always passes, including at zero tolerance
        assert!(close(3.25, 3.25, 0.0, 0.0));
    }

    #[test]
    fn slice_assert_passes_on_close_data() {
        let a = [1.0f32, -2.0, 0.0, 1e-7];
        let b = [1.000001f32, -2.000002, 5e-7, 0.0];
        assert_close_slice(&a, &b, 1e-5, 1e-6, "slices");
        assert_close_default(&a, &b, "slices (defaults)");
    }

    #[test]
    #[should_panic(expected = "elem 1")]
    fn slice_assert_names_the_offender() {
        assert_close_slice(&[1.0, 1.0], &[1.0, 1.1], 1e-5, 1e-6, "offender");
    }

    #[test]
    #[should_panic(expected = "length")]
    fn slice_assert_rejects_length_mismatch() {
        assert_close_slice(&[1.0], &[1.0, 2.0], 1e-5, 1e-6, "len");
    }
}
