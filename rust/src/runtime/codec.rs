//! CodecRuntime: the C3 encode/decode artifacts (the L1 Pallas kernels,
//! AOT-lowered) plus key generation, executed through PJRT.
use std::path::PathBuf;

use crate::ensure;
use crate::runtime::xla_stub as xla;
use crate::util::error::{Context, Result};

use super::convert::{literal_to_tensor, seed_literal, tensor_to_literal};
use super::engine::{Engine, Executable};
use super::manifest::CodecManifest;
use crate::tensor::Tensor;

/// The AOT-compiled C3 codec: gen_keys/encode/decode executables plus the
/// key literal they share once [`CodecRuntime::init_keys`] has run.
pub struct CodecRuntime {
    /// The artifact set's manifest (geometry, kernel family, file map).
    pub manifest: CodecManifest,
    gen_keys: std::sync::Arc<Executable>,
    encode: std::sync::Arc<Executable>,
    decode: std::sync::Arc<Executable>,
    /// Keys as a literal, set by `init_keys` (shared by edge and cloud via
    /// the seed — the keys themselves never cross the wire).
    keys: Option<xla::Literal>,
    keys_tensor: Option<Tensor>,
}

impl CodecRuntime {
    /// Load and compile the codec artifact set under `dir` (expects
    /// `gen_keys`, `c3_encode` and `c3_decode` in its manifest).
    pub fn load(engine: &Engine, dir: impl Into<PathBuf>) -> Result<Self> {
        let dir: PathBuf = dir.into();
        let manifest = CodecManifest::load(&dir)
            .with_context(|| format!("loading codec manifest from {}", dir.display()))?;
        let load = |name: &str| -> Result<std::sync::Arc<Executable>> {
            let file = &manifest.artifact(name)?.file;
            engine.load(dir.join(file))
        };
        Ok(CodecRuntime {
            gen_keys: load("gen_keys")?,
            encode: load("c3_encode")?,
            decode: load("c3_decode")?,
            manifest,
            keys: None,
            keys_tensor: None,
        })
    }

    /// Compression ratio R the artifacts were lowered for.
    pub fn r(&self) -> usize {
        self.manifest.r
    }

    /// Carrier dimensionality D the artifacts were lowered for.
    pub fn d(&self) -> usize {
        self.manifest.d
    }

    /// Generate the fixed key set from a seed (deterministic; both sides call
    /// this with the same seed instead of transmitting R×D key floats).
    pub fn init_keys(&mut self, seed: u64) -> Result<()> {
        let s = seed_literal(seed)?;
        let outs = self.gen_keys.run(&[&s])?;
        let t = literal_to_tensor(&outs[0])?;
        ensure!(
            t.shape() == [self.manifest.r, self.manifest.d],
            "keys shape {:?}",
            t.shape()
        );
        self.keys = Some(tensor_to_literal(&t)?);
        self.keys_tensor = Some(t);
        Ok(())
    }

    /// The generated key tensor `(R, D)`, or `None` before `init_keys`.
    pub fn keys_tensor(&self) -> Option<&Tensor> {
        self.keys_tensor.as_ref()
    }

    /// Encode (B, D) → (G, D) through the Pallas kernel artifact.
    pub fn encode(&self, z: &Tensor) -> Result<Tensor> {
        let keys = self.keys.as_ref().context("codec keys not initialized")?;
        ensure!(
            z.shape() == [self.manifest.batch, self.manifest.d],
            "encode input shape {:?}",
            z.shape()
        );
        let zl = tensor_to_literal(z)?;
        let outs = self.encode.run(&[&zl, keys])?;
        literal_to_tensor(&outs[0])
    }

    /// Decode (G, D) → (B, D).
    pub fn decode(&self, s: &Tensor) -> Result<Tensor> {
        let keys = self.keys.as_ref().context("codec keys not initialized")?;
        ensure!(
            s.shape() == [self.manifest.g, self.manifest.d],
            "decode input shape {:?}",
            s.shape()
        );
        let sl = tensor_to_literal(s)?;
        let outs = self.decode.run(&[&sl, keys])?;
        literal_to_tensor(&outs[0])
    }
}
