# L2 model-construction tests: shapes, split dimensions, parameter counts.

import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.models import (bottlenetpp_codec, resnet50_split, vgg16_split,
                            vgg_tiny_split)


class TestVGG16:
    def test_cut_dim_matches_paper(self):
        # Paper Table 2 ⇒ D = 2048 for VGG-16 on 32×32 CIFAR (512·2·2).
        edge, cloud, d = vgg16_split(num_classes=10, width=1.0, image=32)
        assert d == 2048

    def test_shapes_end_to_end(self):
        edge, cloud, d = vgg16_split(num_classes=10, width=0.125, image=32)
        rng = jax.random.PRNGKey(0)
        ep, eo = edge.init(rng, (3, 32, 32))
        cp, co = cloud.init(rng, eo)
        assert eo == (d,) and co == (10,)
        x = jnp.zeros((2, 3, 32, 32))
        z = edge.apply(ep, x)
        assert z.shape == (2, d)
        assert cloud.apply(cp, z).shape == (2, 10)

    def test_slim_width_scales_cut(self):
        _, _, d_full = vgg16_split(width=1.0, image=32)
        _, _, d_slim = vgg16_split(width=0.25, image=32)
        assert d_slim == d_full // 4


class TestResNet50:
    def test_cut_dim_matches_paper(self):
        # Paper Table 2 ⇒ D = 4096 for ResNet-50 on 32×32 CIFAR (1024·2·2).
        edge, cloud, d = resnet50_split(num_classes=100, width=1.0, image=32)
        assert d == 4096

    def test_shapes_end_to_end_slim(self):
        edge, cloud, d = resnet50_split(num_classes=100, width=0.125, image=32)
        rng = jax.random.PRNGKey(0)
        ep, eo = edge.init(rng, (3, 32, 32))
        cp, co = cloud.init(rng, eo)
        assert eo == (d,) and co == (100,)
        x = jnp.zeros((2, 3, 32, 32))
        z = edge.apply(ep, x)
        assert z.shape == (2, d)
        assert cloud.apply(cp, z).shape == (2, 100)


class TestBottleNetPP:
    @pytest.mark.parametrize("ratio", [2, 4, 8, 16])
    def test_tx_dim_is_cut_over_r(self, ratio):
        c, h, w = 64, 4, 4
        enc, dec, d_tx = bottlenetpp_codec(c, h, w, ratio)
        assert d_tx == (c * h * w) // ratio

    def test_roundtrip_shapes(self):
        c, h, w = 16, 4, 4
        enc, dec, d_tx = bottlenetpp_codec(c, h, w, 4)
        rng = jax.random.PRNGKey(0)
        pe, oe = enc.init(rng, (c, h, w))
        pd, od = dec.init(rng, oe)
        assert oe == (d_tx,)
        assert od == (c * h * w,)
        x = jnp.ones((3, c, h, w))
        s = enc.apply(pe, x)
        assert s.shape == (3, d_tx)
        assert dec.apply(pd, s).shape == (3, c * h * w)
        # Sigmoid bounds the transmitted tensor — quantization-friendly.
        assert float(s.min()) >= 0.0 and float(s.max()) <= 1.0


class TestRegistry:
    def test_presets_resolve(self):
        for preset in ("tiny", "slim", "full"):
            assert len(M.resolve(preset)) >= 1

    def test_single_key_resolves(self):
        (cfg,) = M.resolve("vggt_b32")
        assert cfg.key == "vggt_b32"

    def test_unknown_key_raises(self):
        with pytest.raises(KeyError):
            M.resolve("nope")

    def test_bnpp_config_tx_dim(self):
        (cfg,) = M.resolve("vggt_b32_bnpp_r4")
        _, _, d_tx, d_cut = cfg.build()
        assert d_tx == d_cut // 4
