//! # c3sl — C3-SL: Circular Convolution-Based Batch-Wise Compression for
//! Communication-Efficient Split Learning
//!
//! A three-layer reproduction of Hsieh, Chuang & Wu (2022):
//!
//! * **L3 (this crate)** — the split-learning coordinator: edge/cloud
//!   workers, transports with byte accounting, compression codecs, dataset
//!   substrates, metrics, and a CLI.
//! * **L2 (python/compile)** — JAX model definitions (VGG-16 / ResNet-50 and
//!   slim variants) AOT-lowered to HLO text artifacts at build time.
//! * **L1 (python/compile/kernels)** — Pallas circular-convolution kernels
//!   (the paper's encoder/decoder), lowered into the same artifacts.
//!
//! At runtime Python is never on the path: `runtime` loads the HLO artifacts
//! through the PJRT C API and the coordinator drives training entirely from
//! rust.  See DESIGN.md for the system inventory and experiment index, and
//! docs/ARCHITECTURE.md for the layer map and serving architecture.

// Public API documentation is enforced crate-wide: every module is fully
// documented, the CI doc job denies warnings, and repolint cross-checks
// that any future `#![allow(missing_docs)]` doc-debt marker is declared in
// rust/tools/repolint/doc_debt_allowlist.txt (currently empty).
#![warn(missing_docs)]

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fft;
pub mod flops;
pub mod hdc;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod transport;
pub mod util;

/// Crate version (mirrors Cargo.toml), shown by the CLI's usage banner.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
