//! Host-side stand-in for the `xla` PJRT bindings.
//!
//! This environment builds fully offline: the real `xla` crate (PJRT C API
//! bindings) cannot be fetched, so the runtime layer compiles against this
//! stub (each consuming module aliases it with
//! `use crate::runtime::xla_stub as xla;` — swapping the real crate back in
//! is a one-line change per module).
//!
//! `Literal` is a *real* host container — the Tensor↔Literal conversion
//! layer and its unit tests run unchanged.  Everything that would touch a
//! PJRT device (client construction, HLO parsing, compilation, execution)
//! returns [`XlaError`], so the engine fails loudly at `Engine::cpu()` and
//! every artifact-dependent test/example skips or reports cleanly.
use std::fmt;

/// Error for unavailable PJRT functionality (and literal misuse).
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

impl From<XlaError> for crate::util::error::C3Error {
    fn from(e: XlaError) -> Self {
        Self::msg(e.to_string())
    }
}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what} needs the PJRT runtime (the `xla` crate), which is not part of this offline build"
    ))
}

/// Literal element types used by the conversion layer (all 4-byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    S32,
    /// 32-bit unsigned integer.
    U32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

/// Element types that can be read back out of a literal.
pub trait NativeType: Copy {
    /// The literal element type this Rust type reads back as.
    const TY: ElementType;
    /// Decode one element from its 4 little-endian bytes.
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn from_le(bytes: [u8; 4]) -> Self {
        u32::from_le_bytes(bytes)
    }
}

/// Host-side literal: dtype + dims + raw little-endian bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw little-endian bytes; errors unless the
    /// byte length matches the shape's element count × element size.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, XlaError> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * ty.byte_size() {
            return Err(XlaError(format!(
                "literal data is {} bytes but shape {dims:?} needs {}",
                data.len(),
                elems * ty.byte_size()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    /// Total element count (product of the dims).
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }

    /// The literal's shape (mirrors the xla crate's fallible accessor).
    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Read the elements back as `T`; errors on a dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        if self.ty != T::TY {
            return Err(XlaError(format!(
                "literal holds {:?}, read as {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le(c.try_into().unwrap()))
            .collect())
    }

    /// Decompose a tuple literal into its elements — device-only in the
    /// stub, so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable("decomposing tuple literals"))
    }
}

/// Array shape with i64 dims, mirroring the xla crate's accessor.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension sizes, outermost first.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// PJRT client stub: construction always fails (no device plugin offline).
#[derive(Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Construct the CPU client — always errors in the stub (no device
    /// plugin offline); [`super::engine::Engine::cpu`] surfaces this.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// The platform name the stub reports (`"stub"`).
    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    /// Compile a computation for this client — always errors in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compiling an XlaComputation"))
    }
}

/// Parsed HLO module stub (construction always errors offline).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text artifact — always errors in the stub.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// Computation wrapper stub, mirroring the xla crate's type.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module (shape-only; nothing to do in the stub).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled-executable stub (never obtainable offline; methods error).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments — always errors in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("executing a loaded executable"))
    }

    /// Execute with device-buffer arguments — always errors in the stub.
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("executing a loaded executable"))
    }
}

/// Device buffer stub (never obtainable offline; methods error).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal — always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("fetching a device buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_bytes() {
        let vals = [1.5f32, -2.0, 0.25, 8.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes)
            .unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vals);
        assert!(l.to_vec::<i32>().is_err(), "dtype mismatch must be caught");
    }

    #[test]
    fn literal_rejects_shape_data_mismatch() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &[0u8; 8]
        )
        .is_err());
    }

    #[test]
    fn device_paths_error_cleanly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline"), "{e}");
    }
}
