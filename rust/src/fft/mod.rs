//! FFT substrate (from scratch): iterative radix-2 Cooley–Tukey over
//! interleaved complex buffers, plus real-input convolution helpers used by
//! the rust-native C3 codec hot path.
//!
//! Three kernel families live here:
//!
//! * [`FftPlan`] — the general complex transform, in two flavors: the seed
//!   reference kernel (`forward`/`inverse`, kept verbatim as the numerics
//!   oracle) and the zero-allocation scratch kernel
//!   (`forward_into`/`inverse_into`, bit-identical to the reference);
//! * [`RfftPlan`] — **packed real transforms**: a real signal of length N is
//!   Hermitian-symmetric in the frequency domain, so its spectrum is fully
//!   described by N/2+1 complex bins and can be computed with one N/2-point
//!   complex FFT (the pack/split trick), roughly halving the butterfly work
//!   per row.  A batch inverse ([`RfftPlan::irfft2_into`]) recovers **two**
//!   real rows from one full-size complex inverse — the decode-side win the
//!   `hdc` packed backend is built on.  Packed kernels are *not* bit-
//!   identical to the reference (different operation order); the property
//!   tests pin them to the reference within tight rel+abs tolerances
//!   ([`crate::util::testing`]).
//! * free helpers ([`rfft`], [`irfft`], [`circular_convolve_fft`], …) — the
//!   allocating reference paths used by oracles, analysis and tests.
//!
//! Only power-of-two lengths go through the FFT; the `hdc` module falls back
//! to the direct O(D²) path otherwise (real workloads here have D = 2^k).
//!
//! The packed kernels' inner loops dispatch through [`kernels::Kernels`] — a
//! per-plan SIMD kernel set (scalar / AVX2+FMA / NEON) chosen once at build
//! time.  [`FftPlan::new`] always builds the scalar set so the bit-identical
//! reference/scratch contract survives; [`RfftPlan::new`] auto-detects
//! (overridable via the `C3SL_SIMD` knob, see [`kernels`]).

pub mod kernels;

use kernels::Kernels;
use std::f64::consts::PI;

/// Complex number as (re, im) over f64 for accumulation accuracy.
///
/// `#[repr(C)]` pins the `[re, im]` field order and layout so the SIMD
/// kernels in [`kernels`] may view a `&[C64]` as interleaved contiguous f64s.
#[repr(C)]
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct C64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl C64 {
    /// Construct from real and imaginary parts.
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    /// Complex product `self · o`.
    #[inline]
    pub fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    /// Complex conjugate.
    #[inline]
    pub fn conj(self) -> C64 {
        C64::new(self.re, -self.im)
    }

    /// Complex sum `self + o`.
    #[inline]
    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    /// Complex difference `self − o`.
    #[inline]
    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }

    /// Scale both parts by a real factor.
    #[inline]
    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }
}

/// Twiddle-factor table for a given power-of-two length, reused across calls.
///
/// Two kernels share the tables:
/// * [`forward`](FftPlan::forward) / [`inverse`](FftPlan::inverse) — the seed
///   reference transform, kept verbatim as the numerics oracle and the
///   allocating-path baseline in `benches/codec_hotpath.rs`.
/// * [`forward_into`](FftPlan::forward_into) /
///   [`inverse_into`](FftPlan::inverse_into) — the scratch kernel: same
///   butterfly schedule and twiddle values (so it is **bit-identical** to the
///   reference), but with a precomputed bit-reversal table, a separate
///   exact-conjugate inverse twiddle table (no per-butterfly branch), and
///   iterator-driven inner loops (no bounds checks).
#[derive(Clone, Debug)]
pub struct FftPlan {
    /// Transform length (power of two).
    pub n: usize,
    /// twiddles[k] = exp(-2πi k / n) for k < n/2
    twiddles: Vec<C64>,
    /// conj(twiddles) — exact sign flips, so the scratch kernel's inverse
    /// matches the reference's per-butterfly `w.conj()` bit for bit.
    itwiddles: Vec<C64>,
    /// Precomputed bit-reversal permutation for the scratch kernel.
    bitrev: Vec<u32>,
    /// SIMD kernel set driving the scratch kernel's butterfly passes.
    /// Always scalar for plans built via [`FftPlan::new`], so the
    /// reference/scratch bit-identity contract holds.
    kernels: Kernels,
}

impl FftPlan {
    /// Precompute twiddle and bit-reversal tables for length `n` (must be a
    /// power of two; panics otherwise).  The scratch kernel runs on the
    /// scalar butterfly set — bit-identical to the reference transform.
    pub fn new(n: usize) -> Self {
        Self::with_kernels(n, Kernels::scalar())
    }

    /// Like [`FftPlan::new`], but with an explicit SIMD kernel set for the
    /// scratch kernel's butterflies.  Non-scalar sets trade last-ulp
    /// bit-identity with the reference transform for FMA throughput — only
    /// the tolerance-pinned packed path ([`RfftPlan`]) builds plans this way.
    pub fn with_kernels(n: usize, kernels: Kernels) -> Self {
        assert!(n.is_power_of_two(), "FftPlan requires power-of-two n, got {n}");
        let twiddles: Vec<C64> = (0..n / 2)
            .map(|k| {
                let ang = -2.0 * PI * k as f64 / n as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        let itwiddles = twiddles.iter().map(|w| w.conj()).collect();
        let bits = n.trailing_zeros();
        let bitrev = (0..n)
            .map(|i| {
                if bits == 0 {
                    0
                } else {
                    (i.reverse_bits() >> (usize::BITS - bits)) as u32
                }
            })
            .collect();
        FftPlan { n, twiddles, itwiddles, bitrev, kernels }
    }

    /// In-place forward FFT (decimation in time, bit-reversal permutation).
    pub fn forward(&self, buf: &mut [C64]) {
        self.transform(buf, false);
    }

    /// In-place inverse FFT (includes the 1/n normalization).
    pub fn inverse(&self, buf: &mut [C64]) {
        self.transform(buf, true);
        let inv = 1.0 / self.n as f64;
        for c in buf.iter_mut() {
            c.re *= inv;
            c.im *= inv;
        }
    }

    fn transform(&self, buf: &mut [C64], inverse: bool) {
        let n = self.n;
        assert_eq!(buf.len(), n);
        // bit reversal
        let bits = n.trailing_zeros();
        for i in 0..n {
            let j = i.reverse_bits() >> (usize::BITS - bits);
            if j > i {
                buf.swap(i, j);
            }
        }
        // butterflies
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            for start in (0..n).step_by(len) {
                for k in 0..half {
                    let mut w = self.twiddles[k * step];
                    if inverse {
                        w = w.conj();
                    }
                    let a = buf[start + k];
                    let b = buf[start + k + half].mul(w);
                    buf[start + k] = a.add(b);
                    buf[start + k + half] = a.sub(b);
                }
            }
            len <<= 1;
        }
    }

    /// In-place forward FFT through the scratch kernel.  Bit-identical to
    /// [`FftPlan::forward`]; no allocation, no per-butterfly branches, no
    /// bounds checks in the butterfly loop.
    pub fn forward_into(&self, buf: &mut [C64]) {
        self.transform_into(buf, &self.twiddles);
    }

    /// In-place inverse FFT (with the 1/n normalization) through the scratch
    /// kernel.  Bit-identical to [`FftPlan::inverse`].
    pub fn inverse_into(&self, buf: &mut [C64]) {
        self.transform_into(buf, &self.itwiddles);
        let inv = 1.0 / self.n as f64;
        for c in buf.iter_mut() {
            c.re *= inv;
            c.im *= inv;
        }
    }

    fn transform_into(&self, buf: &mut [C64], twiddles: &[C64]) {
        let n = self.n;
        assert_eq!(buf.len(), n);
        for (i, &j) in self.bitrev.iter().enumerate() {
            let j = j as usize;
            if j > i {
                buf.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            for chunk in buf.chunks_exact_mut(len) {
                let (lo, hi) = chunk.split_at_mut(half);
                self.kernels.butterfly(lo, hi, twiddles, step);
            }
            len <<= 1;
        }
    }
}

/// Forward FFT of a real f32 signal → full complex spectrum.
pub fn rfft(plan: &FftPlan, x: &[f32]) -> Vec<C64> {
    assert_eq!(x.len(), plan.n);
    let mut buf: Vec<C64> = x.iter().map(|&v| C64::new(v as f64, 0.0)).collect();
    plan.forward(&mut buf);
    buf
}

/// Inverse FFT → real part as f32 (imaginary parts must be ~0 for our uses).
pub fn irfft(plan: &FftPlan, mut spec: Vec<C64>) -> Vec<f32> {
    plan.inverse(&mut spec);
    spec.iter().map(|c| c.re as f32).collect()
}

/// Forward FFT of a real signal into caller-owned scratch — the
/// zero-allocation twin of [`rfft`] (bit-identical output).
pub fn rfft_into(plan: &FftPlan, x: &[f32], out: &mut [C64]) {
    assert_eq!(x.len(), plan.n);
    assert_eq!(out.len(), plan.n);
    for (o, &v) in out.iter_mut().zip(x) {
        *o = C64::new(v as f64, 0.0);
    }
    plan.forward_into(out);
}

/// Inverse FFT of `spec` (consumed in place) writing the real part into
/// `out` — the zero-allocation twin of [`irfft`] (bit-identical output).
pub fn irfft_into(plan: &FftPlan, spec: &mut [C64], out: &mut [f32]) {
    assert_eq!(spec.len(), plan.n);
    assert_eq!(out.len(), plan.n);
    plan.inverse_into(spec);
    for (o, c) in out.iter_mut().zip(spec.iter()) {
        *o = c.re as f32;
    }
}

/// Circular convolution via the convolution theorem (power-of-two n).
pub fn circular_convolve_fft(plan: &FftPlan, a: &[f32], b: &[f32]) -> Vec<f32> {
    let fa = rfft(plan, a);
    let fb = rfft(plan, b);
    let prod: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| x.mul(*y)).collect();
    irfft(plan, prod)
}

/// Circular correlation via conj(F(a))·F(b) (power-of-two n).
pub fn circular_correlate_fft(plan: &FftPlan, a: &[f32], b: &[f32]) -> Vec<f32> {
    let fa = rfft(plan, a);
    let fb = rfft(plan, b);
    let prod: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| x.conj().mul(*y)).collect();
    irfft(plan, prod)
}

// ---------------------------------------------------------------------------
// Packed real transforms: half-spectrum kernels over N/2-point complex FFTs.
// ---------------------------------------------------------------------------

/// Packed real-FFT plan for power-of-two length `n >= 2`.
///
/// A real signal's spectrum is Hermitian-symmetric (`X[n−k] = conj(X[k])`),
/// so the `n/2 + 1` bins `X[0..=n/2]` carry the whole transform — the
/// **half spectrum**.  This plan computes it with the pack/split trick:
///
/// ```text
///   pack    z[k] = x[2k] + i·x[2k+1]          n real → n/2 complex
///   fft     Z    = FFT_{n/2}(z)               one half-size transform
///   split   X[k] = Xe[k] + w^k·Xo[k]          O(n) recombination,
///           Xe[k] = (Z[k] + conj(Z[h−k]))/2   w = exp(−2πi/n), h = n/2
///           Xo[k] = (Z[k] − conj(Z[h−k]))/2i
/// ```
///
/// versus the reference path's full `n`-point complex FFT per real row —
/// about half the butterfly work and half the spectrum memory.  The inverse
/// ([`RfftPlan::irfft_into`]) runs the merge/pack steps backwards through
/// one `n/2`-point inverse.  For batch decode, [`RfftPlan::irfft2_into`]
/// reconstructs **two** real rows from one full-size complex inverse by
/// synthesizing `S = A~ + i·B~` from two half spectra (`~` = Hermitian
/// extension): the real part of `IFFT(S)` is row a, the imaginary part row b.
///
/// Unlike the [`FftPlan`] scratch kernels, packed outputs are NOT bit-
/// identical to the reference transforms (the operation order differs);
/// `hdc`'s packed-backend property tests pin them to the reference within
/// rel+abs tolerance instead ([`crate::util::testing::assert_close_slice`]).
#[derive(Clone, Debug)]
pub struct RfftPlan {
    /// Real transform length (power of two, >= 2).
    n: usize,
    /// The n/2-point complex plan behind the pack/split kernels.
    half: FftPlan,
    /// The full n-point plan: drives the two-rows-per-inverse batch decode
    /// and doubles as the reference plan for oracle paths.
    full: FftPlan,
    /// Split/merge twiddles w[k] = exp(−2πi k / n) for k <= n/2.
    w: Vec<C64>,
    /// SIMD kernel set the embedded plans' butterflies dispatch through.
    kernels: Kernels,
}

impl RfftPlan {
    /// Precompute the packed-transform tables for real length `n` (must be a
    /// power of two `>= 2`; panics otherwise — length 1 has no half plan, so
    /// callers fall back to the reference kernels there).  The butterfly
    /// passes run on the auto-detected SIMD kernel set
    /// ([`Kernels::detect`](kernels::Kernels::detect), honoring the
    /// `C3SL_SIMD` knob); packed outputs are tolerance-pinned, not bitwise,
    /// so the ISA choice stays inside the tested envelope.
    pub fn new(n: usize) -> Self {
        Self::with_kernels(n, Kernels::detect())
    }

    /// Like [`RfftPlan::new`], but with an explicit SIMD kernel set — the
    /// bench harness and the parity tests use this to pin venues to a
    /// specific ISA (forced-scalar reproduces the pre-SIMD packed kernels
    /// bit for bit).
    pub fn with_kernels(n: usize, kernels: Kernels) -> Self {
        assert!(
            n.is_power_of_two() && n >= 2,
            "RfftPlan requires power-of-two n >= 2, got {n}"
        );
        let w = (0..=n / 2)
            .map(|k| {
                let ang = -2.0 * PI * k as f64 / n as f64;
                C64::new(ang.cos(), ang.sin())
            })
            .collect();
        RfftPlan {
            n,
            half: FftPlan::with_kernels(n / 2, kernels),
            full: FftPlan::with_kernels(n, kernels),
            w,
            kernels,
        }
    }

    /// Real transform length N.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The SIMD kernel set this plan's butterflies dispatch through.
    pub fn kernels(&self) -> Kernels {
        self.kernels
    }

    /// Half-spectrum length N/2 + 1 (bins `0..=N/2`).
    pub fn spectrum_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// The embedded full-length complex plan — the reference-kernel plan for
    /// oracle paths ([`rfft`], [`circular_convolve_fft`], …) so a packed
    /// engine never builds a second set of full-size tables.
    pub fn full(&self) -> &FftPlan {
        &self.full
    }

    /// Packed forward transform: real `x` (len N) → half spectrum `out`
    /// (len N/2+1), using `work` (len N/2) as the pack buffer.  Zero
    /// allocations.
    pub fn rfft_into(&self, x: &[f32], out: &mut [C64], work: &mut [C64]) {
        let h = self.n / 2;
        assert_eq!(x.len(), self.n);
        assert_eq!(out.len(), h + 1);
        assert_eq!(work.len(), h);
        for (wk, p) in work.iter_mut().zip(x.chunks_exact(2)) {
            *wk = C64::new(p[0] as f64, p[1] as f64);
        }
        self.half.forward_into(work);
        for (k, o) in out.iter_mut().enumerate() {
            let zk = work[if k == h { 0 } else { k }];
            let zc = work[(h - k) % h].conj();
            let xe = zk.add(zc).scale(0.5);
            let u = zk.sub(zc);
            // u / 2i = (u.im/2, −u.re/2)
            let xo = C64::new(0.5 * u.im, -0.5 * u.re);
            *o = xe.add(self.w[k].mul(xo));
        }
    }

    /// Packed inverse transform: half spectrum `spec` (len N/2+1, read-only)
    /// → real `out` (len N), using `work` (len N/2) as the merge buffer.
    /// Zero allocations; includes the 1/N normalization.
    pub fn irfft_into(&self, spec: &[C64], out: &mut [f32], work: &mut [C64]) {
        let h = self.n / 2;
        assert_eq!(spec.len(), h + 1);
        assert_eq!(out.len(), self.n);
        assert_eq!(work.len(), h);
        for (k, wk) in work.iter_mut().enumerate() {
            let xk = spec[k];
            let xc = spec[h - k].conj();
            let xe = xk.add(xc).scale(0.5);
            // (xk − xc)/2 = w^k·Xo[k]; undo the twiddle to recover Xo
            let xo = xk.sub(xc).scale(0.5).mul(self.w[k].conj());
            // z[k] = Xe[k] + i·Xo[k]
            *wk = C64::new(xe.re - xo.im, xe.im + xo.re);
        }
        self.half.inverse_into(work);
        for (p, wk) in out.chunks_exact_mut(2).zip(work.iter()) {
            p[0] = wk.re as f32;
            p[1] = wk.im as f32;
        }
    }

    /// Batch inverse — **two real outputs per complex inverse**: reconstruct
    /// rows `a` and `b` from their half spectra `sa`/`sb` with ONE full-size
    /// inverse FFT, by synthesizing `S = A~ + i·B~` (Hermitian extensions)
    /// in `work` (len N) and splitting real/imaginary parts of `IFFT(S)`.
    /// The decode hot path pairs its R per-key inverses through this, so R
    /// unbinds cost ⌈R/2⌉ inverse transforms instead of R.
    pub fn irfft2_into(
        &self,
        sa: &[C64],
        sb: &[C64],
        out_a: &mut [f32],
        out_b: &mut [f32],
        work: &mut [C64],
    ) {
        let (n, h) = (self.n, self.n / 2);
        assert_eq!(sa.len(), h + 1);
        assert_eq!(sb.len(), h + 1);
        assert_eq!(out_a.len(), n);
        assert_eq!(out_b.len(), n);
        assert_eq!(work.len(), n);
        for (j, wk) in work.iter_mut().take(h + 1).enumerate() {
            // S[j] = A[j] + i·B[j]
            *wk = C64::new(sa[j].re - sb[j].im, sa[j].im + sb[j].re);
        }
        for j in (h + 1)..n {
            // Hermitian extension: A~[j] = conj(A[n−j]), same for B
            let a = sa[n - j].conj();
            let b = sb[n - j].conj();
            work[j] = C64::new(a.re - b.im, a.im + b.re);
        }
        self.full.inverse_into(work);
        for ((oa, ob), wv) in out_a.iter_mut().zip(out_b.iter_mut()).zip(work.iter()) {
            *oa = wv.re as f32;
            *ob = wv.im as f32;
        }
    }
}

/// Naive O(n²) DFT — test oracle for the FFT itself.
#[allow(dead_code)]
pub fn dft_naive(x: &[C64], inverse: bool) -> Vec<C64> {
    let n = x.len();
    let sign = if inverse { 2.0 } else { -2.0 };
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let mut acc = C64::new(0.0, 0.0);
        for (j, &v) in x.iter().enumerate() {
            let ang = sign * PI * (k * j) as f64 / n as f64;
            acc = acc.add(v.mul(C64::new(ang.cos(), ang.sin())));
        }
        if inverse {
            acc.re /= n as f64;
            acc.im /= n as f64;
        }
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;
    use crate::util::rng::Rng;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn fft_matches_naive_dft() {
        let mut rng = Rng::new(1);
        for &n in &[2usize, 4, 8, 64, 256] {
            let plan = FftPlan::new(n);
            let x: Vec<C64> = (0..n)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect();
            let mut got = x.clone();
            plan.forward(&mut got);
            let want = dft_naive(&x, false);
            for (g, w) in got.iter().zip(&want) {
                assert!(close(g.re, w.re, 1e-9) && close(g.im, w.im, 1e-9));
            }
        }
    }

    #[test]
    fn inverse_roundtrips() {
        Prop::new("ifft(fft(x)) == x", 30).run(|g| {
            let n = g.pow2_in(1, 10);
            let plan = FftPlan::new(n);
            let x = g.vec_normal(n, 0.0, 1.0);
            let spec = rfft(&plan, &x);
            let back = irfft(&plan, spec);
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn convolution_theorem_matches_direct() {
        Prop::new("fft conv == direct conv", 20).run(|g| {
            let n = g.pow2_in(2, 8);
            let plan = FftPlan::new(n);
            let a = g.vec_normal(n, 0.0, 1.0);
            let b = g.vec_normal(n, 0.0, 1.0);
            let got = circular_convolve_fft(&plan, &a, &b);
            // direct: out[k] = Σ_m a[m] b[(k−m) mod n]
            for k in 0..n {
                let want: f32 = (0..n)
                    .map(|m| a[m] * b[(k + n - m) % n])
                    .sum();
                assert!((got[k] - want).abs() < 1e-3, "n={n} k={k}: {} vs {want}", got[k]);
            }
        });
    }

    #[test]
    fn correlation_matches_direct() {
        Prop::new("fft corr == direct corr", 20).run(|g| {
            let n = g.pow2_in(2, 8);
            let plan = FftPlan::new(n);
            let a = g.vec_normal(n, 0.0, 1.0);
            let b = g.vec_normal(n, 0.0, 1.0);
            let got = circular_correlate_fft(&plan, &a, &b);
            // direct: out[k] = Σ_m a[m] b[(k+m) mod n]
            for k in 0..n {
                let want: f32 = (0..n).map(|m| a[m] * b[(k + m) % n]).sum();
                assert!((got[k] - want).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn delta_convolution_is_identity() {
        let n = 16;
        let plan = FftPlan::new(n);
        let mut delta = vec![0.0f32; n];
        delta[0] = 1.0;
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let y = circular_convolve_fft(&plan, &delta, &x);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        FftPlan::new(12);
    }

    #[test]
    fn scratch_kernel_bit_identical_to_reference() {
        // The whole point of the scratch kernel: same floats, fewer cycles.
        Prop::new("forward_into == forward (bits)", 20).run(|g| {
            let n = g.pow2_in(1, 11);
            let plan = FftPlan::new(n);
            let x: Vec<C64> = g
                .vec_normal(2 * n, 0.0, 1.0)
                .chunks_exact(2)
                .map(|p| C64::new(p[0] as f64, p[1] as f64))
                .collect();
            let mut a = x.clone();
            let mut b = x.clone();
            plan.forward(&mut a);
            plan.forward_into(&mut b);
            for (u, v) in a.iter().zip(&b) {
                assert_eq!(u.re.to_bits(), v.re.to_bits());
                assert_eq!(u.im.to_bits(), v.im.to_bits());
            }
            let mut a = x.clone();
            let mut b = x;
            plan.inverse(&mut a);
            plan.inverse_into(&mut b);
            for (u, v) in a.iter().zip(&b) {
                assert_eq!(u.re.to_bits(), v.re.to_bits());
                assert_eq!(u.im.to_bits(), v.im.to_bits());
            }
        });
    }

    #[test]
    fn rfft_into_matches_rfft_bitwise() {
        Prop::new("rfft_into == rfft (bits)", 20).run(|g| {
            let n = g.pow2_in(1, 10);
            let plan = FftPlan::new(n);
            let x = g.vec_normal(n, 0.0, 1.0);
            let want = rfft(&plan, &x);
            let mut spec = vec![C64::new(0.0, 0.0); n];
            rfft_into(&plan, &x, &mut spec);
            for (u, v) in want.iter().zip(&spec) {
                assert_eq!(u.re.to_bits(), v.re.to_bits());
                assert_eq!(u.im.to_bits(), v.im.to_bits());
            }
            let back_want = irfft(&plan, want);
            let mut back = vec![0.0f32; n];
            irfft_into(&plan, &mut spec, &mut back);
            for (u, v) in back_want.iter().zip(&back) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        });
    }

    #[test]
    fn scratch_buffers_are_reusable() {
        // Steady state: the same scratch buffer across many transforms must
        // not leak state between calls.
        let n = 64;
        let plan = FftPlan::new(n);
        let mut rng = Rng::new(17);
        let mut spec = vec![C64::new(0.0, 0.0); n];
        let mut out = vec![0.0f32; n];
        for _ in 0..5 {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            rfft_into(&plan, &x, &mut spec);
            irfft_into(&plan, &mut spec, &mut out);
            for (a, b) in x.iter().zip(&out) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    // --- packed real-transform kernels ------------------------------------

    use crate::util::testing::{assert_close_slice, DEFAULT_ABS, DEFAULT_REL};

    /// Half spectrum via the packed kernel, allocating scratch (tests only).
    fn packed_rfft(rp: &RfftPlan, x: &[f32]) -> Vec<C64> {
        let mut out = vec![C64::new(0.0, 0.0); rp.spectrum_len()];
        let mut work = vec![C64::new(0.0, 0.0); rp.n() / 2];
        rp.rfft_into(x, &mut out, &mut work);
        out
    }

    #[test]
    fn packed_forward_matches_reference_half_spectrum() {
        // The packed forward must reproduce the reference transform's first
        // N/2+1 bins within tolerance (not bits — different op order).
        Prop::new("packed rfft == reference bins", 25).run(|g| {
            let n = g.pow2_in(1, 11); // 2..=2048
            let rp = RfftPlan::new(n);
            let x = g.vec_normal(n, 0.0, 1.0);
            let want = rfft(rp.full(), &x);
            let got = packed_rfft(&rp, &x);
            assert_eq!(got.len(), n / 2 + 1);
            for (k, (gk, wk)) in got.iter().zip(&want).enumerate() {
                assert!(
                    crate::util::testing::close(gk.re, wk.re, 1e-9, 1e-9)
                        && crate::util::testing::close(gk.im, wk.im, 1e-9, 1e-9),
                    "n={n} bin {k}: ({}, {}) vs ({}, {})",
                    gk.re,
                    gk.im,
                    wk.re,
                    wk.im
                );
            }
        });
    }

    #[test]
    fn packed_inverse_roundtrips() {
        Prop::new("packed irfft(rfft(x)) == x", 25).run(|g| {
            let n = g.pow2_in(1, 11);
            let rp = RfftPlan::new(n);
            let x = g.vec_normal(n, 0.0, 1.0);
            let spec = packed_rfft(&rp, &x);
            let mut back = vec![0.0f32; n];
            let mut work = vec![C64::new(0.0, 0.0); n / 2];
            rp.irfft_into(&spec, &mut back, &mut work);
            assert_close_slice(&x, &back, DEFAULT_REL, DEFAULT_ABS, "packed roundtrip");
        });
    }

    #[test]
    fn packed_pair_inverse_recovers_both_rows() {
        // irfft2: ONE full-size inverse must reconstruct two independent
        // real rows from their half spectra.
        Prop::new("irfft2 recovers (a, b)", 25).run(|g| {
            let n = g.pow2_in(1, 10);
            let rp = RfftPlan::new(n);
            let a = g.vec_normal(n, 0.0, 1.0);
            let b = g.vec_normal(n, 0.0, 1.0);
            let sa = packed_rfft(&rp, &a);
            let sb = packed_rfft(&rp, &b);
            let mut out_a = vec![0.0f32; n];
            let mut out_b = vec![0.0f32; n];
            let mut work = vec![C64::new(0.0, 0.0); n];
            rp.irfft2_into(&sa, &sb, &mut out_a, &mut out_b, &mut work);
            assert_close_slice(&a, &out_a, DEFAULT_REL, DEFAULT_ABS, "irfft2 row a");
            assert_close_slice(&b, &out_b, DEFAULT_REL, DEFAULT_ABS, "irfft2 row b");
        });
    }

    #[test]
    fn packed_kernels_at_n2_are_exact() {
        // Smallest supported size, checked against the closed form:
        // X = [x0+x1, x0−x1].
        let rp = RfftPlan::new(2);
        assert_eq!(rp.spectrum_len(), 2);
        let x = [3.0f32, -1.25];
        let spec = packed_rfft(&rp, &x);
        assert!((spec[0].re - 1.75).abs() < 1e-12 && spec[0].im.abs() < 1e-12);
        assert!((spec[1].re - 4.25).abs() < 1e-12 && spec[1].im.abs() < 1e-12);
        let mut back = [0.0f32; 2];
        let mut work = [C64::new(0.0, 0.0); 1];
        rp.irfft_into(&spec, &mut back, &mut work);
        assert_close_slice(&x, &back, 0.0, 1e-6, "n=2 roundtrip");
    }

    #[test]
    fn packed_scratch_buffers_are_reusable() {
        // Same steady-state contract as the complex scratch kernels: one set
        // of buffers across many transforms, no state leakage.
        let n = 128;
        let rp = RfftPlan::new(n);
        let mut rng = Rng::new(23);
        let mut spec = vec![C64::new(0.0, 0.0); rp.spectrum_len()];
        let mut work = vec![C64::new(0.0, 0.0); n / 2];
        let mut full_work = vec![C64::new(0.0, 0.0); n];
        let mut out = vec![0.0f32; n];
        let mut out_b = vec![0.0f32; n];
        for _ in 0..5 {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            rp.rfft_into(&x, &mut spec, &mut work);
            rp.irfft_into(&spec, &mut out, &mut work);
            assert_close_slice(&x, &out, DEFAULT_REL, DEFAULT_ABS, "reuse roundtrip");
            rp.irfft2_into(&spec, &spec, &mut out, &mut out_b, &mut full_work);
            assert_close_slice(&x, &out, DEFAULT_REL, DEFAULT_ABS, "reuse irfft2 a");
            assert_close_slice(&x, &out_b, DEFAULT_REL, DEFAULT_ABS, "reuse irfft2 b");
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two n >= 2")]
    fn packed_rejects_length_one() {
        RfftPlan::new(1);
    }

    #[test]
    #[should_panic(expected = "power-of-two n >= 2")]
    fn packed_rejects_non_pow2() {
        RfftPlan::new(12);
    }

    #[test]
    fn parseval_energy_preserved() {
        let n = 128;
        let plan = FftPlan::new(n);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let spec = rfft(&plan, &x);
        let time_e: f64 = x.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        let freq_e: f64 =
            spec.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / n as f64;
        assert!(close(time_e, freq_e, 1e-9));
    }
}
