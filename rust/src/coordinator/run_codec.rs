//! RunCodec: the codec venue abstraction the workers use on both directions.
//!
//! * `None`      — vanilla SL and BottleNet++ (whose codec is inside the
//!                 model artifacts): tensors pass through untouched.
//! * `Host`      — rust-native hdc implementation (FFT/direct), no XLA call.
//! * `Artifact`  — the AOT-lowered Pallas kernels through PJRT.
//!
//! Host and Artifact venues must agree numerically when fed the same keys;
//! rust/tests/integration.rs checks exactly that.

use crate::compress::{C3Codec, Codec};
use crate::hdc::{Backend, FftBackend, KeySet};
use crate::runtime::{CodecRuntime, Engine};
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// The codec venue a run compresses through (both directions).
pub enum RunCodec {
    /// Identity: vanilla SL and BottleNet++ (codec inside the model).
    None,
    /// rust-native hdc implementation (FFT or direct).
    Host(C3Codec),
    /// AOT-lowered Pallas kernels through PJRT.
    Artifact(CodecRuntime),
}

impl RunCodec {
    /// Host venue: keys from the (deterministic) rust PRNG at `seed`,
    /// group-parallel across `workers` threads (1 = serial), on the
    /// reference FFT kernels.
    pub fn host(seed: u64, r: usize, d: usize, workers: usize) -> Self {
        Self::host_with(seed, r, d, workers, FftBackend::default())
    }

    /// [`RunCodec::host`] with an explicit FFT kernel family
    /// (`scheme.fft_backend`): `FftBackend::Packed` runs the half-spectrum
    /// kernels on power-of-two D.
    pub fn host_with(seed: u64, r: usize, d: usize, workers: usize, fft: FftBackend) -> Self {
        let mut rng = Rng::new(seed);
        RunCodec::Host(C3Codec::with_backends(
            KeySet::generate(&mut rng, r, d),
            Backend::Auto,
            fft,
            workers,
        ))
    }

    /// Artifact venue: keys from the gen_keys artifact at `seed`.
    pub fn artifact(engine: &Engine, dir: &str, seed: u64) -> Result<Self> {
        let mut rt = CodecRuntime::load(engine, dir)?;
        rt.init_keys(seed)?;
        Ok(RunCodec::Artifact(rt))
    }

    /// The host C3 engine, when this codec runs in the host venue.  Lets the
    /// reactor cloud's worker pool drive the zero-allocation
    /// `encode_into`/`decode_into` path with per-worker scratch instead of
    /// the allocating [`RunCodec::encode`]/[`RunCodec::decode`] wrappers.
    pub fn host_engine(&self) -> Option<&crate::hdc::C3> {
        match self {
            RunCodec::Host(c) => Some(c.engine()),
            _ => None,
        }
    }

    /// Human-readable venue/scheme label for logs and reports.
    pub fn name(&self) -> String {
        match self {
            RunCodec::None => "none".into(),
            RunCodec::Host(c) => format!("host/{}", c.name()),
            RunCodec::Artifact(rt) => {
                format!("artifact/c3-r{} ({})", rt.r(), rt.manifest.kernel)
            }
        }
    }

    /// Nominal compression ratio R (1 for the identity venue).
    pub fn ratio(&self) -> usize {
        match self {
            RunCodec::None => 1,
            RunCodec::Host(c) => c.r(),
            RunCodec::Artifact(rt) => rt.r(),
        }
    }

    /// Compress a (B, D) feature/gradient batch to its wire form.
    pub fn encode(&self, z: &Tensor) -> Result<Tensor> {
        match self {
            RunCodec::None => Ok(z.clone()),
            RunCodec::Host(c) => Ok(Codec::encode(c, z)),
            RunCodec::Artifact(rt) => rt.encode(z),
        }
    }

    /// Reconstruct a (B, D) batch from its compressed wire form.
    pub fn decode(&self, s: &Tensor) -> Result<Tensor> {
        match self {
            RunCodec::None => Ok(s.clone()),
            RunCodec::Host(c) => Ok(Codec::decode(c, s)),
            RunCodec::Artifact(rt) => rt.decode(s),
        }
    }
}
