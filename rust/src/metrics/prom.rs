//! Prometheus text exposition writer for the ops `/metrics` endpoint.
//!
//! Implements exactly the subset of the text format (version 0.0.4) the
//! control plane serves: `# HELP` / `# TYPE` headers, counter/gauge samples
//! with optional labels, and full histogram families (`_bucket` with
//! cumulative `le` labels incl. `+Inf`, `_sum`, `_count`).  Std-only, like
//! the rest of the repo — no client library, just careful string assembly
//! with the escaping rules the format requires.

use std::fmt::Write as _;

use super::Histogram;

/// Incremental builder for one exposition payload.  `family` writes the
/// HELP/TYPE header, then any number of `sample` calls add series; call
/// [`PromWriter::finish`] for the final body.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    /// Empty payload.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a metric family: writes the `# HELP` and `# TYPE` lines.
    /// `kind` is the Prometheus type name (`counter`, `gauge`, `histogram`).
    pub fn family(&mut self, name: &str, help: &str, kind: &str) {
        let _ = writeln!(self.out, "# HELP {name} {}", escape_help(help));
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    /// One sample line: `name{labels} value`.  Labels are `(key, value)`
    /// pairs; pass `&[]` for an unlabelled series.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                let _ = write!(self.out, "{k}=\"{}\"", escape_label(v));
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// Header + single unlabelled sample, for the common counter case.
    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.family(name, help, "counter");
        self.sample(name, &[], value as f64);
    }

    /// Header + single unlabelled sample, for the common gauge case.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.family(name, help, "gauge");
        self.sample(name, &[], value);
    }

    /// A full histogram family from a [`Histogram`] snapshot: cumulative
    /// `_bucket` series per bound plus `+Inf`, then `_sum` and `_count`.
    pub fn histogram(&mut self, name: &str, help: &str, h: &Histogram) {
        self.family(name, help, "histogram");
        let bucket = format!("{name}_bucket");
        let mut acc = 0u64;
        for (i, bound) in h.bounds().iter().enumerate() {
            acc += h.counts()[i];
            let le = fmt_value(*bound);
            self.sample(&bucket, &[("le", &le)], acc as f64);
        }
        // overflow bucket: cumulative count over everything
        self.sample(&bucket, &[("le", "+Inf")], h.total as f64);
        // an empty histogram's sum is 0, not the NaN min+max would suggest
        let sum = if h.total == 0 { 0.0 } else { h.sum };
        self.sample(&format!("{name}_sum"), &[], sum);
        self.sample(&format!("{name}_count"), &[], h.total as f64);
    }

    /// The assembled exposition body.
    pub fn finish(self) -> String {
        self.out
    }
}

/// Sample-value formatting: integers render without a fractional part
/// (Prometheus accepts both; the compact form diffs cleanly in tests),
/// non-finite values use the spec's `NaN` / `+Inf` / `-Inf` spellings.
fn fmt_value(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// HELP text escaping per the format: backslash and newline.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Label value escaping per the format: backslash, double-quote, newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render() {
        let mut w = PromWriter::new();
        w.counter("c3sl_steps_total", "Steps completed.", 42);
        w.gauge("c3sl_clients_active", "Open connections.", 3.0);
        let body = w.finish();
        assert!(body.contains("# HELP c3sl_steps_total Steps completed.\n"));
        assert!(body.contains("# TYPE c3sl_steps_total counter\n"));
        assert!(body.contains("\nc3sl_steps_total 42\n"));
        assert!(body.contains("# TYPE c3sl_clients_active gauge\n"));
        assert!(body.contains("\nc3sl_clients_active 3\n"));
    }

    #[test]
    fn labels_and_escaping() {
        let mut w = PromWriter::new();
        w.family("x", "with \\ and\nnewline", "gauge");
        w.sample("x", &[("shard", "3"), ("who", "a\"b")], 1.0);
        let body = w.finish();
        assert!(body.contains("# HELP x with \\\\ and\\nnewline\n"));
        assert!(body.contains("x{shard=\"3\",who=\"a\\\"b\"} 1\n"));
    }

    #[test]
    fn histogram_family_is_cumulative_with_inf() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]);
        // all binary-exact values so the _sum assertion is representation-safe
        for x in [0.5, 1.5, 1.75, 3.0, 8.0] {
            h.observe(x);
        }
        let mut w = PromWriter::new();
        w.histogram("lat", "Latency.", &h);
        let body = w.finish();
        assert!(body.contains("# TYPE lat histogram\n"));
        assert!(body.contains("lat_bucket{le=\"1\"} 1\n"));
        assert!(body.contains("lat_bucket{le=\"2\"} 3\n"));
        assert!(body.contains("lat_bucket{le=\"4\"} 4\n"));
        assert!(body.contains("lat_bucket{le=\"+Inf\"} 5\n"));
        assert!(body.contains("lat_count 5\n"));
        assert!(body.contains("lat_sum 14.75\n"));
    }

    #[test]
    fn empty_histogram_sum_is_zero() {
        let h = Histogram::new(vec![1.0]);
        let mut w = PromWriter::new();
        w.histogram("lat", "Latency.", &h);
        let body = w.finish();
        assert!(body.contains("lat_bucket{le=\"+Inf\"} 0\n"));
        assert!(body.contains("lat_sum 0\n"));
        assert!(body.contains("lat_count 0\n"));
    }

    #[test]
    fn nonfinite_values_use_spec_spellings() {
        assert_eq!(fmt_value(f64::NAN), "NaN");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(2.5), "2.5");
        assert_eq!(fmt_value(-7.0), "-7");
    }
}
