//! EdgeWorker: owns f_theta, the training data, and the edge half of the
//! codec.  Drives the training loop (it is the data owner, as in the paper's
//! SL formulation) and records all metrics.

use super::run_codec::RunCodec;
use crate::bail;
use crate::config::ExperimentConfig;
use crate::data::{Batch, Dataset, Loader};
use crate::metrics::{RunRecorder, StepRecord};
use crate::runtime::xla_stub as xla;
use crate::runtime::{AdamState, Engine, ModelRuntime};
use crate::transport::{Msg, Transport};
use crate::util::error::{Context, Result};
use crate::util::timer::Timer;

/// The edge actor: f_theta, its optimizer state, the training data loader's
/// geometry, and the edge half of the codec.
pub struct EdgeWorker {
    model: ModelRuntime,
    codec: RunCodec,
    params: Vec<xla::Literal>,
    adam: AdamState,
    lr: f32,
}

impl EdgeWorker {
    /// Build the edge side: engine, artifacts, params, codec.
    pub fn new(engine: &Engine, cfg: &ExperimentConfig) -> Result<Self> {
        let model = ModelRuntime::load(engine, cfg.model_dir())
            .context("loading edge model artifacts")?;
        let codec = build_codec(engine, cfg, /*role=*/ "edge")?;
        let params = model.edge_init(cfg.seed)?;
        let adam = AdamState::zeros_like(&params)?;
        Ok(EdgeWorker { model, codec, params, adam, lr: cfg.lr })
    }

    /// Batch size B the model artifacts were lowered for.
    pub fn batch_size(&self) -> usize {
        self.model.manifest.batch
    }

    /// Flattened cut-layer feature dimensionality D.
    pub fn d_tx(&self) -> usize {
        self.model.manifest.d_tx
    }

    /// Run `steps` training steps against the cloud over `transport`,
    /// evaluating on `test` every `eval_every` steps.  Consumes the
    /// transport's message protocol documented in coordinator/mod.rs.
    pub fn run(
        &mut self,
        transport: &mut dyn Transport,
        train: &dyn Dataset,
        test: &dyn Dataset,
        cfg: &ExperimentConfig,
    ) -> Result<RunRecorder> {
        let mut rec = RunRecorder::new();
        let mut loader = Loader::new(train, self.batch_size(), cfg.seed ^ 0xDA7A, cfg.augment);
        let eval_batches = Loader::eval_batches(test, self.batch_size());
        let stats = transport.stats();

        // Key agreement: tell the cloud which seed to derive the codec keys
        // from (the keys themselves never cross the wire).
        transport.send(&Msg::KeySeed { seed: key_seed(cfg) })?;

        for step in 0..cfg.steps as u64 {
            let t = Timer::start();
            let tx0 = stats.tx();
            let rx0 = stats.rx();

            let batch = loader.next_batch();
            let z = self.model.edge_fwd(&self.params, &batch.images)?;
            let s = self.codec.encode(&z)?;
            transport.send(&Msg::Features { step, tensor: s })?;
            transport.send(&Msg::TrainLabels { step, labels: batch.labels.clone() })?;

            // Downlink: compressed gradients + step stats.
            let gs = match transport.recv()? {
                Msg::Gradients { step: gstep, tensor } => {
                    if gstep != step {
                        bail!("gradient step mismatch: {gstep} != {step}");
                    }
                    tensor
                }
                other => bail!("edge expected Gradients, got {other:?}"),
            };
            let (loss, ncorrect) = match transport.recv()? {
                Msg::StepStats { loss, ncorrect, .. } => (loss, ncorrect),
                other => bail!("edge expected StepStats, got {other:?}"),
            };

            let gz = self.codec.decode(&gs)?;
            let grads = self.model.edge_bwd(&self.params, &batch.images, &gz)?;
            let params = std::mem::take(&mut self.params);
            self.params = self.model.edge_adam(params, &grads, &mut self.adam, self.lr)?;

            rec.record(StepRecord {
                step: step as usize,
                loss: loss as f64,
                acc: ncorrect as f64 / self.batch_size() as f64,
                uplink_bytes: stats.tx() - tx0,
                downlink_bytes: stats.rx() - rx0,
                step_seconds: t.elapsed_secs(),
            });

            let is_last = step as usize + 1 == cfg.steps;
            if cfg.eval_every > 0 && ((step as usize + 1) % cfg.eval_every == 0 || is_last) {
                let (eloss, eacc) =
                    self.evaluate(transport, &eval_batches[..cfg.eval_batches.min(eval_batches.len())], step)?;
                rec.record_eval(step as usize, eloss, eacc);
            }
        }
        transport.send(&Msg::Shutdown)?;
        rec.set_scalar("d_tx", self.d_tx() as f64);
        rec.set_scalar("ratio", self.codec.ratio() as f64);
        Ok(rec)
    }

    /// Evaluate through the full compressed pipeline (codec in place, as the
    /// paper does: the codec IS part of the deployed model).
    fn evaluate(
        &mut self,
        transport: &mut dyn Transport,
        batches: &[Batch],
        step: u64,
    ) -> Result<(f64, f64)> {
        let mut total_loss = 0.0f64;
        let mut total_correct = 0.0f64;
        let mut total_n = 0usize;
        for b in batches {
            let z = self.model.edge_fwd(&self.params, &b.images)?;
            let s = self.codec.encode(&z)?;
            transport.send(&Msg::EvalFeatures {
                step,
                tensor: s,
                labels: b.labels.clone(),
            })?;
            match transport.recv()? {
                Msg::EvalStats { loss, ncorrect, .. } => {
                    total_loss += loss as f64;
                    total_correct += ncorrect as f64;
                    total_n += b.labels.len();
                }
                other => bail!("edge expected EvalStats, got {other:?}"),
            }
        }
        let nb = batches.len().max(1) as f64;
        Ok((total_loss / nb, total_correct / total_n.max(1) as f64))
    }
}

/// Codec construction shared by both workers.
pub(crate) fn build_codec(engine: &Engine, cfg: &ExperimentConfig, role: &str) -> Result<RunCodec> {
    use crate::config::{CodecVenue, SchemeKind};
    Ok(match cfg.scheme {
        SchemeKind::Vanilla | SchemeKind::BottleNetPP { .. } => RunCodec::None,
        SchemeKind::C3 { r } => match cfg.codec_venue {
            CodecVenue::Artifact => {
                let dir = cfg
                    .codec_dir()
                    .context("C3 scheme requires a codec artifact dir")?;
                RunCodec::artifact(engine, &dir, key_seed(cfg))
                    .with_context(|| format!("loading {role} codec from {dir}"))?
            }
            CodecVenue::Host => {
                // d_tx comes from the model manifest; read it cheaply.
                let manifest = crate::runtime::ModelManifest::load(cfg.model_dir())?;
                RunCodec::host_with(
                    key_seed(cfg),
                    r,
                    manifest.d_tx,
                    cfg.codec_workers,
                    cfg.fft_backend,
                )
            }
        },
    })
}

/// The key seed both sides derive the fixed key set from.
pub(crate) fn key_seed(cfg: &ExperimentConfig) -> u64 {
    cfg.seed ^ 0xC3_C3_C3_C3u64
}
