//! Tiny CSV writer for loss curves and bench tables.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// A buffered CSV file with a fixed column count, checked (debug builds)
/// against every row's arity.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create (or truncate) `path`, creating parent directories as needed,
    /// and write the header row.  The header's length fixes the column
    /// count for the file.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    /// Write one pre-stringified row (must match the header's arity).
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        writeln!(self.w, "{}", fields.join(","))
    }

    /// Write one numeric row (each field formatted with `{}`).
    pub fn row_f64(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|x| format!("{x}")).collect();
        self.row(&strs)
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let path = std::env::temp_dir().join("c3sl_csv_test.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row_f64(&[0.0, 2.5]).unwrap();
            w.row_f64(&[1.0, 2.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,loss");
        assert_eq!(lines.len(), 3);
        std::fs::remove_file(&path).ok();
    }
}
