# L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).
#
# The Pallas kernels implement direct tiled-circulant contraction; the FFT
# oracle uses the convolution theorem; the roll oracle is a literal Eq. (1)/
# Eq. (3) transcription.  Agreement across all three is the core correctness
# signal for the codec.  Hypothesis sweeps shapes/dtypes/tiles.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import circconv, ref

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else dict(rtol=5e-2, atol=5e-2)


def _rand(shape, seed, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Fixed-shape golden checks
# ---------------------------------------------------------------------------

class TestEncodeDecodeGolden:
    def test_encode_matches_fft_oracle(self):
        keys = ref.generate_keys(jax.random.PRNGKey(0), 4, 256)
        z = _rand((2, 4, 256), 1)
        np.testing.assert_allclose(
            circconv.c3_encode(z, keys), ref.encode_ref(z, keys), **_tol(jnp.float32))

    def test_decode_matches_fft_oracle(self):
        keys = ref.generate_keys(jax.random.PRNGKey(0), 4, 256)
        s = _rand((2, 256), 2)
        np.testing.assert_allclose(
            circconv.c3_decode(s, keys), ref.decode_ref(s, keys), **_tol(jnp.float32))

    def test_encode_matches_roll_oracle(self):
        keys = ref.generate_keys(jax.random.PRNGKey(3), 3, 128)
        z = _rand((1, 3, 128), 4)
        s_roll = sum(ref.circ_conv_roll(keys[i], z[0, i]) for i in range(3))
        np.testing.assert_allclose(
            circconv.c3_encode(z, keys)[0], s_roll, **_tol(jnp.float32))

    def test_decode_matches_roll_oracle(self):
        keys = ref.generate_keys(jax.random.PRNGKey(5), 3, 128)
        s = _rand((1, 128), 6)
        zh = circconv.c3_decode(s, keys)
        for i in range(3):
            np.testing.assert_allclose(
                zh[0, i], ref.circ_corr_roll(keys[i], s[0]), **_tol(jnp.float32))

    def test_r1_delta_key_roundtrip_is_identity(self):
        # The delta key pins down index conventions exactly:
        # delta ⊛ z = z and delta ⋆ s = s.
        d = 64
        delta = jnp.zeros((1, d)).at[0, 0].set(1.0)
        z = _rand((2, 1, d), 7)
        s = circconv.c3_encode(z, delta)
        np.testing.assert_allclose(s, z[:, 0, :], rtol=1e-5, atol=1e-5)
        zh = circconv.c3_decode(s, delta)
        np.testing.assert_allclose(zh, z, rtol=1e-5, atol=1e-5)

    def test_shift_key_rotates(self):
        # Binding with a one-hot key at position p circularly shifts z by p.
        d, p = 32, 5
        key = jnp.zeros((1, d)).at[0, p].set(1.0)
        z = _rand((1, 1, d), 8)
        s = circconv.c3_encode(z, key)
        np.testing.assert_allclose(s[0], jnp.roll(z[0, 0], p), rtol=1e-5, atol=1e-5)
        zh = circconv.c3_decode(s, key)
        np.testing.assert_allclose(zh[0, 0], z[0, 0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Algebraic properties
# ---------------------------------------------------------------------------

class TestAlgebra:
    def test_linearity_of_encode(self):
        keys = ref.generate_keys(jax.random.PRNGKey(0), 2, 128)
        z1, z2 = _rand((1, 2, 128), 1), _rand((1, 2, 128), 2)
        a, b = 0.7, -1.3
        lhs = circconv.c3_encode(a * z1 + b * z2, keys)
        rhs = a * circconv.c3_encode(z1, keys) + b * circconv.c3_encode(z2, keys)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)

    def test_adjointness_encode_decode(self):
        # <E(z), s> == <z, D(s)>: decode is the transpose of encode.  This is
        # the identity that makes distributed gradient compression exact
        # (DESIGN.md §1).
        keys = ref.generate_keys(jax.random.PRNGKey(1), 4, 256)
        z = _rand((2, 4, 256), 3)
        s = _rand((2, 256), 4)
        lhs = jnp.vdot(circconv.c3_encode(z, keys), s)
        rhs = jnp.vdot(z, circconv.c3_decode(s, keys))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)

    def test_autodiff_vjp_equals_manual_adjoint(self):
        # jax VJP through encode == decode applied to the cotangent.
        keys = ref.generate_keys(jax.random.PRNGKey(2), 3, 128)
        z = _rand((2, 3, 128), 5)
        ct = _rand((2, 128), 6)
        _, vjp = jax.vjp(lambda zz: ref.encode_ref(zz, keys), z)
        np.testing.assert_allclose(
            vjp(ct)[0], ref.decode_ref(ct, keys), rtol=1e-4, atol=1e-4)

    def test_crosstalk_decomposition_is_exact(self):
        # Eq. (4): decode(encode(z)) == self_term + cross_term.
        keys = ref.generate_keys(jax.random.PRNGKey(3), 4, 256)
        z = _rand((2, 4, 256), 7)
        zh = ref.encode_decode_ref(z, keys)
        self_t, cross_t = ref.crosstalk_decomposition(z, keys)
        np.testing.assert_allclose(zh, self_t + cross_t, rtol=1e-4, atol=1e-4)

    def test_crosstalk_energy_grows_with_r(self):
        # Quasi-orthogonality: crosstalk-to-signal energy rises with R.
        d = 1024
        energies = []
        for r in (2, 8, 32):
            keys = ref.generate_keys(jax.random.PRNGKey(4), r, d)
            z = _rand((1, r, d), 8)
            _, cross = ref.crosstalk_decomposition(z, keys)
            energies.append(float(jnp.linalg.norm(cross) / jnp.linalg.norm(z)))
        assert energies[0] < energies[1] < energies[2], energies

    def test_keys_are_unit_norm(self):
        keys = ref.generate_keys(jax.random.PRNGKey(5), 16, 2048)
        np.testing.assert_allclose(
            jnp.linalg.norm(keys, axis=-1), jnp.ones(16), rtol=1e-5, atol=1e-5)

    def test_keys_quasi_orthogonal(self):
        keys = ref.generate_keys(jax.random.PRNGKey(6), 16, 4096)
        gram = keys @ keys.T
        off = gram - jnp.diag(jnp.diag(gram))
        # random unit vectors in D=4096: |<k_i,k_j>| ~ 1/sqrt(D) ≈ 0.016
        assert float(jnp.abs(off).max()) < 0.1


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, dtypes, tiles
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    g=st.integers(1, 4),
    r=st.sampled_from([1, 2, 3, 4, 8]),
    logd=st.integers(5, 9),               # D ∈ {32 … 512}
    tile=st.sampled_from([32, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_encode_hypothesis(g, r, logd, tile, seed):
    d = 1 << logd
    keys = ref.generate_keys(jax.random.PRNGKey(seed), r, d)
    z = _rand((g, r, d), seed % 1000 + 1)
    np.testing.assert_allclose(
        circconv.c3_encode(z, keys, tile=tile), ref.encode_ref(z, keys),
        rtol=5e-4, atol=5e-4)


@settings(max_examples=25, deadline=None)
@given(
    g=st.integers(1, 4),
    r=st.sampled_from([1, 2, 4, 8]),
    logd=st.integers(5, 9),
    tile=st.sampled_from([32, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_hypothesis(g, r, logd, tile, seed):
    d = 1 << logd
    keys = ref.generate_keys(jax.random.PRNGKey(seed), r, d)
    s = _rand((g, d), seed % 1000 + 1)
    np.testing.assert_allclose(
        circconv.c3_decode(s, keys, tile=tile), ref.decode_ref(s, keys),
        rtol=5e-4, atol=5e-4)


@settings(max_examples=8, deadline=None)
@given(
    logd=st.integers(6, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_correlates_hypothesis(logd, seed):
    # Reconstruction correlates positively with the original for modest R.
    d = 1 << logd
    r = 2
    keys = ref.generate_keys(jax.random.PRNGKey(seed), r, d)
    z = _rand((1, r, d), seed % 1000 + 1)
    zh = circconv.c3_decode(circconv.c3_encode(z, keys), keys)
    cos = jnp.vdot(z, zh) / (jnp.linalg.norm(z) * jnp.linalg.norm(zh))
    assert float(cos) > 0.15, float(cos)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    keys = ref.generate_keys(jax.random.PRNGKey(0), 2, 128, dtype=dtype)
    z = _rand((1, 2, 128), 1, dtype)
    s = circconv.c3_encode(z, keys)
    assert s.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(s, dtype=np.float32),
        np.asarray(ref.encode_ref(z.astype(jnp.float32), keys.astype(jnp.float32))),
        **_tol(dtype))


@settings(max_examples=15, deadline=None)
@given(
    g=st.integers(1, 4),
    r=st.sampled_from([1, 2, 4]),
    logd=st.integers(5, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_and_matmul_variants_agree(g, r, logd, seed):
    # v1 (per-feature matvec) and v2 (circulant-tile matmul, MXU-batched)
    # are different tilings of the same math — they must agree exactly.
    d = 1 << logd
    keys = ref.generate_keys(jax.random.PRNGKey(seed), r, d)
    z = _rand((g, r, d), seed % 1000 + 1)
    np.testing.assert_allclose(
        circconv.c3_encode(z, keys, variant="matvec"),
        circconv.c3_encode(z, keys, variant="matmul"),
        rtol=2e-4, atol=2e-4)
    s = _rand((g, d), seed % 1000 + 2)
    np.testing.assert_allclose(
        circconv.c3_decode(s, keys, variant="matvec"),
        circconv.c3_decode(s, keys, variant="matmul"),
        rtol=2e-4, atol=2e-4)


def test_non_pow2_d_tile_fallback():
    # D=96 is not a power of two; pick_tile must find a divisor.
    d = 96
    assert d % circconv.pick_tile(d) == 0
    keys = ref.generate_keys(jax.random.PRNGKey(0), 2, d)
    z = _rand((2, 2, d), 1)
    np.testing.assert_allclose(
        circconv.c3_encode(z, keys), ref.encode_ref(z, keys), rtol=5e-4, atol=5e-4)
