# L2 split-step tests.  The critical one is gradient equivalence: the
# DISTRIBUTED pipeline (edge_fwd → encode → decode → cloud_step →
# encode(grads) → decode(grads) → edge_bwd), which is what the rust
# coordinator executes, must match the paper's single-process Algorithm 1
# (one loss.backward() through the whole graph) exactly.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M, split
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny():
    (cfg,) = M.resolve("vggt_b32")
    edge, cloud, d_tx, _ = cfg.build()
    rng = jax.random.PRNGKey(0)
    ep, eo = edge.init(rng, (3, 16, 16))
    cp, _ = cloud.init(jax.random.PRNGKey(1), eo)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 3, 16, 16))
    y = jax.random.randint(jax.random.PRNGKey(3), (32,), 0, 10)
    return cfg, edge, cloud, ep, cp, x, y, d_tx


def _distributed_c3_step(edge, cloud, ep, cp, keys, x, y, r, d):
    """Exactly the message flow the rust coordinator drives."""
    b = x.shape[0]
    g = b // r
    # edge
    z = edge.apply(ep, x)                                   # edge_fwd
    s = ref.encode_ref(z.reshape(g, r, d), keys)            # c3_encode  → uplink
    # cloud
    zhat = ref.decode_ref(s, keys).reshape(b, d)            # c3_decode

    def loss_fn(p, zz):
        logits = cloud.apply(p, zz)
        return split.xent_and_ncorrect(logits, zz_y)[0]

    zz_y = y
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, zhat)
    gcloud, gzhat = grads
    gs = ref.encode_ref(gzhat.reshape(g, r, d), keys)       # c3_encode  → downlink
    # edge
    gz = ref.decode_ref(gs, keys).reshape(b, d)             # c3_decode
    _, vjp = jax.vjp(lambda p: edge.apply(p, x), ep)
    (gedge,) = vjp(gz)
    return loss, gedge, gcloud


class TestGradientEquivalence:
    @pytest.mark.parametrize("r", [2, 4, 8])
    def test_distributed_equals_singleprocess(self, tiny, r):
        cfg, edge, cloud, ep, cp, x, y, d = tiny
        keys = ref.generate_keys(jax.random.PRNGKey(7), r, d)
        loss1, nc1, ge1, gc1 = split.singleprocess_c3_step(
            edge, cloud, ep, cp, keys, x, y, r)
        loss2, ge2, gc2 = _distributed_c3_step(
            edge, cloud, ep, cp, keys, x, y, r, d)
        np.testing.assert_allclose(loss1, loss2, rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(ge1),
                        jax.tree_util.tree_leaves(ge2)):
            np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-5)
        for a, b in zip(jax.tree_util.tree_leaves(gc1),
                        jax.tree_util.tree_leaves(gc2)):
            np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-5)


class TestFlatWrappers:
    def test_edge_fwd_flat_matches_apply(self, tiny):
        cfg, edge, cloud, ep, cp, x, y, d = tiny
        leaves, tree = split.flatten_spec(ep)
        fwd = split.make_edge_fwd(edge, tree, len(leaves))
        (z_flat,) = fwd(*leaves, x)
        np.testing.assert_allclose(z_flat, edge.apply(ep, x), rtol=1e-6)

    def test_cloud_step_outputs(self, tiny):
        cfg, edge, cloud, ep, cp, x, y, d = tiny
        z = edge.apply(ep, x)
        leaves, tree = split.flatten_spec(cp)
        step = split.make_cloud_step(cloud, tree, len(leaves))
        outs = step(*leaves, z, y)
        loss, nc = outs[0], outs[1]
        gleaves, gz = outs[2:-1], outs[-1]
        assert len(gleaves) == len(leaves)
        assert gz.shape == z.shape
        assert 0.0 <= float(nc) <= 32.0
        assert float(loss) > 0.0

    def test_edge_bwd_matches_vjp(self, tiny):
        cfg, edge, cloud, ep, cp, x, y, d = tiny
        gz = jax.random.normal(jax.random.PRNGKey(9), (32, d))
        leaves, tree = split.flatten_spec(ep)
        bwd = split.make_edge_bwd(edge, tree, len(leaves))
        gleaves = bwd(*leaves, x, gz)
        _, vjp = jax.vjp(lambda p: edge.apply(p, x), ep)
        (want,) = vjp(gz)
        for a, b in zip(gleaves, jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


class TestAdam:
    def test_adam_single_param_matches_closed_form(self):
        adam = split.make_adam(1)
        p = jnp.array([1.0, 2.0])
        g = jnp.array([0.5, -0.5])
        m = jnp.zeros(2)
        v = jnp.zeros(2)
        step = jnp.array(0.0)
        lr = jnp.array(0.1)
        new_p, new_m, new_v = adam(p, g, m, v, step, lr)
        # closed form for t=1: mhat = g, vhat = g^2 → update = -lr*g/(|g|+eps)
        want = p - 0.1 * jnp.sign(g)
        np.testing.assert_allclose(new_p, want, rtol=1e-4)
        np.testing.assert_allclose(new_m, 0.1 * g, rtol=1e-6)
        np.testing.assert_allclose(new_v, 0.001 * g * g, rtol=1e-4)

    def test_adam_decreases_quadratic(self):
        # Minimize f(p) = |p|^2 with Adam for a few steps.
        adam = split.make_adam(1)
        p = jnp.array([3.0, -2.0])
        m = jnp.zeros(2)
        v = jnp.zeros(2)
        lr = jnp.array(0.2)
        for t in range(50):
            g = 2.0 * p
            p, m, v = adam(p, g, m, v, jnp.array(float(t)), lr)
        assert float(jnp.abs(p).max()) < 1.0


class TestTrainingSmoke:
    def test_loss_decreases_singleprocess(self, tiny):
        # A few Adam steps on one batch must reduce the C3-SL loss (R=4).
        cfg, edge, cloud, ep, cp, x, y, d = tiny
        keys = ref.generate_keys(jax.random.PRNGKey(11), 4, d)
        eleaves, etree = split.flatten_spec(ep)
        cleaves, ctree = split.flatten_spec(cp)
        eadam = split.make_adam(len(eleaves))
        cadam = split.make_adam(len(cleaves))
        em = [jnp.zeros_like(l) for l in eleaves]
        ev = [jnp.zeros_like(l) for l in eleaves]
        cm = [jnp.zeros_like(l) for l in cleaves]
        cv = [jnp.zeros_like(l) for l in cleaves]
        lr = jnp.array(1e-3)

        @jax.jit
        def one_step(eleaves, cleaves, em, ev, cm, cv, t):
            ep_ = jax.tree_util.tree_unflatten(etree, eleaves)
            cp_ = jax.tree_util.tree_unflatten(ctree, cleaves)
            loss, nc, ge, gc = split.singleprocess_c3_step(
                edge, cloud, ep_, cp_, keys, x, y, 4)
            geleaves = jax.tree_util.tree_leaves(ge)
            gcleaves = jax.tree_util.tree_leaves(gc)
            eout = eadam(*eleaves, *geleaves, *em, *ev, t, lr)
            cout = cadam(*cleaves, *gcleaves, *cm, *cv, t, lr)
            n = len(eleaves)
            k = len(cleaves)
            return (loss, list(eout[:n]), list(cout[:k]),
                    list(eout[n:2 * n]), list(eout[2 * n:]),
                    list(cout[k:2 * k]), list(cout[2 * k:]))

        losses = []
        for t in range(8):
            loss, eleaves, cleaves, em, ev, cm, cv = one_step(
                eleaves, cleaves, em, ev, cm, cv, jnp.array(float(t)))
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
