//! Deterministic fault injection: adversarial network shapes for chaos tests.
//!
//! Every link this repo serves over in production crosses radio hops, load
//! balancers and congested uplinks; every link the test suite exercised
//! before this module crossed a clean mpsc channel or a loopback socket.
//! [`FaultyLink`] (blocking [`Transport`]) and [`FaultyConn`] (nonblocking
//! [`ReactorConn`]) wrap a real endpoint and impair it according to a
//! per-direction [`Impairments`] matrix: latency/jitter, probabilistic and
//! burst frame drop, detected corruption and truncation, mid-stream
//! disconnects, slow-loris pacing, and bandwidth caps.
//!
//! # Determinism
//!
//! The entire fault schedule is a pure function of `(seed, matrix, frame
//! index)`.  Both wrappers fork one [`Rng`] stream per direction with fixed
//! tags, and every frame draws the same fixed sequence of rolls (drop,
//! corrupt, truncate, truncation cut, jitter) whether or not the matching
//! impairment is enabled — so enabling one impairment never shifts a
//! sibling's schedule, and two links built from the same seed and matrix
//! produce bit-identical [`FaultEvent`] logs.  The chaos harness
//! (`util::chaos`) prints the seed on every run and embeds it in every
//! assertion failure, so a red chaos test reproduces exactly.
//!
//! # Detected corruption, by design
//!
//! The wire format carries no checksum (the transport beneath it — TCP,
//! in-process channels — is assumed byte-faithful), so a random payload bit
//! flip could decode into a silently wrong tensor.  The injector's contract
//! is corruption the decoder is *guaranteed* to detect, never silently
//! decode: corruption smashes the frame's tag byte to a value `wire::decode`
//! has no arm for ([`CORRUPT_TAG`]), and truncation cuts to a strict prefix,
//! which the fully length-checked decoder always rejects (every field length
//! is self-describing, so a prefix of a valid frame cannot decode).

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::reactor::{PollIn, ReactorConn};
use super::{wire, LinkStats, Msg, Transport, TransportError};
use crate::util::rng::Rng;

/// The tag byte corruption smashes a frame's first byte to.  `wire::decode`
/// has no arm for it (tags stop well below), so a corrupted frame always
/// surfaces as a loud `WireError::UnknownTag` — never a silently wrong
/// message.
pub const CORRUPT_TAG: u8 = 0xEE;

// ---------------------------------------------------------------------------
// Pacing + frame-level access to the wrapped endpoint
// ---------------------------------------------------------------------------

/// Byte pacing for one frame: trickle the body in `chunk`-byte writes
/// separated by `gap` — the slow-loris writer shape.  [`Pacing::NONE`]
/// writes the frame in one piece.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pacing {
    /// Bytes per write; 0 disables pacing.
    pub chunk: usize,
    /// Sleep between chunk writes.
    pub gap: Duration,
}

impl Pacing {
    /// No pacing: the frame goes out in one write.
    pub const NONE: Pacing = Pacing { chunk: 0, gap: Duration::ZERO };

    /// Whether this pacing actually trickles.
    pub fn is_active(&self) -> bool {
        self.chunk > 0 && !self.gap.is_zero()
    }

    /// Total trickle time for a `len`-byte body under this pacing.
    pub fn total_delay(&self, len: usize) -> Duration {
        if !self.is_active() || len == 0 {
            return Duration::ZERO;
        }
        // a gap lands between consecutive chunks, not after the last one
        let chunks = len.div_ceil(self.chunk);
        self.gap * (chunks.saturating_sub(1)) as u32
    }
}

/// Raw frame-level access to a blocking endpoint, the seam [`FaultyLink`]
/// injects through.  [`Transport::send`] re-encodes a [`Msg`], so a
/// corrupted or truncated frame could never pass through it; this trait
/// moves already-encoded (and possibly impaired) frames while preserving
/// the endpoint's exact byte accounting.
pub trait FrameLink: Send {
    /// Transmit one already-encoded frame, optionally trickled under
    /// `pace`.  Accounting must match the endpoint's [`Transport::send`].
    fn send_frame(&mut self, frame: Vec<u8>, pace: Pacing) -> Result<(), TransportError>;

    /// Receive one raw frame without decoding it (length gate still
    /// applies).  Accounting must match the endpoint's [`Transport::recv`].
    fn recv_frame(&mut self) -> Result<Vec<u8>, TransportError>;

    /// Announce a full frame but transmit only `part` of it (paced), then
    /// sever the link: the slow-loris death.  The peer must observe a
    /// partial frame it loudly rejects — byte-stream links ship the partial
    /// body; message links (which cannot ship half a frame) just sever,
    /// and the peer still observes a mid-protocol hangup.
    fn send_partial_then_sever(&mut self, part: &[u8], total: usize, pace: Pacing);

    /// Hard-close both directions of the link (mid-stream disconnect).
    fn sever(&mut self);

    /// The endpoint's shared byte counters.
    fn link_stats(&self) -> Arc<LinkStats>;
}

impl FrameLink for super::InProc {
    fn send_frame(&mut self, frame: Vec<u8>, pace: Pacing) -> Result<(), TransportError> {
        // a message channel cannot trickle bytes; charge the whole trickle
        // as one up-front delay so pacing still shapes time identically
        let d = pace.total_delay(frame.len());
        if !d.is_zero() {
            std::thread::sleep(d);
        }
        self.stats.tx_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.stats.tx_msgs.fetch_add(1, Ordering::Relaxed);
        self.tx.send(frame).map_err(|_| TransportError::Closed)?;
        self.notify.wake();
        Ok(())
    }

    fn recv_frame(&mut self) -> Result<Vec<u8>, TransportError> {
        let frame = self.rx.recv().map_err(|_| TransportError::Closed)?;
        self.stats.rx_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.stats.rx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(frame)
    }

    fn send_partial_then_sever(&mut self, _part: &[u8], _total: usize, pace: Pacing) {
        // no partial frames over a channel; the hangup is the signal
        if pace.is_active() {
            std::thread::sleep(pace.gap);
        }
        self.sever();
    }

    fn sever(&mut self) {
        // mirror of InProc::drop: disconnect FIRST, then ring, so a reactor
        // peer's clear-then-recheck observes the hangup (see that comment)
        let (dummy, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dummy));
        self.notify.wake();
    }

    fn link_stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }
}

impl FrameLink for super::tcp::Tcp {
    fn send_frame(&mut self, frame: Vec<u8>, pace: Pacing) -> Result<(), TransportError> {
        self.write_frame_paced(&frame, pace.chunk, pace.gap)
    }

    fn recv_frame(&mut self) -> Result<Vec<u8>, TransportError> {
        self.read_frame_raw()
    }

    fn send_partial_then_sever(&mut self, part: &[u8], total: usize, pace: Pacing) {
        self.write_partial_then_sever(part, total, pace.chunk, pace.gap);
    }

    fn sever(&mut self) {
        self.sever_stream();
    }

    fn link_stats(&self) -> Arc<LinkStats> {
        self.stats()
    }
}

// ---------------------------------------------------------------------------
// The impairment matrix
// ---------------------------------------------------------------------------

/// A contiguous run of dropped frames: indices `first .. first + len`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Burst {
    /// First frame index the burst swallows.
    pub first: u64,
    /// Number of consecutive frames dropped.
    pub len: u64,
}

impl Burst {
    fn covers(&self, idx: u64) -> bool {
        idx >= self.first && idx - self.first < self.len
    }
}

/// One direction's impairment matrix.  `Default` is all-off: a wrapper
/// carrying two default matrices is byte- and accounting-identical to the
/// bare endpoint (the zero-impairment parity tests pin this).
///
/// Frame indices count the frames *this wrapper carries in this direction*,
/// starting at 0 — e.g. on a sharded edge uplink, frame 0 is `ShardHello`,
/// 1 is `KeyShard`, and training step `k` sends frames `2+2k` (Features)
/// and `3+2k` (TrainLabels).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Impairments {
    /// Fixed added latency per frame, microseconds.
    pub latency_us: u64,
    /// Uniform extra jitter per frame in `[0, jitter_us]`, microseconds.
    pub jitter_us: u64,
    /// Probability each frame is dropped (lost in flight, no error).
    pub drop_prob: f64,
    /// Deterministic burst drop on top of `drop_prob`.
    pub burst_drop: Option<Burst>,
    /// Probability each frame's tag byte is smashed to [`CORRUPT_TAG`].
    pub corrupt_prob: f64,
    /// Deterministically corrupt this frame index.
    pub corrupt_at: Option<u64>,
    /// Probability each frame is cut to a strict (undecodable) prefix.
    pub truncate_prob: f64,
    /// Deterministically truncate this frame index.
    pub truncate_at: Option<u64>,
    /// Swap this frame index with the next delivered frame: the frame is
    /// stashed and ships immediately *after* the following carried frame,
    /// so the peer observes the adjacent pair in inverted order.  Only an
    /// otherwise-intact delivery is swapped (a dropped/corrupted/truncated
    /// frame at this index wins its own fate), and the stash is stranded if
    /// no later frame is ever carried — schedule mid-stream indices.  Draws
    /// zero RNG rolls, so enabling it never shifts a sibling's schedule.
    pub reorder_at: Option<u64>,
    /// Sever the link instead of carrying this frame index.
    pub disconnect_at: Option<u64>,
    /// Trickle roughly half of this frame index, then sever mid-frame:
    /// the slow-loris death (tx direction; on rx it severs like
    /// `disconnect_at`).
    pub die_mid_frame: Option<u64>,
    /// Serialization-delay cap in bits/second (0 = unlimited): each frame
    /// is delayed by `wire_bytes * 8e6 / bandwidth_bps` microseconds.
    pub bandwidth_bps: u64,
    /// Slow-loris write pacing: bytes per write (0 = off).
    pub stall_chunk: usize,
    /// Slow-loris write pacing: microseconds between chunk writes.
    pub stall_gap_us: u64,
}

impl Impairments {
    /// The all-off matrix (same as `Default`).
    pub fn off() -> Self {
        Self::default()
    }

    /// Whether every impairment is disabled.
    pub fn is_off(&self) -> bool {
        *self == Self::default()
    }

    fn pacing(&self) -> Pacing {
        if self.stall_chunk > 0 && self.stall_gap_us > 0 {
            Pacing {
                chunk: self.stall_chunk,
                gap: Duration::from_micros(self.stall_gap_us),
            }
        } else {
            Pacing::NONE
        }
    }
}

// ---------------------------------------------------------------------------
// Schedule + recording
// ---------------------------------------------------------------------------

/// Which half of the link an event happened on, from the wrapper's own
/// perspective (`Tx` = frames this endpoint sends).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// The wrapper's outbound direction.
    Tx,
    /// The wrapper's inbound direction.
    Rx,
}

/// What the injector did to one frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Carried intact after `delay_us` microseconds of injected delay.
    Delivered {
        /// Injected latency + jitter + serialization delay, microseconds.
        delay_us: u64,
    },
    /// Lost in flight: the sender observes success, the peer nothing.
    Dropped,
    /// Tag byte smashed to [`CORRUPT_TAG`]; the peer's decode fails loudly.
    Corrupted,
    /// Cut to a strict prefix; the peer's decode fails loudly.
    Truncated {
        /// Bytes kept (0 ≤ kept < original length).
        kept: usize,
    },
    /// Stashed to ship after the next carried frame: the peer observes the
    /// adjacent pair swapped.  Only the sequencing layer (`transport::seq`)
    /// makes this loud — bare frames decode fine in either order.
    Reordered,
    /// Link severed instead of carrying the frame.
    Disconnected,
    /// Partial frame trickled, then the link severed mid-frame.
    DiedMidFrame {
        /// Body bytes actually shipped before the cut.
        sent: usize,
    },
}

/// One entry of a fault schedule log.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Direction the frame was traveling.
    pub dir: Dir,
    /// Frame index within that direction (0-based).
    pub frame: u64,
    /// What the injector did to it.
    pub action: FaultAction,
}

/// Shared, thread-safe log of every decision an injector made — the
/// artifact the seed-reproducibility tests compare bit-for-bit.
#[derive(Debug, Default)]
pub struct FaultRecorder {
    events: Mutex<Vec<FaultEvent>>,
}

impl FaultRecorder {
    /// Snapshot of all recorded events, in decision order.
    pub fn events(&self) -> Vec<FaultEvent> {
        self.events.lock().map(|e| e.clone()).unwrap_or_default()
    }

    /// Count of events in direction `dir` matching `pred`.
    pub fn count(&self, dir: Dir, pred: impl Fn(&FaultAction) -> bool) -> usize {
        self.events()
            .iter()
            .filter(|e| e.dir == dir && pred(&e.action))
            .count()
    }

    /// Dropped-frame count in direction `dir`.
    pub fn dropped(&self, dir: Dir) -> usize {
        self.count(dir, |a| matches!(a, FaultAction::Dropped))
    }

    fn push(&self, dir: Dir, frame: u64, action: FaultAction) {
        if let Ok(mut e) = self.events.lock() {
            e.push(FaultEvent { dir, frame, action });
        }
    }
}

/// The scheduled treatment of one frame, decided before any I/O.
enum Decision {
    Disconnect,
    DieMidFrame,
    Drop,
    Deliver { corrupt: bool, truncate: Option<usize>, delay_us: u64, reorder: bool },
}

/// One direction's live schedule: matrix + RNG stream + frame counter.
struct DirState {
    imp: Impairments,
    rng: Rng,
    frame: u64,
}

impl DirState {
    fn new(imp: Impairments, rng: Rng) -> Self {
        DirState { imp, rng, frame: 0 }
    }

    /// Decide frame `self.frame`'s fate and advance the counter.  The roll
    /// sequence is FIXED (drop, corrupt, truncate, cut, jitter — always all
    /// five) so the decision stream is a pure function of (seed, matrix,
    /// index) and enabling one impairment never shifts another's schedule.
    fn decide(&mut self, len: usize) -> (u64, Decision) {
        let idx = self.frame;
        self.frame += 1;
        let drop_roll = self.rng.uniform();
        let corrupt_roll = self.rng.uniform();
        let trunc_roll = self.rng.uniform();
        let cut = if len > 1 { 1 + self.rng.below(len - 1) } else { 0 };
        let jitter = if self.imp.jitter_us > 0 {
            self.rng.below(self.imp.jitter_us as usize + 1) as u64
        } else {
            self.rng.next_u64();
            0
        };
        if self.imp.disconnect_at == Some(idx) {
            return (idx, Decision::Disconnect);
        }
        if self.imp.die_mid_frame == Some(idx) {
            return (idx, Decision::DieMidFrame);
        }
        let burst = self.imp.burst_drop.map(|b| b.covers(idx)).unwrap_or(false);
        if burst || drop_roll < self.imp.drop_prob {
            return (idx, Decision::Drop);
        }
        let corrupt =
            corrupt_roll < self.imp.corrupt_prob || self.imp.corrupt_at == Some(idx);
        let truncate = (trunc_roll < self.imp.truncate_prob
            || self.imp.truncate_at == Some(idx))
        .then_some(cut);
        let mut delay_us = self.imp.latency_us + jitter;
        if self.imp.bandwidth_bps > 0 {
            // 4-byte prefix included: serialization delay charges wire bytes
            let bits = (len as u64 + 4).saturating_mul(8_000_000);
            delay_us += bits / self.imp.bandwidth_bps;
        }
        // reorder costs no roll (pure index test), so it cannot shift the
        // fixed five-roll schedule above; a corrupted/truncated frame keeps
        // its own fate rather than being swapped
        let reorder =
            self.imp.reorder_at == Some(idx) && !corrupt && truncate.is_none();
        (idx, Decision::Deliver { corrupt, truncate, delay_us, reorder })
    }
}

/// Apply a deliver-decision's mutation to the frame, recording exactly one
/// event.  Truncation wins over corruption when both trigger (one frame,
/// one observable fault).
fn mutate_frame(
    frame: &mut Vec<u8>,
    corrupt: bool,
    truncate: Option<usize>,
    delay_us: u64,
    rec: &FaultRecorder,
    dir: Dir,
    idx: u64,
) {
    if let Some(kept) = truncate {
        frame.truncate(kept);
        rec.push(dir, idx, FaultAction::Truncated { kept });
    } else if corrupt {
        if let Some(b) = frame.first_mut() {
            *b = CORRUPT_TAG;
        }
        rec.push(dir, idx, FaultAction::Corrupted);
    } else {
        rec.push(dir, idx, FaultAction::Delivered { delay_us });
    }
}

fn sleep_us(us: u64) {
    if us > 0 {
        std::thread::sleep(Duration::from_micros(us));
    }
}

// ---------------------------------------------------------------------------
// FaultyLink: blocking Transport wrapper
// ---------------------------------------------------------------------------

/// Deterministic fault-injecting wrapper around a blocking endpoint.
///
/// With both matrices at [`Impairments::off`] it is byte- and
/// accounting-identical to the bare endpoint.  Temporal impairments
/// (latency, jitter, bandwidth, pacing) sleep on the calling thread, which
/// is exactly where a blocking edge would feel them.
pub struct FaultyLink<T: FrameLink> {
    inner: T,
    tx: DirState,
    rx: DirState,
    rec: Arc<FaultRecorder>,
    /// Outbound frame stashed by `reorder_at`, shipped after the next
    /// carried frame.
    tx_stash: Option<Vec<u8>>,
    /// Inbound frame stashed by `reorder_at` on the receive side.
    rx_stash: Option<Vec<u8>>,
    /// Inbound frame whose swap completed: returned by the next `recv`.
    rx_ready: Option<Vec<u8>>,
    /// Severed by a disconnect/die impairment; all further I/O is `Closed`.
    dead: bool,
}

/// Fixed fork tags so the two direction streams are independent of each
/// other's traffic volume (and shared with [`FaultyConn`], so a blocking
/// and a reactor wrapper built from the same seed schedule identically).
const TX_STREAM: u64 = 0x74_78; // "tx"
const RX_STREAM: u64 = 0x72_78; // "rx"

impl<T: FrameLink> FaultyLink<T> {
    /// Wrap `inner`, deriving both direction schedules from `seed`.
    pub fn new(inner: T, seed: u64, tx: Impairments, rx: Impairments) -> Self {
        let mut root = Rng::new(seed);
        let txr = root.fork(TX_STREAM);
        let rxr = root.fork(RX_STREAM);
        FaultyLink {
            inner,
            tx: DirState::new(tx, txr),
            rx: DirState::new(rx, rxr),
            rec: Arc::new(FaultRecorder::default()),
            tx_stash: None,
            rx_stash: None,
            rx_ready: None,
            dead: false,
        }
    }

    /// The shared fault-schedule log for this link.
    pub fn recorder(&self) -> Arc<FaultRecorder> {
        self.rec.clone()
    }
}

impl<T: FrameLink> Transport for FaultyLink<T> {
    fn send(&mut self, msg: &Msg) -> Result<(), TransportError> {
        if self.dead {
            return Err(TransportError::Closed);
        }
        let mut frame = wire::encode(msg);
        let (idx, decision) = self.tx.decide(frame.len());
        match decision {
            Decision::Disconnect => {
                self.rec.push(Dir::Tx, idx, FaultAction::Disconnected);
                self.inner.sever();
                self.dead = true;
                Err(TransportError::Closed)
            }
            Decision::DieMidFrame => {
                let sent = frame.len() / 2;
                self.rec.push(Dir::Tx, idx, FaultAction::DiedMidFrame { sent });
                let pace = self.tx.imp.pacing();
                self.inner.send_partial_then_sever(&frame[..sent], frame.len(), pace);
                self.dead = true;
                Err(TransportError::Closed)
            }
            Decision::Drop => {
                // lossy-network semantics: the frame vanishes in flight, the
                // sender observes success.  Not charged to tx stats — it
                // never reached the wire.
                self.rec.push(Dir::Tx, idx, FaultAction::Dropped);
                Ok(())
            }
            Decision::Deliver { corrupt, truncate, delay_us, reorder } => {
                if reorder {
                    // stash; the swap completes when the next frame ships
                    self.rec.push(Dir::Tx, idx, FaultAction::Reordered);
                    self.tx_stash = Some(frame);
                    return Ok(());
                }
                sleep_us(delay_us);
                mutate_frame(
                    &mut frame, corrupt, truncate, delay_us, &self.rec, Dir::Tx, idx,
                );
                let pace = self.tx.imp.pacing();
                self.inner.send_frame(frame, pace)?;
                if let Some(stash) = self.tx_stash.take() {
                    self.inner.send_frame(stash, pace)?;
                }
                Ok(())
            }
        }
    }

    fn recv(&mut self) -> Result<Msg, TransportError> {
        if self.dead {
            return Err(TransportError::Closed);
        }
        if let Some(stash) = self.rx_ready.take() {
            // second half of a completed swap
            return Ok(wire::decode(&stash)?);
        }
        loop {
            let mut frame = self.inner.recv_frame()?;
            let (idx, decision) = self.rx.decide(frame.len());
            match decision {
                Decision::Disconnect | Decision::DieMidFrame => {
                    self.rec.push(Dir::Rx, idx, FaultAction::Disconnected);
                    self.inner.sever();
                    self.dead = true;
                    return Err(TransportError::Closed);
                }
                Decision::Drop => {
                    // the frame was lost in flight: keep waiting for the next
                    self.rec.push(Dir::Rx, idx, FaultAction::Dropped);
                    continue;
                }
                Decision::Deliver { corrupt, truncate, delay_us, reorder } => {
                    if reorder {
                        self.rec.push(Dir::Rx, idx, FaultAction::Reordered);
                        self.rx_stash = Some(frame);
                        continue;
                    }
                    sleep_us(delay_us);
                    mutate_frame(
                        &mut frame, corrupt, truncate, delay_us, &self.rec, Dir::Rx, idx,
                    );
                    if let Some(stash) = self.rx_stash.take() {
                        // deliver this frame now, the stashed one next call
                        self.rx_ready = Some(stash);
                    }
                    return Ok(wire::decode(&frame)?);
                }
            }
        }
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.inner.link_stats()
    }
}

// ---------------------------------------------------------------------------
// FaultyConn: nonblocking ReactorConn wrapper
// ---------------------------------------------------------------------------

/// Deterministic fault-injecting wrapper around a reactor connection.
///
/// A reactor connection must never sleep on the I/O thread, so temporal
/// impairments use *deadline staging* instead: delayed inbound frames are
/// held in a queue and released once due ([`ReactorConn::poll_recv`]
/// reports `Idle` meanwhile), and delayed outbound frames stage before
/// entering the inner outbox (counting toward [`ReactorConn::pending_out`],
/// so they engage the reactor's outbox backpressure like a genuinely slow
/// writer).  Under the epoll backend a staged deadline with no other
/// traffic is noticed at worst one idle tick later (`EPOLL_IDLE_TIMEOUT_MS`
/// bounds it); instant impairments (drop, corrupt, truncate, disconnect)
/// have no such latency on either backend.
pub struct FaultyConn<C: ReactorConn> {
    inner: C,
    tx: DirState,
    rx: DirState,
    rec: Arc<FaultRecorder>,
    /// Outbound frames impaired-and-accepted but not yet due to enter the
    /// inner outbox (latency/bandwidth staging), with their release times.
    staged_out: VecDeque<(Instant, Vec<u8>)>,
    /// Inbound frames pulled from the inner connection but not yet due for
    /// delivery (latency/jitter staging).
    held_in: VecDeque<(Instant, Vec<u8>)>,
    /// Outbound frame stashed by `reorder_at`, queued after the next
    /// carried frame.
    tx_stash: Option<Vec<u8>>,
    /// Inbound frame stashed by `reorder_at` on the receive side.
    rx_stash: Option<Vec<u8>>,
    dead: bool,
}

impl<C: ReactorConn> FaultyConn<C> {
    /// Wrap `inner`, deriving both direction schedules from `seed`.  The
    /// stream derivation matches [`FaultyLink::new`], so the same seed and
    /// matrix schedule identically on both wrappers.
    pub fn new(inner: C, seed: u64, tx: Impairments, rx: Impairments) -> Self {
        let mut root = Rng::new(seed);
        let txr = root.fork(TX_STREAM);
        let rxr = root.fork(RX_STREAM);
        FaultyConn {
            inner,
            tx: DirState::new(tx, txr),
            rx: DirState::new(rx, rxr),
            rec: Arc::new(FaultRecorder::default()),
            staged_out: VecDeque::new(),
            held_in: VecDeque::new(),
            tx_stash: None,
            rx_stash: None,
            dead: false,
        }
    }

    /// The shared fault-schedule log for this connection.
    pub fn recorder(&self) -> Arc<FaultRecorder> {
        self.rec.clone()
    }
}

impl<C: ReactorConn> ReactorConn for FaultyConn<C> {
    fn poll_recv(&mut self) -> Result<PollIn, TransportError> {
        if self.dead {
            return Ok(PollIn::Closed);
        }
        // release due held frames first, preserving arrival order; a head
        // frame that is not yet due blocks the queue (order over speed)
        if let Some((due, _)) = self.held_in.front() {
            if *due <= Instant::now() {
                if let Some((_, frame)) = self.held_in.pop_front() {
                    return Ok(PollIn::Frame(frame));
                }
            }
            return Ok(PollIn::Idle);
        }
        loop {
            match self.inner.poll_recv()? {
                PollIn::Frame(mut frame) => {
                    let (idx, decision) = self.rx.decide(frame.len());
                    match decision {
                        Decision::Disconnect | Decision::DieMidFrame => {
                            self.rec.push(Dir::Rx, idx, FaultAction::Disconnected);
                            self.dead = true;
                            return Ok(PollIn::Closed);
                        }
                        Decision::Drop => {
                            self.rec.push(Dir::Rx, idx, FaultAction::Dropped);
                            continue;
                        }
                        Decision::Deliver { corrupt, truncate, delay_us, reorder } => {
                            if reorder {
                                self.rec.push(Dir::Rx, idx, FaultAction::Reordered);
                                self.rx_stash = Some(frame);
                                continue;
                            }
                            mutate_frame(
                                &mut frame, corrupt, truncate, delay_us, &self.rec,
                                Dir::Rx, idx,
                            );
                            let stash = self.rx_stash.take();
                            if delay_us == 0 {
                                if let Some(st) = stash {
                                    // swap completes: this frame now, the
                                    // stash on the next poll (held, due now)
                                    self.held_in.push_back((Instant::now(), st));
                                }
                                return Ok(PollIn::Frame(frame));
                            }
                            let due =
                                Instant::now() + Duration::from_micros(delay_us);
                            self.held_in.push_back((due, frame));
                            if let Some(st) = stash {
                                self.held_in.push_back((due, st));
                            }
                            return Ok(PollIn::Idle);
                        }
                    }
                }
                other => return Ok(other),
            }
        }
    }

    fn queue_frame(&mut self, frame: Vec<u8>) {
        if self.dead {
            return;
        }
        let mut frame = frame;
        let (idx, decision) = self.tx.decide(frame.len());
        match decision {
            Decision::Disconnect | Decision::DieMidFrame => {
                self.rec.push(Dir::Tx, idx, FaultAction::Disconnected);
                self.dead = true;
            }
            Decision::Drop => {
                self.rec.push(Dir::Tx, idx, FaultAction::Dropped);
            }
            Decision::Deliver { corrupt, truncate, delay_us, reorder } => {
                if reorder {
                    self.rec.push(Dir::Tx, idx, FaultAction::Reordered);
                    self.tx_stash = Some(frame);
                    return;
                }
                mutate_frame(
                    &mut frame, corrupt, truncate, delay_us, &self.rec, Dir::Tx, idx,
                );
                let stash = self.tx_stash.take();
                if delay_us == 0 && self.staged_out.is_empty() {
                    self.inner.queue_frame(frame);
                    if let Some(st) = stash {
                        self.inner.queue_frame(st);
                    }
                } else {
                    let due = Instant::now() + Duration::from_micros(delay_us);
                    self.staged_out.push_back((due, frame));
                    if let Some(st) = stash {
                        self.staged_out.push_back((due, st));
                    }
                }
            }
        }
    }

    fn poll_send(&mut self) -> Result<bool, TransportError> {
        if self.dead {
            // a severed peer accepts nothing more; the hangup surfaces via
            // poll_recv's Closed, matching a real half-dead socket
            return Ok(true);
        }
        let now = Instant::now();
        while let Some((due, _)) = self.staged_out.front() {
            if *due > now {
                break;
            }
            if let Some((_, frame)) = self.staged_out.pop_front() {
                self.inner.queue_frame(frame);
            }
        }
        let drained = self.inner.poll_send()?;
        Ok(drained && self.staged_out.is_empty())
    }

    fn pending_out(&self) -> usize {
        // staged frames count: a slow link's backlog must engage the
        // reactor's outbox bound exactly like an unwritable socket's
        self.staged_out.len() + self.inner.pending_out()
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.inner.stats()
    }

    fn readiness_fd(&self) -> Option<std::os::fd::RawFd> {
        self.inner.readiness_fd()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Labels, Tensor};
    use crate::transport::{inproc_pair, inproc_reactor_pair_with};

    fn feat(step: u64) -> Msg {
        Msg::Features {
            step,
            tensor: Tensor::from_vec(&[2, 4], (0..8).map(|i| i as f32).collect()),
        }
    }

    fn menu() -> Vec<Msg> {
        vec![
            feat(0),
            Msg::TrainLabels { step: 0, labels: Labels(vec![1, 2]) },
            Msg::Gradients { step: 0, tensor: Tensor::zeros(&[2, 4]) },
            Msg::StepStats { step: 0, loss: 0.5, ncorrect: 1.0 },
            Msg::ShardHello,
            Msg::KeyShard { client_id: 1, epoch: 0, proof: 7 },
            Msg::Shutdown,
        ]
    }

    #[test]
    fn zero_impairment_parity_with_bare_inproc() {
        // identical traffic over a bare pair and an all-off faulty pair:
        // every decoded message and every stats counter must match
        let (mut ba, mut bb) = inproc_pair();
        let (fa, fb) = inproc_pair();
        let mut fa = FaultyLink::new(fa, 1, Impairments::off(), Impairments::off());
        let mut fb = FaultyLink::new(fb, 2, Impairments::off(), Impairments::off());
        for m in menu() {
            ba.send(&m).unwrap();
            fa.send(&m).unwrap();
            assert_eq!(bb.recv().unwrap(), m);
            assert_eq!(fb.recv().unwrap(), m);
            bb.send(&m).unwrap();
            fb.send(&m).unwrap();
            assert_eq!(ba.recv().unwrap(), m);
            assert_eq!(fa.recv().unwrap(), m);
        }
        for (b, f) in [(ba.stats(), fa.stats()), (bb.stats(), fb.stats())] {
            assert_eq!(b.tx(), f.tx());
            assert_eq!(b.rx(), f.rx());
            assert_eq!(
                b.tx_msgs.load(Ordering::Relaxed),
                f.tx_msgs.load(Ordering::Relaxed)
            );
            assert_eq!(
                b.rx_msgs.load(Ordering::Relaxed),
                f.rx_msgs.load(Ordering::Relaxed)
            );
        }
        // and the schedule log records pure deliveries with zero delay
        assert!(fa
            .recorder()
            .events()
            .iter()
            .all(|e| e.action == FaultAction::Delivered { delay_us: 0 }));
    }

    #[test]
    fn drop_count_matches_schedule_and_replays_bit_for_bit() {
        let run = |seed: u64| {
            let (a, b) = inproc_pair();
            let imp = Impairments { drop_prob: 0.5, ..Impairments::off() };
            let mut a = FaultyLink::new(a, seed, imp, Impairments::off());
            let mut b = b;
            for i in 0..40 {
                a.send(&feat(i)).unwrap();
            }
            drop(a.inner); // hang up so the receive loop terminates
            let mut got = 0;
            while b.recv().is_ok() {
                got += 1;
            }
            (got, a.rec.events())
        };
        let (got1, log1) = run(0xC3);
        let (got2, log2) = run(0xC3);
        // same seed → bit-identical schedule, and delivered + dropped = sent
        assert_eq!(log1, log2);
        assert_eq!(got1, got2);
        let dropped =
            log1.iter().filter(|e| e.action == FaultAction::Dropped).count();
        assert_eq!(got1 + dropped, 40);
        assert!(dropped > 0, "p=0.5 over 40 frames never dropping is ~1e-12");
    }

    #[test]
    fn burst_drop_swallows_exactly_the_scheduled_indices() {
        let (a, b) = inproc_pair();
        let imp = Impairments {
            burst_drop: Some(Burst { first: 2, len: 3 }),
            ..Impairments::off()
        };
        let mut a = FaultyLink::new(a, 9, imp, Impairments::off());
        let mut b = b;
        for i in 0..8 {
            a.send(&feat(i)).unwrap();
        }
        let dropped: Vec<u64> = a
            .recorder()
            .events()
            .iter()
            .filter(|e| e.action == FaultAction::Dropped)
            .map(|e| e.frame)
            .collect();
        assert_eq!(dropped, vec![2, 3, 4]);
        // the peer sees exactly the surviving steps, in order
        for step in [0u64, 1, 5, 6, 7] {
            assert_eq!(b.recv().unwrap(), feat(step));
        }
    }

    #[test]
    fn truncation_is_always_a_loud_transport_error() {
        // property: whatever the message and wherever the cut lands, a
        // truncated frame NEVER decodes — the peer errors loudly
        crate::util::proptest::Prop::new("truncate-loud", 40).run(|g| {
            let msg = match g.usize_in(0, 3) {
                0 => feat(g.usize_in(0, 100) as u64),
                1 => Msg::TrainLabels {
                    step: 1,
                    labels: Labels((0..g.usize_in(1, 9)).map(|i| i as i32).collect()),
                },
                2 => Msg::KeySeed { seed: 0xAB },
                _ => Msg::Shutdown,
            };
            let (a, b) = inproc_pair();
            let imp = Impairments { truncate_prob: 1.0, ..Impairments::off() };
            let mut a =
                FaultyLink::new(a, g.usize_in(0, 1 << 20) as u64, imp, Impairments::off());
            let mut b = b;
            a.send(&msg).unwrap();
            match b.recv() {
                Err(TransportError::Wire(_)) | Err(TransportError::EmptyFrame) => {}
                other => panic!("truncated frame must not decode: {other:?}"),
            }
        });
    }

    #[test]
    fn corruption_is_always_detected_never_misdecoded() {
        let (a, b) = inproc_pair();
        let imp = Impairments { corrupt_at: Some(0), ..Impairments::off() };
        let mut a = FaultyLink::new(a, 5, imp, Impairments::off());
        let mut b = b;
        a.send(&feat(3)).unwrap();
        match b.recv() {
            Err(TransportError::Wire(wire::WireError::UnknownTag(t))) => {
                assert_eq!(t, CORRUPT_TAG)
            }
            other => panic!("corrupted frame must fail decode, got {other:?}"),
        }
    }

    #[test]
    fn disconnect_at_severs_both_ways() {
        let (a, b) = inproc_pair();
        let imp = Impairments { disconnect_at: Some(2), ..Impairments::off() };
        let mut a = FaultyLink::new(a, 5, imp, Impairments::off());
        let mut b = b;
        a.send(&feat(0)).unwrap();
        a.send(&feat(1)).unwrap();
        assert!(matches!(a.send(&feat(2)), Err(TransportError::Closed)));
        // the wrapper is dead for every later call too
        assert!(matches!(a.send(&feat(3)), Err(TransportError::Closed)));
        assert!(matches!(a.recv(), Err(TransportError::Closed)));
        // the peer drains what was carried, then observes the hangup
        assert_eq!(b.recv().unwrap(), feat(0));
        assert_eq!(b.recv().unwrap(), feat(1));
        assert!(matches!(b.recv(), Err(TransportError::Closed)));
    }

    #[test]
    fn rx_impairments_apply_on_the_receive_side() {
        let (a, b) = inproc_pair();
        let imp = Impairments {
            burst_drop: Some(Burst { first: 0, len: 1 }),
            corrupt_at: Some(1),
            ..Impairments::off()
        };
        let mut a = a;
        let mut b = FaultyLink::new(b, 5, Impairments::off(), imp);
        a.send(&feat(0)).unwrap(); // dropped in flight (rx frame 0)
        a.send(&feat(1)).unwrap(); // corrupted (rx frame 1)
        a.send(&feat(2)).unwrap(); // delivered (rx frame 2)
        assert!(matches!(b.recv(), Err(TransportError::Wire(_))));
        assert_eq!(b.recv().unwrap(), feat(2));
        assert_eq!(b.recorder().dropped(Dir::Rx), 1);
    }

    #[test]
    fn reorder_swaps_adjacent_frames_and_is_recorded() {
        let (a, b) = inproc_pair();
        let imp = Impairments { reorder_at: Some(1), ..Impairments::off() };
        let mut a = FaultyLink::new(a, 21, imp, Impairments::off());
        let mut b = b;
        for i in 0..4 {
            a.send(&feat(i)).unwrap();
        }
        // frames 1 and 2 arrive swapped; 0 and 3 are untouched
        for step in [0u64, 2, 1, 3] {
            assert_eq!(b.recv().unwrap(), feat(step));
        }
        let log = a.recorder().events();
        assert_eq!(log[1].action, FaultAction::Reordered);
        assert_eq!(log[1].frame, 1);
        // reorder draws no rolls: the sibling deliveries schedule exactly
        // as they would with the impairment off
        assert!(log.iter().filter(|e| e.frame != 1).all(|e| matches!(
            e.action,
            FaultAction::Delivered { delay_us: 0 }
        )));
    }

    #[test]
    fn reorder_applies_on_the_receive_side_too() {
        let (a, b) = inproc_pair();
        let imp = Impairments { reorder_at: Some(0), ..Impairments::off() };
        let mut a = a;
        let mut b = FaultyLink::new(b, 22, Impairments::off(), imp);
        for i in 0..3 {
            a.send(&feat(i)).unwrap();
        }
        for step in [1u64, 0, 2] {
            assert_eq!(b.recv().unwrap(), feat(step));
        }
        assert_eq!(b.recorder().count(Dir::Rx, |a| *a == FaultAction::Reordered), 1);
    }

    #[test]
    fn reorder_on_faulty_conn_matches_link_schedule() {
        let (mut edge, conn) = inproc_reactor_pair_with(false);
        let imp = Impairments { reorder_at: Some(1), ..Impairments::off() };
        let mut conn = FaultyConn::new(conn, 21, Impairments::off(), imp);
        for i in 0..4 {
            edge.send(&feat(i)).unwrap();
        }
        let mut got = Vec::new();
        while got.len() < 4 {
            match conn.poll_recv().unwrap() {
                PollIn::Frame(f) => got.push(wire::decode(&f).unwrap()),
                PollIn::Idle => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, vec![feat(0), feat(2), feat(1), feat(3)]);
    }

    #[test]
    fn unsequenced_reorder_is_silent_sequenced_is_loud() {
        // The negative control for the sequencing layer: two bare data
        // frames swapped in flight DECODE FINE in the wrong order — the
        // receiver cannot tell — while the same traffic under Sequenced
        // envelopes trips a loud SeqError on the very first swapped frame.
        use crate::transport::seq::{Seq, SeqError};
        let run = |sequenced: bool| -> Result<Vec<Msg>, SeqError> {
            let (a, b) = inproc_pair();
            let imp = Impairments { reorder_at: Some(0), ..Impairments::off() };
            let mut a = FaultyLink::new(a, 33, imp, Impairments::off());
            let mut b = b;
            let mut tx = Seq::new();
            let mut rx = Seq::new();
            for i in 0..3 {
                let m = feat(i);
                let m = if sequenced { tx.stamp(m) } else { m };
                a.send(&m).unwrap();
            }
            let mut got = Vec::new();
            for _ in 0..3 {
                got.push(rx.accept(b.recv().unwrap())?);
            }
            Ok(got)
        };
        // bare: silently mis-ordered — steps 1 and 0 swapped, no error
        assert_eq!(run(false).unwrap(), vec![feat(1), feat(0), feat(2)]);
        // sequenced: the swap is loud (frame 1 lands where 0 was expected)
        assert_eq!(run(true).unwrap_err(), SeqError::Gap { expected: 0, got: 1 });
    }

    #[test]
    fn pacing_charges_trickle_time() {
        assert_eq!(Pacing::NONE.total_delay(1000), Duration::ZERO);
        let p = Pacing { chunk: 64, gap: Duration::from_millis(1) };
        // 1000 bytes → 16 chunks → 15 gaps
        assert_eq!(p.total_delay(1000), Duration::from_millis(15));
        assert_eq!(p.total_delay(0), Duration::ZERO);
        assert_eq!(p.total_delay(64), Duration::ZERO);
    }

    #[test]
    fn faulty_conn_corrupts_and_drops_on_poll_recv() {
        // edge (blocking InProc) → cloud (FaultyConn over NbInProc): rx
        // drop swallows frame 0, rx corruption smashes frame 1 — and the
        // corrupted frame is returned for the PUMP to detect (the reactor's
        // decode is the detection point), never silently fixed up
        let (mut edge, conn) = inproc_reactor_pair_with(false);
        let imp = Impairments {
            burst_drop: Some(Burst { first: 0, len: 1 }),
            corrupt_at: Some(1),
            ..Impairments::off()
        };
        let mut conn = FaultyConn::new(conn, 11, Impairments::off(), imp);
        edge.send(&feat(0)).unwrap();
        edge.send(&feat(1)).unwrap();
        edge.send(&feat(2)).unwrap();
        // frame 0 dropped inside the poll loop; frame 1 surfaces corrupted
        let got = match conn.poll_recv().unwrap() {
            PollIn::Frame(f) => f,
            other => panic!("expected corrupted frame, got {other:?}"),
        };
        assert_eq!(got[0], CORRUPT_TAG);
        assert!(wire::decode(&got).is_err(), "corruption must be detectable");
        // frame 2 intact
        match conn.poll_recv().unwrap() {
            PollIn::Frame(f) => assert_eq!(wire::decode(&f).unwrap(), feat(2)),
            other => panic!("expected intact frame, got {other:?}"),
        }
        assert_eq!(conn.recorder().dropped(Dir::Rx), 1);
    }

    #[test]
    fn faulty_conn_stages_delayed_frames_without_blocking() {
        let (mut edge, conn) = inproc_reactor_pair_with(false);
        let imp = Impairments { latency_us: 20_000, ..Impairments::off() };
        let mut conn = FaultyConn::new(conn, 3, Impairments::off(), imp);
        edge.send(&feat(0)).unwrap();
        // the frame is pulled and staged, not delivered: Idle, immediately
        let t0 = Instant::now();
        assert!(matches!(conn.poll_recv().unwrap(), PollIn::Idle));
        assert!(
            t0.elapsed() < Duration::from_millis(15),
            "poll_recv must never sleep on the I/O thread"
        );
        // once due, the frame is released intact
        std::thread::sleep(Duration::from_millis(25));
        match conn.poll_recv().unwrap() {
            PollIn::Frame(f) => assert_eq!(wire::decode(&f).unwrap(), feat(0)),
            other => panic!("expected staged frame after its deadline, got {other:?}"),
        }
    }

    #[test]
    fn faulty_conn_tx_staging_counts_toward_outbox_backpressure() {
        let (_edge, conn) = inproc_reactor_pair_with(false);
        let imp = Impairments { latency_us: 50_000, ..Impairments::off() };
        let mut conn = FaultyConn::new(conn, 3, imp, Impairments::off());
        for i in 0..5 {
            conn.queue_frame(wire::encode(&feat(i)));
        }
        // all five are staged behind their deadlines: pending_out must show
        // them (this is what engages the reactor's wants_read outbox bound)
        assert_eq!(conn.pending_out(), 5);
        assert!(!conn.poll_send().unwrap(), "staged frames are not drained");
        // after the deadline they drain into the inner outbox and out
        std::thread::sleep(Duration::from_millis(60));
        assert!(conn.poll_send().unwrap());
        assert_eq!(conn.pending_out(), 0);
    }

    #[test]
    fn same_seed_same_schedule_across_wrapper_kinds() {
        // FaultyLink and FaultyConn built from one seed and matrix must
        // make identical per-frame decisions (the conformance the chaos
        // harness's reproduce-from-seed promise rests on)
        let imp = Impairments {
            drop_prob: 0.3,
            corrupt_prob: 0.2,
            jitter_us: 50,
            ..Impairments::off()
        };
        let sizes = [64usize, 8, 300, 9, 120, 64, 33, 7];
        let link_log = {
            let (a, _b) = inproc_pair();
            let mut a = FaultyLink::new(a, 77, imp, Impairments::off());
            for (i, _) in sizes.iter().enumerate() {
                // drive the tx schedule with same-size frames via decide()
                // through real sends of fixed shape
                let _ = a.send(&feat(i as u64));
            }
            a.recorder().events()
        };
        let conn_log = {
            let (_edge, conn) = inproc_reactor_pair_with(false);
            let mut conn = FaultyConn::new(conn, 77, imp, Impairments::off());
            for (i, _) in sizes.iter().enumerate() {
                conn.queue_frame(wire::encode(&feat(i as u64)));
            }
            conn.recorder().events()
        };
        // compare decisions only (delay realization differs: the link
        // sleeps, the conn stages — but the schedule itself must agree)
        let strip = |log: Vec<FaultEvent>| -> Vec<(Dir, u64, FaultAction)> {
            log.into_iter().map(|e| (e.dir, e.frame, e.action)).collect()
        };
        assert_eq!(strip(link_log), strip(conn_log));
    }
}
