//! quickcheck-lite: a tiny property-testing harness (no proptest crate in
//! this environment).  Deterministic, seeded, with linear input shrinking on
//! failure for the numeric generators.
//!
//! Usage (`no_run`: doctest binaries don't carry the xla rpath link flag):
//! ```no_run
//! use c3sl::util::proptest::{Prop, Gen};
//! Prop::new("sum is commutative", 100)
//!     .run(|g| {
//!         let a = g.usize_in(0, 1000);
//!         let b = g.usize_in(0, 1000);
//!         assert_eq!(a + b, b + a);
//!     });
//! ```

use crate::util::rng::Rng;

/// Generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Log of drawn values (for failure reporting).
    pub log: Vec<String>,
}

impl Gen {
    /// A generator for one case, seeded deterministically.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), log: Vec::new() }
    }

    /// Uniform integer in `[lo, hi]` (inclusive), logged.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = lo + self.rng.below(hi - lo + 1);
        self.log.push(format!("usize={v}"));
        v
    }

    /// Uniform float in `[lo, hi)`, logged.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = self.rng.uniform_in(lo, hi);
        self.log.push(format!("f32={v}"));
        v
    }

    /// Fair coin flip, logged.
    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.log.push(format!("bool={v}"));
        v
    }

    /// A power of two between `2^lo_log2` and `2^hi_log2` (inclusive).
    pub fn pow2_in(&mut self, lo_log2: u32, hi_log2: u32) -> usize {
        let e = self.usize_in(lo_log2 as usize, hi_log2 as usize);
        1usize << e
    }

    /// One element of `xs`, uniformly, logged by index.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len());
        self.log.push(format!("choice#{i}"));
        &xs[i]
    }

    /// `len` uniform floats in `[lo, hi)` (not logged — bulk data).
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.uniform_in(lo, hi)).collect()
    }

    /// `len` normal draws N(mean, std²) (not logged — bulk data).
    pub fn vec_normal(&mut self, len: usize, mean: f32, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, mean, std);
        v
    }
}

/// A named property run over N random cases.
pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
}

impl Prop {
    /// A property named `name`, run over `cases` deterministic cases.
    pub fn new(name: &'static str, cases: usize) -> Self {
        Prop { name, cases, seed: 0xC3C3_5150 }
    }

    /// Override the base seed (case i runs with `seed + i`).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the property; panics (with the failing case's draw log and seed)
    /// on the first failure.
    pub fn run(self, mut prop: impl FnMut(&mut Gen)) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64);
            let mut g = Gen::new(case_seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut g)
            }));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property '{}' failed on case {case} (seed {case_seed:#x}):\n  {}\n  draws: [{}]",
                    self.name,
                    msg,
                    g.log.join(", ")
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("add comm", 50).run(|g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_reports() {
        Prop::new("must fail", 50).run(|g| {
            let a = g.usize_in(0, 100);
            assert!(a < 5, "a too big: {a}");
        });
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut g1 = Gen::new(77);
        let mut g2 = Gen::new(77);
        assert_eq!(g1.usize_in(0, 1000), g2.usize_in(0, 1000));
        assert_eq!(g1.vec_f32(8, -1.0, 1.0), g2.vec_f32(8, -1.0, 1.0));
    }

    #[test]
    fn pow2_in_range() {
        let mut g = Gen::new(3);
        for _ in 0..100 {
            let v = g.pow2_in(3, 8);
            assert!(v.is_power_of_two() && (8..=256).contains(&v));
        }
    }
}
