//! Compression schemes for the cut-layer tensors.
//!
//! `Codec` is the host-side interface the coordinator uses for both
//! directions (features uplink, gradients downlink — C3-SL compresses both,
//! paper §1).  The C3 codec here is the rust-native hot path mirroring the
//! L1 Pallas kernels; the coordinator can alternatively route encode/decode
//! through the AOT artifacts (runtime::codec) — both are tested to agree.
//!
//! Extension codecs (fp16 / int8 quantization) implement the "combine
//! dimension-wise and batch-wise compression" future-work note in the
//! paper's §5: they stack with C3 by quantizing the compressed feature.

pub mod quant;

use crate::hdc::{Backend, FftBackend, KeySet, C3};
use crate::tensor::Tensor;

/// A (possibly lossy) batch codec.  encode: (B, D) → smaller; decode: inverse.
pub trait Codec: Send {
    /// Human-readable scheme label for logs and reports (e.g. `"c3-r4"`).
    fn name(&self) -> String;
    /// Nominal compression ratio on payload bytes.
    fn ratio(&self) -> f64;
    /// Compress a (B, D) batch to its wire form.
    fn encode(&self, z: &Tensor) -> Tensor;
    /// Reconstruct a (B, D) batch from its compressed wire form.
    fn decode(&self, s: &Tensor) -> Tensor;
    /// Payload bytes actually transmitted for an encoded tensor.
    fn tx_bytes(&self, encoded: &Tensor) -> usize {
        encoded.len() * 4
    }
}

/// Vanilla SL: no compression.
pub struct IdentityCodec;

impl Codec for IdentityCodec {
    fn name(&self) -> String {
        "identity".into()
    }

    fn ratio(&self) -> f64 {
        1.0
    }

    fn encode(&self, z: &Tensor) -> Tensor {
        z.clone()
    }

    fn decode(&self, s: &Tensor) -> Tensor {
        s.clone()
    }
}

/// C3-SL batch-wise codec over a fixed key set (paper §3).
pub struct C3Codec {
    c3: C3,
}

impl C3Codec {
    /// Serial codec over a fixed key set on the given backend.
    pub fn new(keys: KeySet, backend: Backend) -> Self {
        C3Codec { c3: C3::new(keys, backend) }
    }

    /// C3 codec with group-parallel encode/decode across `workers` threads.
    pub fn with_workers(keys: KeySet, backend: Backend, workers: usize) -> Self {
        C3Codec { c3: C3::with_workers(keys, backend, workers) }
    }

    /// Fully explicit construction: codec backend, FFT kernel family
    /// (`scheme.fft_backend`) and worker count — see [`C3::with_backends`].
    pub fn with_backends(
        keys: KeySet,
        backend: Backend,
        fft: FftBackend,
        workers: usize,
    ) -> Self {
        C3Codec { c3: C3::with_backends(keys, backend, fft, workers) }
    }

    /// Compression ratio R (features folded per carrier).
    pub fn r(&self) -> usize {
        self.c3.keys.r
    }

    /// Feature dimensionality D.
    pub fn d(&self) -> usize {
        self.c3.keys.d
    }

    /// Group-parallel worker count of the underlying engine.
    pub fn workers(&self) -> usize {
        self.c3.workers()
    }

    /// The underlying host engine, for callers that manage their own
    /// scratch/threading (e.g. the reactor cloud's codec worker pool, which
    /// drives `encode_into`/`decode_into` with one `C3Scratch` per worker).
    pub fn engine(&self) -> &C3 {
        &self.c3
    }
}

impl Codec for C3Codec {
    fn name(&self) -> String {
        format!("c3-r{}", self.c3.keys.r)
    }

    fn ratio(&self) -> f64 {
        self.c3.keys.r as f64
    }

    fn encode(&self, z: &Tensor) -> Tensor {
        self.c3.encode(z)
    }

    fn decode(&self, s: &Tensor) -> Tensor {
        self.c3.decode(s)
    }
}

/// Stack two codecs: `outer` runs on the already-compressed tensor.
/// (paper §5 future work: dimension-wise + batch-wise combined.)
pub struct Stacked<A: Codec, B: Codec> {
    /// The batch-wise stage (runs first on encode, last on decode).
    pub inner: A,
    /// The dimension-wise stage over the already-compressed tensor.
    pub outer: B,
}

impl<A: Codec, B: Codec> Codec for Stacked<A, B> {
    fn name(&self) -> String {
        format!("{}+{}", self.inner.name(), self.outer.name())
    }

    fn ratio(&self) -> f64 {
        self.inner.ratio() * self.outer.ratio()
    }

    fn encode(&self, z: &Tensor) -> Tensor {
        self.outer.encode(&self.inner.encode(z))
    }

    fn decode(&self, s: &Tensor) -> Tensor {
        self.inner.decode(&self.outer.decode(s))
    }

    fn tx_bytes(&self, encoded: &Tensor) -> usize {
        self.outer.tx_bytes(encoded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut d = vec![0.0f32; shape.iter().product()];
        rng.fill_normal(&mut d, 0.0, 1.0);
        Tensor::from_vec(shape, d)
    }

    #[test]
    fn identity_is_lossless() {
        let mut rng = Rng::new(1);
        let z = rand_tensor(&mut rng, &[8, 64]);
        let c = IdentityCodec;
        assert_eq!(c.decode(&c.encode(&z)), z);
        assert_eq!(c.ratio(), 1.0);
    }

    #[test]
    fn c3_shapes_and_ratio() {
        let mut rng = Rng::new(2);
        let keys = KeySet::generate(&mut rng, 4, 128);
        let c = C3Codec::new(keys, Backend::Auto);
        let z = rand_tensor(&mut rng, &[16, 128]);
        let s = c.encode(&z);
        assert_eq!(s.shape(), &[4, 128]);
        assert_eq!(c.tx_bytes(&s) * 4, c.tx_bytes(&z)); // 4× fewer bytes
        let zh = c.decode(&s);
        assert_eq!(zh.shape(), &[16, 128]);
    }

    #[test]
    fn c3_reconstruction_correlates() {
        let mut rng = Rng::new(3);
        let keys = KeySet::generate(&mut rng, 2, 512);
        let c = C3Codec::new(keys, Backend::Fft);
        let z = rand_tensor(&mut rng, &[4, 512]);
        let zh = c.decode(&c.encode(&z));
        let cos = z.dot(&zh) / (z.norm() * zh.norm());
        assert!(cos > 0.3, "cos={cos}");
    }

    #[test]
    fn stacked_ratio_multiplies() {
        let mut rng = Rng::new(4);
        let keys = KeySet::generate(&mut rng, 4, 64);
        let stacked = Stacked {
            inner: C3Codec::new(keys, Backend::Auto),
            outer: quant::QuantCodec::f16(),
        };
        assert_eq!(stacked.ratio(), 8.0);
        let z = rand_tensor(&mut rng, &[8, 64]);
        let s = stacked.encode(&z);
        assert_eq!(s.shape(), &[2, 64]);
        // fp16 payload: 2 bytes per element
        assert_eq!(stacked.tx_bytes(&s), s.len() * 2);
        let zh = stacked.decode(&s);
        assert_eq!(zh.shape(), &[8, 64]);
    }
}
