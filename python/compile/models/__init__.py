from .vgg import vgg16_split, vgg_tiny_split
from .resnet import resnet50_split
from .bottlenetpp import bottlenetpp_codec

__all__ = ["vgg16_split", "vgg_tiny_split", "resnet50_split", "bottlenetpp_codec"]
