//! Bench: the accuracy columns of Table 1 — shape reproduction.
//!
//!   cargo bench --bench table1_accuracy
//!   C3SL_ACC_STEPS=300 C3SL_ACC_SEEDS=3 cargo bench --bench table1_accuracy
//!
//! Trains the tiny split model (vggt_b32, D=1024) on SynthCIFAR-10 through
//! the full two-actor coordinator for every scheme × R in Table 1, then
//! prints the table.  On this 1-core-CPU testbed the models are width-slim
//! and the runs short (see DESIGN.md §3), so the *shape* is the target:
//!
//!   * C3 tracks vanilla closely for R ≤ 8 and droops mildly at R = 16;
//!   * C3 is competitive with BottleNet++ at every R;
//!   * all schemes are far above the 10% chance floor.

use c3sl::config::{CodecVenue, ExperimentConfig, SchemeKind, TransportKind};
use c3sl::coordinator::run_experiment;

fn env_usize(k: &str, default: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn cfg(scheme: SchemeKind, steps: usize, seed: u64) -> ExperimentConfig {
    // Host codec venue: numerically equivalent to the Pallas artifacts
    // (rust/tests/integration.rs::artifact_codec_matches_host_codec) and
    // ~10× faster per step on CPU (§Perf) — lets the sweep run more steps.
    ExperimentConfig {
        name: "table1_accuracy".into(),
        model_key: "vggt_b32".into(),
        artifacts_root: "artifacts".into(),
        scheme,
        codec_venue: CodecVenue::Host,
        transport: TransportKind::InProc,
        steps,
        lr: 1e-3,
        seed,
        eval_every: steps,
        eval_batches: 8,
        synth_train: 2048,
        synth_test: 512,
        ..Default::default()
    }
}

fn main() {
    let steps = env_usize("C3SL_ACC_STEPS", 60);
    let seeds = env_usize("C3SL_ACC_SEEDS", 1) as u64;
    if !std::path::Path::new("artifacts/vggt_b32/manifest.json").exists() {
        eprintln!("SKIP table1_accuracy: run `make artifacts` first");
        return;
    }

    println!(
        "# Table 1 accuracy columns (shape repro): vggt_b32 on SynthCIFAR-10, \
         {steps} steps x {seeds} seed(s)\n"
    );

    let mut rows: Vec<(String, usize, f64, f64)> = Vec::new(); // name, r, acc, up_bytes
    let mut schemes: Vec<SchemeKind> = vec![SchemeKind::Vanilla];
    for r in [2usize, 4, 8, 16] {
        schemes.push(SchemeKind::C3 { r });
    }
    for r in [2usize, 4, 8, 16] {
        schemes.push(SchemeKind::BottleNetPP { r });
    }

    for scheme in schemes {
        let mut acc_sum = 0.0;
        let mut up = 0.0;
        for seed in 0..seeds {
            let out = run_experiment(&cfg(scheme, steps, seed))
                .expect("experiment failed");
            acc_sum += out.recorder.evals.last().map(|e| e.2).unwrap_or(0.0);
            up = out.recorder.total_uplink() as f64;
        }
        rows.push((scheme.name(), scheme.ratio(), acc_sum / seeds as f64, up));
    }

    println!(
        "{:<12} {:>3} {:>12} {:>14} {:>10}",
        "scheme", "R", "accuracy", "uplink bytes", "vs vanilla"
    );
    let base_acc = rows[0].2;
    let base_up = rows[0].3;
    for (name, r, acc, up) in &rows {
        println!(
            "{:<12} {:>3} {:>11.1}% {:>14} {:>9.2}x   (Δacc {:+.1} pts)",
            name,
            r,
            acc * 100.0,
            *up as u64,
            base_up / up,
            (acc - base_acc) * 100.0,
        );
    }
    println!(
        "\nshape targets: C3 within a few points of vanilla for R<=8, droop at 16;\n\
         C3 ≈ BN++ accuracy at equal R with ZERO codec params (cf. table2_formulas)."
    );
}
