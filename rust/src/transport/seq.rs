//! Per-session frame sequencing: loud detection of reordering, loss, and
//! duplication at the protocol layer.
//!
//! Each direction of a session carries its own monotonic counter, stamped
//! onto every data frame as a [`Msg::Sequenced`] envelope.  The receiver is
//! **opt-in-and-lock**: a connection starts tolerant (bare frames pass
//! through untouched, so hand-rolled legacy peers and the adversarial rogue
//! tests keep working), but the first sequenced frame *locks* the session —
//! from then on every data frame must arrive enveloped and in exact order.
//! Anything else — a gap where the network dropped a frame, a duplicate,
//! two frames swapped in flight, or a peer that quietly stops sequencing —
//! is a [`SeqError`], surfaced as a connection-fatal transport error rather
//! than silently mis-decoding downstream (a reordered `Features`/`Gradients`
//! pair would otherwise still *decode*, just into the wrong step).
//!
//! Handshake traffic (`KeySeed`, `ShardHello`, `ShardChallenge`,
//! `KeyShard`, `Resume`, `ResumeOk`) is never enveloped: it runs before the
//! session exists, and its own challenge/nonce discipline already rejects
//! replay.  Counters are per *connection* — a resumed session starts fresh
//! at 0 on both sides, with the resume point pinned by
//! `Msg::Resume::last_acked_step` instead of the old counters.

use std::fmt;

use crate::transport::Msg;

/// Sequencing violation on a received frame — always connection-fatal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqError {
    /// A sequenced frame skipped ahead: at least one frame was lost.
    Gap {
        /// The sequence number the receiver required next.
        expected: u64,
        /// The (higher) sequence number that actually arrived.
        got: u64,
    },
    /// A sequenced frame arrived at or below the watermark: a duplicate,
    /// or two frames swapped in flight (the later one already advanced
    /// the counter past this one).
    Reordered {
        /// The sequence number the receiver required next.
        expected: u64,
        /// The (lower) sequence number that actually arrived.
        got: u64,
    },
    /// A bare data frame arrived on a session that already locked into
    /// sequencing — a peer must not stop stamping mid-session.
    Unsequenced,
}

impl fmt::Display for SeqError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SeqError::Gap { expected, got } => {
                write!(f, "sequence gap: expected frame {expected}, got {got}")
            }
            SeqError::Reordered { expected, got } => {
                write!(f, "duplicate or reordered frame: expected frame {expected}, got {got}")
            }
            SeqError::Unsequenced => write!(f, "unsequenced frame in a sequenced session"),
        }
    }
}

impl std::error::Error for SeqError {}

/// One direction-pair of session sequence state (own transmit counter,
/// peer's expected-next counter, and the opt-in lock).
#[derive(Debug, Default, Clone)]
pub struct Seq {
    next_tx: u64,
    next_rx: u64,
    locked: bool,
}

impl Seq {
    /// Fresh counters: transmit starts at 0, receive side still tolerant.
    pub fn new() -> Self {
        Self::default()
    }

    /// Envelope one outbound data frame with the next transmit number.
    pub fn stamp(&mut self, msg: Msg) -> Msg {
        let seq = self.next_tx;
        self.next_tx += 1;
        Msg::Sequenced { seq, inner: Box::new(msg) }
    }

    /// The sequence number [`Seq::stamp`] will assign next; for callers
    /// that stamp pre-encoded frames via [`crate::transport::wire::seq_frame`]
    /// instead of re-encoding a [`Msg`].
    pub fn take_tx(&mut self) -> u64 {
        let seq = self.next_tx;
        self.next_tx += 1;
        seq
    }

    /// Validate one inbound frame.  Sequenced frames must carry exactly the
    /// expected number (and lock the session); bare frames pass through
    /// only while the session is still unlocked.
    pub fn accept(&mut self, msg: Msg) -> Result<Msg, SeqError> {
        match msg {
            Msg::Sequenced { seq, inner } => {
                let expected = self.next_rx;
                if seq > expected {
                    return Err(SeqError::Gap { expected, got: seq });
                }
                if seq < expected {
                    return Err(SeqError::Reordered { expected, got: seq });
                }
                self.next_rx += 1;
                self.locked = true;
                Ok(*inner)
            }
            m if self.locked => {
                // handshake re-runs never reach here (a resume is a new
                // connection with a new Seq), so any bare frame is a peer
                // that stopped sequencing mid-session
                let _ = m;
                Err(SeqError::Unsequenced)
            }
            m => Ok(m),
        }
    }

    /// Whether the peer has locked this session into sequencing.
    pub fn locked(&self) -> bool {
        self.locked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_order_stream_passes_and_unwraps() {
        let mut tx = Seq::new();
        let mut rx = Seq::new();
        for step in 0..5u64 {
            let m = tx.stamp(Msg::KeySeed { seed: step });
            assert_eq!(rx.accept(m).unwrap(), Msg::KeySeed { seed: step });
        }
        assert!(rx.locked());
    }

    #[test]
    fn gap_detected() {
        let mut tx = Seq::new();
        let mut rx = Seq::new();
        rx.accept(tx.stamp(Msg::Shutdown)).unwrap();
        let _lost = tx.stamp(Msg::Shutdown);
        let err = rx.accept(tx.stamp(Msg::Shutdown)).unwrap_err();
        assert_eq!(err, SeqError::Gap { expected: 1, got: 2 });
        assert_eq!(err.to_string(), "sequence gap: expected frame 1, got 2");
    }

    #[test]
    fn duplicate_and_swap_detected() {
        let mut tx = Seq::new();
        let mut rx = Seq::new();
        let a = tx.stamp(Msg::KeySeed { seed: 1 });
        let b = tx.stamp(Msg::KeySeed { seed: 2 });
        // swapped in flight: b lands first (a gap), then retrying in the
        // true order trips the reorder arm on a fresh receiver
        assert!(matches!(rx.accept(b.clone()), Err(SeqError::Gap { expected: 0, got: 1 })));
        let mut rx = Seq::new();
        rx.accept(a.clone()).unwrap();
        rx.accept(b).unwrap();
        let err = rx.accept(a).unwrap_err();
        assert_eq!(err, SeqError::Reordered { expected: 2, got: 0 });
    }

    #[test]
    fn tolerant_until_locked_then_strict() {
        let mut rx = Seq::new();
        // legacy peer: bare frames sail through while unlocked
        assert_eq!(rx.accept(Msg::Shutdown).unwrap(), Msg::Shutdown);
        assert!(!rx.locked());
        let mut tx = Seq::new();
        rx.accept(tx.stamp(Msg::Shutdown)).unwrap();
        // the first envelope locked the session: bare frames now fail
        assert_eq!(rx.accept(Msg::Shutdown).unwrap_err(), SeqError::Unsequenced);
        assert_eq!(
            SeqError::Unsequenced.to_string(),
            "unsequenced frame in a sequenced session"
        );
    }

    #[test]
    fn take_tx_matches_stamp_numbering() {
        let mut s = Seq::new();
        assert_eq!(s.take_tx(), 0);
        assert!(matches!(s.stamp(Msg::Shutdown), Msg::Sequenced { seq: 1, .. }));
        assert_eq!(s.take_tx(), 2);
    }
}
