//! Multi-client coordinator: one cloud serving N concurrent edges, with
//! per-client and aggregate `LinkStats`, in either of two serving styles:
//!
//! * **thread-per-client** ([`serve_clients`]) — one OS thread per edge,
//!   blocking transports; simple, but thread stacks and context switches cap
//!   concurrency at the dozens;
//! * **reactor** ([`serve_clients_reactor`]) — one I/O thread multiplexes
//!   every edge over nonblocking connections ([`crate::transport::reactor`])
//!   and feeds decode/step/encode jobs to a pool of `scheme.workers` codec
//!   threads, each owning a `C3Scratch`; per-client outbox bounds and a
//!   parsed-job bound give slow or pipelining clients genuine backpressure
//!   without stalling anyone else.  This is the thousand-edge path.
//!
//! The PJRT model halves are artifact-gated (runtime::xla_stub), so this
//! scenario exercises the full *codec + transport + accounting* stack
//! host-natively: each edge holds a feature buffer z, uplinks `encode(z)`
//! with labels, and the cloud decodes, evaluates the quadratic probe
//! objective L = ½·mean(ẑ²), encodes the gradient gẑ = ẑ/N and downlinks it
//! with the step stats — the same message protocol the single-edge
//! CloudWorker speaks.  The edge applies the decoded gradient to z (toy
//! SGD), so the objective genuinely decreases end-to-end *through* the lossy
//! codec in both directions — the property the tests assert.
//!
//! # Key agreement
//!
//! Keys never cross the wire in either mode.
//!
//! * **Shared** ([`CloudCodec::Shared`] / [`EdgeCodec::Shared`]): every
//!   endpoint builds its `RunCodec` from one shared key seed, announced by
//!   `Msg::KeySeed` — the original single-key-set contract.
//! * **Sharded** ([`CloudCodec::Sharded`] / [`EdgeCodec::Sharded`]): the
//!   edge opens with `Msg::ShardHello` (the edge speaks first in every
//!   mode, so a mis-paired deployment fails loudly instead of deadlocking),
//!   the cloud answers with a **fresh challenge**
//!   (`Msg::ShardChallenge { nonce }`); each edge holds only its
//!   *per-client sub-master* ([`EdgeShard`], derived one-way from the ring
//!   master by the trusted coordinator — see [`crate::hdc::keyring`]) and
//!   completes with `Msg::KeyShard { client_id, epoch, proof }`, where
//!   `proof` is a one-way possession proof binding the claim AND the nonce
//!   — not even a seed is announced, and a recorded proof is single-use
//!   (replaying it against a later session's challenge fails, so an
//!   observer can no longer squat a shard id across sessions).  The cloud's
//!   [`ShardGate`] verifies the claim — id in range, not already claimed,
//!   epoch current, proof answering this connection's own challenge — and
//!   rejects the client otherwise (without disturbing healthy edges).  A
//!   compromised edge therefore holds nothing that derives a sibling's
//!   keys, and a wire observer of the handshake can regenerate no key
//!   material.  Keys then *rotate*: every `rotation_steps` training steps
//!   both endpoints re-derive the shard at the next epoch, in lockstep,
//!   purely from the step number.

//!
//! # Ops control plane
//!
//! The reactor serve can additionally answer a plaintext HTTP/1.0 ops
//! endpoint off its *own* readiness loop (the listener is one more pollable
//! fd — no extra threads): `GET /metrics` (Prometheus text format),
//! `GET /healthz` and `POST /drain` (graceful drain).  Both serving styles
//! publish live counters into a shared [`OpsRegistry`]; SIGHUP re-applies
//! the safe knob subset ([`OpsReload`]).  See [`OpsOptions`],
//! [`serve_clients_reactor_ops`] and ARCHITECTURE.md.

use super::run_codec::RunCodec;
use crate::hdc::keyring::{ClientCodec, EdgeShard, KeyRing, RevocationList};
use crate::hdc::{C3Scratch, FftBackend, C3};
use crate::metrics::prom::PromWriter;
use crate::metrics::Histogram;
use crate::tensor::{Labels, Tensor};
use crate::transport::reactor::{
    Event, Reactor, ReactorConfig, ReactorConn, ReactorIoStats,
};
use crate::transport::readiness::{
    hangup_count, install_hangup_handler, thread_cpu_time, ReadinessBackend, WakeHandle,
};
use crate::transport::seq::Seq;
use crate::transport::{Msg, Transport};
use crate::util::error::{C3Error, Context, Result};
use crate::util::rng::Rng;
use crate::{bail, ensure};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// Per-client report from the multi-edge cloud (its half of the link).
/// `PartialEq` so the chaos harness can compare whole reports across runs
/// (seed reproducibility) and across serving styles.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientReport {
    /// Accept-order client index.
    pub client: usize,
    /// The key shard this client claimed via `Msg::KeyShard` (`None` when
    /// serving a shared key set).
    pub shard: Option<u64>,
    /// Training steps served for this client.
    pub steps: u64,
    /// Bytes the cloud sent to this client (downlink).
    pub tx_bytes: u64,
    /// Bytes the cloud received from this client (uplink).
    pub rx_bytes: u64,
    /// Messages sent to this client.
    pub tx_msgs: u64,
    /// Messages received from this client.
    pub rx_msgs: u64,
    /// Probe loss at the client's final served step.
    pub last_loss: f32,
}

/// Aggregated multi-client stats.
#[derive(Clone, Debug, Default)]
pub struct MultiStats {
    /// One report per client, in accept order.
    pub per_client: Vec<ClientReport>,
    /// I/O-thread observability for a reactor serve (readiness backend
    /// actually used, pump wakeups, I/O-thread CPU time); `None` for the
    /// thread-per-client pool, which has no single I/O thread to meter.
    pub reactor_io: Option<ReactorIoStats>,
}

impl MultiStats {
    /// Total downlink bytes across clients.
    pub fn total_tx(&self) -> u64 {
        self.per_client.iter().map(|c| c.tx_bytes).sum()
    }

    /// Total uplink bytes across clients.
    pub fn total_rx(&self) -> u64 {
        self.per_client.iter().map(|c| c.rx_bytes).sum()
    }

    /// Total training steps served across clients.
    pub fn total_steps(&self) -> u64 {
        self.per_client.iter().map(|c| c.steps).sum()
    }
}

/// Per-edge report (the edge's half of the link).  `PartialEq` so the
/// chaos harness can assert byte-identical reports across runs and styles.
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeReport {
    /// Training steps this edge ran.
    pub steps: u64,
    /// Probe loss reported by the cloud at the first step.
    pub first_loss: f32,
    /// Probe loss reported by the cloud at the final step.
    pub last_loss: f32,
    /// Bytes this edge sent (uplink).
    pub tx_bytes: u64,
    /// Bytes this edge received (downlink).
    pub rx_bytes: u64,
}

// ---------------------------------------------------------------------------
// Key plumbing: shared key set vs per-client shards.
// ---------------------------------------------------------------------------

/// Process-global salt folded into every gate's nonce-stream seed, so two
/// gates created in the same clock tick still issue disjoint challenges.
static NONCE_SALT: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0x9E37_79B9_7F4A_7C15);

/// Entropy for a gate's challenge stream: wall-clock nanoseconds XOR a
/// process-global counter.  Challenges guard against *replay* (a DoS, not a
/// key-disclosure risk — see `hdc::keyring`), so clock+counter freshness is
/// the right weight: no OS randomness dependency, never the same stream
/// twice within or across processes.
fn nonce_seed() -> u64 {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let salt = NONCE_SALT
        .fetch_add(0x9E37_79B9_7F4A_7C15, std::sync::atomic::Ordering::Relaxed);
    t ^ salt.rotate_left(17)
}

/// Mutable handshake state behind the gate's one lock.
struct GateState {
    /// Who holds each shard: indexed by shard id, `Some(slot)` names the
    /// accept slot whose connection claimed it (each id is claimable by
    /// exactly one connection at a time).  Recording the OWNER — not just
    /// a boolean — is what lets [`ShardGate::release`] refuse to free a
    /// claim on behalf of anyone but the connection that made it.
    claimed: Vec<Option<usize>>,
    /// The challenge nonce issued to each accept slot (indexed by
    /// connection slot, NOT shard id — a proof must answer the challenge
    /// that went down its own connection).  Grown on demand: accept slots
    /// are not capped by the shard count, so a cloud accepting more
    /// connections than it serves shards (reconnects, rogues) still
    /// challenges every one of them and rejects at the claim, not here.
    nonces: Vec<Option<u64>>,
    /// The highest training step each shard id has uplinked this session
    /// (`None` until its first step).  This is the re-claim watermark: a
    /// reconnecting edge resumes at the epoch of the step after the last
    /// one its shard completed, so admission accepts that CURRENT epoch —
    /// per shard, because step numbering is per-client, and a fast
    /// sibling's progress must never invalidate a fresh edge's claim.
    last_step: Vec<Option<u64>>,
    /// The fresh-challenge stream.
    rng: Rng,
    /// Claims refused by operator policy even with a valid proof
    /// ([`ShardGate::revoke`]) — the per-epoch revocation list.
    revoked: RevocationList,
}

/// Shared handshake state for one sharded serving session: the key ring the
/// shards derive from, which shard ids have been claimed, and the fresh
/// challenge nonce issued to every connection ([`ShardGate::issue_nonce`])
/// that its `Msg::KeyShard` possession proof must bind.
pub struct ShardGate {
    ring: KeyRing,
    /// Group-parallel workers for per-client codecs on the *blocking* serve
    /// path (the reactor parallelizes across clients instead).
    workers: usize,
    /// FFT kernel family for every per-client codec this gate admits.
    fft: FftBackend,
    state: Mutex<GateState>,
}

impl ShardGate {
    /// A gate deriving from `ring` and serving shard ids `0..clients`.
    pub fn new(ring: KeyRing, clients: usize) -> Self {
        ShardGate {
            ring,
            workers: 1,
            fft: FftBackend::default(),
            state: Mutex::new(GateState {
                claimed: vec![None; clients],
                nonces: vec![None; clients],
                last_step: vec![None; clients],
                rng: Rng::new(nonce_seed()),
                revoked: RevocationList::new(),
            }),
        }
    }

    /// Group-parallel worker count for per-client codecs built by the
    /// thread-per-client serve path (`scheme.workers`; the reactor's codec
    /// pool parallelizes across clients and keeps per-client engines
    /// serial, so it ignores this).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// FFT kernel family (`scheme.fft_backend`) for every per-client codec
    /// admitted through this gate.
    pub fn with_fft_backend(mut self, fft: FftBackend) -> Self {
        self.fft = fft;
        self
    }

    /// The FFT kernel family this gate configures admitted codecs with.
    pub fn fft_backend(&self) -> FftBackend {
        self.fft
    }

    /// Carrier dimensionality D of every shard this gate derives (geometry
    /// only — the ring itself, which holds the master seed, never leaves
    /// the gate).
    pub fn d(&self) -> usize {
        self.ring.d()
    }

    /// Issue the fresh challenge for accept-slot `client` — the value the
    /// cloud sends as `Msg::ShardChallenge` and the slot's `Msg::KeyShard`
    /// proof must bind.  Accept slots are unbounded (unlike shard ids): a
    /// connection beyond the shard count still gets its challenge and is
    /// rejected later at the claim, where the error names the real problem.
    pub fn issue_nonce(&self, client: usize) -> Result<u64> {
        let mut st = self
            .state
            .lock()
            .map_err(|_| C3Error::msg("shard gate lock poisoned"))?;
        if client >= st.nonces.len() {
            st.nonces.resize(client + 1, None);
        }
        let nonce = st.rng.next_u64();
        st.nonces[client] = Some(nonce);
        Ok(nonce)
    }

    /// Validate one `Msg::KeyShard` announcement from accept-slot `client`
    /// and hand back the validated shard handle (no keygen here — admission
    /// is cheap; the caller decides when to derive keys).  Every check is a
    /// *per-client* rejection — the caller fails that connection only.
    /// Public alongside [`ShardGate::issue_nonce`] / [`ShardGate::release`]
    /// so custom serving loops (and the interleaving harness) can drive the
    /// full admission protocol; the built-in serve paths call it for you.
    pub fn admit(
        &self,
        client: usize,
        client_id: u64,
        epoch: u64,
        proof: u64,
    ) -> Result<EdgeShard> {
        let mut st = self
            .state
            .lock()
            .map_err(|_| C3Error::msg("shard gate lock poisoned"))?;
        let n = st.claimed.len();
        ensure!(
            client_id < n as u64,
            "client {client}: shard id {client_id} out of range (serving {n} shards)"
        );
        // The expected claim epoch is the shard's CURRENT rotation epoch:
        // a fresh shard claims at epoch_of(step 0) — identically 0 for
        // every rotation cadence — while a mid-session re-claim (the edge
        // reconnected after a drop) resumes at the step after the last one
        // this shard completed.  A disconnect exactly on an epoch boundary
        // leaves the resume step in the NEXT epoch, so both the epoch of
        // the last observed step and of the step after it are accepted.
        // The proof still binds the announced epoch (it is derived from
        // that epoch's sub-seed), so acceptance is never wider than the
        // key material the edge actually proves it holds.
        let (lo, hi) = match st.last_step[client_id as usize] {
            None => {
                let e0 = self.ring.epoch_of_step(0);
                (e0, e0)
            }
            Some(last) => (
                self.ring.epoch_of_step(last),
                self.ring.epoch_of_step(last.saturating_add(1)),
            ),
        };
        ensure!(
            epoch == lo || epoch == hi,
            "client {client}: stale key epoch {epoch} for shard {client_id} \
             (expected {lo}..={hi} at its current rotation position)"
        );
        // a missing nonce is the CLIENT's protocol violation (KeyShard as
        // the first message, skipping ShardHello), not a server invariant
        let nonce = st.nonces.get(client).copied().flatten().with_context(|| {
            format!(
                "client {client}: KeyShard before ShardHello — no challenge \
                 issued for this connection"
            )
        })?;
        // NB: never echo `want_proof` — it is the valid credential for this
        // challenge, and rejection messages reach logs and aggregate errors
        let want_proof = self.ring.shard_proof(client_id, epoch, nonce);
        ensure!(
            proof == want_proof,
            "client {client}: shard proof mismatch for shard {client_id} \
             (announced {proof:#x} — wrong master seed, or a replayed/stale \
             proof that does not answer this connection's challenge?)"
        );
        // The challenge is answered: BURN it before any further outcome, so
        // a wire-recorded proof verifies at most once.  Without this, a
        // later connection reusing this accept slot (shard re-claim keeps
        // gates alive across connections) could replay the recorded frame
        // against the still-stored nonce and squat the shard.  A fresh
        // claim must re-hello for a fresh challenge.
        st.nonces[client] = None;
        // Policy gate AFTER proof verification and the nonce burn: a
        // revoked (shard, epoch) pair is refused even when the edge holds
        // perfectly valid key material — revocation is an operator
        // decision, not a cryptographic failure — and the burned nonce
        // means the refused proof cannot be replayed either.
        ensure!(
            !st.revoked.is_revoked(client_id, epoch),
            "client {client}: shard {client_id} epoch {epoch} is revoked \
             (valid proof refused by policy)"
        );
        let slot = &mut st.claimed[client_id as usize];
        ensure!(
            slot.is_none(),
            "client {client}: shard id {client_id} already claimed"
        );
        *slot = Some(client);
        Ok(self.ring.edge_shard(client_id))
    }

    /// Validate one `Msg::Resume` claim from accept-slot `client` and hand
    /// back the shard handle plus the step the session restarts at.  A
    /// resume is a re-claim with *exact accounting*: the edge announces the
    /// last step it holds an acknowledgement for (`last_acked_step`), and
    /// the gate checks it against this shard's [`ShardGate::observe_step`]
    /// watermark `w`.  Only two positions are coherent:
    ///
    /// * `last_acked_step == w` — the edge saw every reply; the session
    ///   resumes at `w + 1`;
    /// * `last_acked_step == w - 1` — the connection died between the
    ///   edge's uplink of step `w` and the cloud's reply; the edge re-runs
    ///   step `w`, which the cloud re-executes idempotently (the probe step
    ///   is a pure function of the uplink, and the watermark is monotonic).
    ///
    /// Anything staler is a loud `stale resume watermark` rejection (the
    /// edge lost state it claims to hold), anything ahead is a loud
    /// `resume ahead of watermark` rejection (the edge claims replies this
    /// cloud never sent).  Like [`ShardGate::admit`], the proof must answer
    /// this connection's own challenge, the nonce burns before the
    /// revocation check, and the claim must be free.
    pub fn resume(
        &self,
        client: usize,
        client_id: u64,
        epoch: u64,
        last_acked_step: u64,
        proof: u64,
    ) -> Result<(EdgeShard, u64)> {
        let mut st = self
            .state
            .lock()
            .map_err(|_| C3Error::msg("shard gate lock poisoned"))?;
        let n = st.claimed.len();
        ensure!(
            client_id < n as u64,
            "client {client}: shard id {client_id} out of range (serving {n} shards)"
        );
        let w = st.last_step[client_id as usize].with_context(|| {
            format!(
                "client {client}: nothing to resume for shard {client_id} \
                 (no step observed this session — claim fresh with KeyShard)"
            )
        })?;
        ensure!(
            last_acked_step.saturating_add(1) >= w,
            "client {client}: stale resume watermark for shard {client_id} \
             (last acked {last_acked_step}, but this cloud observed step {w})"
        );
        ensure!(
            last_acked_step <= w,
            "client {client}: resume ahead of watermark for shard {client_id} \
             (last acked {last_acked_step}, but this cloud observed only step {w})"
        );
        let resume_step = last_acked_step.saturating_add(1);
        let want_epoch = self.ring.epoch_of_step(resume_step);
        ensure!(
            epoch == want_epoch,
            "client {client}: stale key epoch {epoch} for shard {client_id} \
             (resuming at step {resume_step} requires epoch {want_epoch})"
        );
        let nonce = st.nonces.get(client).copied().flatten().with_context(|| {
            format!(
                "client {client}: Resume before ShardHello — no challenge \
                 issued for this connection"
            )
        })?;
        let want_proof = self.ring.shard_proof(client_id, epoch, nonce);
        ensure!(
            proof == want_proof,
            "client {client}: shard proof mismatch for shard {client_id} \
             (announced {proof:#x} — wrong master seed, or a replayed/stale \
             proof that does not answer this connection's challenge?)"
        );
        // burn the answered challenge before any further outcome, exactly
        // like admit: a recorded resume proof must verify at most once
        st.nonces[client] = None;
        ensure!(
            !st.revoked.is_revoked(client_id, epoch),
            "client {client}: shard {client_id} epoch {epoch} is revoked \
             (valid proof refused by policy)"
        );
        let slot = &mut st.claimed[client_id as usize];
        ensure!(
            slot.is_none(),
            "client {client}: shard id {client_id} already claimed"
        );
        *slot = Some(client);
        Ok((self.ring.edge_shard(client_id), resume_step))
    }

    /// Release a shard claim: accept-slot `client`'s connection is gone.
    /// Both serve paths call this when a client's connection closes —
    /// cleanly or not — so a restarted edge can re-handshake the same
    /// shard id (fresh challenge, fresh proof) instead of being locked out
    /// for the rest of the serving session.  The gate enforces ownership
    /// *mechanically*: the claim is freed only when `client` is the slot
    /// recorded at admission, so no connection — not even a buggy caller
    /// releasing after its own "already claimed" rejection — can free a
    /// live claim it does not hold.  Best-effort on a poisoned gate lock —
    /// the session is already failing then, and a release must never mask
    /// the original error.
    pub fn release(&self, client: usize, client_id: u64) {
        if let Ok(mut st) = self.state.lock() {
            if let Some(slot) = st.claimed.get_mut(client_id as usize) {
                if *slot == Some(client) {
                    *slot = None;
                }
            }
        }
    }

    /// Record that shard `client_id` uplinked training step `step` — the
    /// re-claim watermark consulted by admission.  Monotonic (out-of-order
    /// observations never move it backwards) and per shard, because step
    /// numbering is per-client.  Both serve paths call this as each
    /// training step's labels arrive, so a reconnecting edge is admitted
    /// at the epoch it will actually resume in instead of epoch 0.
    /// Best-effort on a poisoned lock (the session is already failing) and
    /// a no-op for out-of-range ids.
    pub fn observe_step(&self, client_id: u64, step: u64) {
        if let Ok(mut st) = self.state.lock() {
            if let Some(slot) = st.last_step.get_mut(client_id as usize) {
                *slot = Some(match *slot {
                    Some(prev) => prev.max(step),
                    None => step,
                });
            }
        }
    }

    /// Revoke shard `client_id`'s claim rights for key `epoch`: any later
    /// [`ShardGate::admit`] announcing that (shard, epoch) pair is refused
    /// even with a valid proof.  Scoped to one epoch — the shard claims
    /// again once rotation moves it past the revoked epoch (or at an
    /// earlier epoch still inside its admission window) — and irreversible,
    /// mirroring [`RevocationList::revoke`].  Existing claims are NOT torn
    /// down: revocation gates (re-)admission, and the serving loop owns
    /// live connections.  Returns `true` if the pair was newly revoked,
    /// `false` if it already was (or the gate lock is poisoned — the
    /// session is already failing then).
    pub fn revoke(&self, client_id: u64, epoch: u64) -> bool {
        match self.state.lock() {
            Ok(mut st) => st.revoked.revoke(client_id, epoch),
            Err(_) => false,
        }
    }

    /// Whether (shard `client_id`, key `epoch`) is on the revocation list.
    /// `false` on a poisoned gate lock (best-effort, like `release`).
    pub fn is_revoked(&self, client_id: u64, epoch: u64) -> bool {
        match self.state.lock() {
            Ok(st) => st.revoked.is_revoked(client_id, epoch),
            Err(_) => false,
        }
    }

    /// The accept-slot currently holding shard `client_id`'s claim, or
    /// `None` when the shard is unclaimed (or the id is out of range, or
    /// the gate lock is poisoned).  The chaos harness uses this for exact
    /// final accounting: after a serving session every claim must be back
    /// to `None`, however rudely its connection ended.
    pub fn claimant(&self, client_id: u64) -> Option<usize> {
        match self.state.lock() {
            Ok(st) => st.claimed.get(client_id as usize).copied().flatten(),
            Err(_) => None,
        }
    }

    /// The re-claim watermark for shard `client_id`: the highest training
    /// step it has uplinked, or `None` before its first observed step (or
    /// for an out-of-range id / poisoned lock).  Read-side twin of
    /// [`ShardGate::observe_step`], exposed so churn tests can assert the
    /// exact resume cursor a reconnecting edge will be admitted at.
    pub fn last_step(&self, client_id: u64) -> Option<u64> {
        match self.state.lock() {
            Ok(st) => st.last_step.get(client_id as usize).copied().flatten(),
            Err(_) => None,
        }
    }

    /// Number of shard ids this gate serves: the ops `/metrics` exporter
    /// enumerates `0..shards()` for its per-shard watermark gauges.  0 on
    /// a poisoned lock.
    pub fn shards(&self) -> usize {
        match self.state.lock() {
            Ok(st) => st.claimed.len(),
            Err(_) => 0,
        }
    }
}

/// How the cloud obtains codec keys for its clients.
#[derive(Clone, Copy)]
pub enum CloudCodec<'a> {
    /// One codec shared by every client (global key set, `Msg::KeySeed`).
    Shared(&'a RunCodec),
    /// Per-client key shards negotiated via `Msg::KeyShard` and validated
    /// by the [`ShardGate`].
    Sharded(&'a ShardGate),
}

impl CloudCodec<'_> {
    /// Expected carrier dimensionality D, when statically known (used to
    /// reject wrong-geometry uplinks before they reach a codec engine).
    fn wire_d(&self) -> Option<usize> {
        match self {
            CloudCodec::Shared(c) => c.host_engine().map(|c3| c3.keys.d),
            CloudCodec::Sharded(g) => Some(g.ring.d()),
        }
    }

    fn is_sharded(&self) -> bool {
        matches!(self, CloudCodec::Sharded(_))
    }
}

/// How an edge derives its codec keys.
pub enum EdgeCodec<'a> {
    /// Global key set built from a shared seed on both endpoints; the seed
    /// is announced via `Msg::KeySeed` (keys never cross the wire).
    Shared {
        /// The codec venue constructed from `key_seed` on both sides.
        codec: &'a RunCodec,
        /// The codec-construction seed announced in the handshake.
        key_seed: u64,
    },
    /// This edge's own key shard, claimed via `Msg::KeyShard` in answer to
    /// the cloud's `Msg::ShardChallenge` and rotated on the shard's epoch
    /// schedule.  Carries only the per-client sub-master ([`EdgeShard`]) —
    /// never the ring master — so even a fully compromised edge cannot
    /// derive any sibling shard's keys.
    Sharded {
        /// The edge-side shard handle (sub-master + geometry + cadence).
        shard: EdgeShard,
        /// Group-parallel codec workers for this edge's engine
        /// (`scheme.workers`; 1 = serial).
        workers: usize,
        /// FFT kernel family for this edge's engine
        /// (`scheme.fft_backend`).
        fft: FftBackend,
    },
}

/// The edge's per-step codec engine: either the shared `RunCodec` or its
/// own rotating per-client shard.
enum EdgeEngine<'a> {
    Shared(&'a RunCodec),
    Sharded(ClientCodec),
}

impl EdgeEngine<'_> {
    fn encode(&mut self, step: u64, z: &Tensor) -> Result<Tensor> {
        match self {
            EdgeEngine::Shared(c) => c.encode(z),
            EdgeEngine::Sharded(cc) => Ok(cc.for_step(step)?.encode(z)),
        }
    }

    fn decode(&mut self, step: u64, s: &Tensor) -> Result<Tensor> {
        match self {
            EdgeEngine::Shared(c) => c.decode(s),
            EdgeEngine::Sharded(cc) => Ok(cc.for_step(step)?.decode(s)),
        }
    }
}

/// The probe objective L = ½·mean(ẑ²) on a raw slice (the codec workers
/// operate on `decode_into` output buffers, no Tensor in the loop).
fn probe_loss_slice(z: &[f32]) -> f32 {
    let n = z.len().max(1) as f32;
    0.5 * z.iter().map(|v| v * v).sum::<f32>() / n
}

fn probe_loss(zhat: &Tensor) -> f32 {
    probe_loss_slice(zhat.data())
}

/// Reject wrong-geometry uplinks before they reach a codec engine (whose
/// `decode_into` asserts on shape — one malicious client must not take a
/// shared worker down).  `d` is the expected carrier dimensionality when
/// statically known.
fn check_uplink_geometry(d: Option<usize>, t: &Tensor, client: usize) -> Result<()> {
    if let Some(d) = d {
        ensure!(
            t.ndim() == 2 && t.shape()[1] == d,
            "client {client}: carrier shape {:?} does not match (G, {d})",
            t.shape()
        );
    }
    Ok(())
}

/// Serve one edge until it sends Shutdown: decode uplink features, evaluate
/// the probe objective, encode the gradients back.  In sharded mode the
/// edge opens with `Msg::ShardHello`, the cloud answers with its fresh
/// `Msg::ShardChallenge`, and the edge's next message must be the
/// `Msg::KeyShard` claim answering it.  When the connection ends — cleanly
/// or with an error — any shard it claimed is released back to the gate
/// ([`ShardGate::release`]) so a reconnecting edge can re-claim it.
pub fn serve_one(
    codec: CloudCodec<'_>,
    transport: &mut dyn Transport,
    client: usize,
) -> Result<ClientReport> {
    serve_one_ops(codec, transport, client, None)
}

/// [`serve_one`] with live ops publication: completed train steps and the
/// session outcome feed the shared [`OpsRegistry`] as they happen, and a
/// requested drain ([`OpsRegistry::request_drain`]) ends the session
/// cleanly at the next message boundary — the blocking path's half of the
/// drain contract (it has no reactor loop to serve the HTTP endpoints from;
/// the registry itself is its publication surface).
pub fn serve_one_ops(
    codec: CloudCodec<'_>,
    transport: &mut dyn Transport,
    client: usize,
    registry: Option<&OpsRegistry>,
) -> Result<ClientReport> {
    serve_one_deadlines(codec, transport, client, registry, SessionDeadlines::default())
}

/// Cloud-side per-session deadlines.  `None` disables a deadline; the
/// defaults disable both, so embedders opt in explicitly (the driver wires
/// the `[resilience]` config keys here).  The *handshake* deadline bounds a
/// connection that never completes key agreement (a connect-and-stall edge
/// must not occupy an accept slot forever); the *idle* deadline bounds a
/// handshaken session that stops sending (a vanished edge is reaped and its
/// shard claim released for the reconnect).
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionDeadlines {
    /// Max wait for key agreement to complete after accept.
    pub handshake: Option<std::time::Duration>,
    /// Max gap between messages once the session is handshaken.
    pub idle: Option<std::time::Duration>,
}

/// Whether an error chain bottoms out in a transport deadline
/// ([`crate::transport::TransportError::TimedOut`] renders exactly this).
fn is_deadline_error(e: &C3Error) -> bool {
    e.to_string().contains("link deadline elapsed")
}

/// [`serve_one_ops`] with cloud-side deadlines applied to the blocking
/// transport: a connection that stalls before key agreement is reaped after
/// `deadlines.handshake`, a handshaken session that goes quiet after
/// `deadlines.idle` — both loudly, with the shard claim released so a
/// reconnecting edge can resume.
pub fn serve_one_deadlines(
    codec: CloudCodec<'_>,
    transport: &mut dyn Transport,
    client: usize,
    registry: Option<&OpsRegistry>,
    deadlines: SessionDeadlines,
) -> Result<ClientReport> {
    let mut shard: Option<ClientCodec> = None;
    let served =
        serve_one_session(codec, transport, client, &mut shard, registry, deadlines);
    // Shard re-claim: this connection is over on every path through the
    // session loop.  The gate frees the claim only if THIS slot owns it
    // (and a rejected claim leaves `shard` empty anyway).
    if let (CloudCodec::Sharded(gate), Some(cc)) = (codec, shard.as_ref()) {
        gate.release(client, cc.client_id());
    }
    if let Some(reg) = registry {
        match &served {
            Ok(_) => reg.note_client_finished(),
            Err(_) => reg.note_client_failed(),
        }
    }
    let (steps, last_loss) = served?;
    let stats = transport.stats();
    Ok(ClientReport {
        client,
        shard: shard.as_ref().map(|cc| cc.client_id()),
        steps,
        tx_bytes: stats.tx(),
        rx_bytes: stats.rx(),
        tx_msgs: stats.tx_msgs.load(std::sync::atomic::Ordering::Relaxed),
        rx_msgs: stats.rx_msgs.load(std::sync::atomic::Ordering::Relaxed),
        last_loss,
    })
}

/// The protocol loop behind [`serve_one`], factored out so the caller can
/// release the shard claim on *every* exit path (clean shutdown and error
/// alike).  Returns (steps served, last loss).
fn serve_one_session(
    codec: CloudCodec<'_>,
    transport: &mut dyn Transport,
    client: usize,
    shard: &mut Option<ClientCodec>,
    registry: Option<&OpsRegistry>,
    deadlines: SessionDeadlines,
) -> Result<(u64, f32)> {
    let mut challenged = false;
    let mut pending: Option<(u64, Tensor)> = None;
    let mut steps = 0u64;
    let mut last_loss = 0.0f32;
    // per-connection sequencing state: the edge's first Sequenced envelope
    // locks the session, after which gaps / duplicates / bare frames are
    // connection-fatal — and the cloud mirrors by stamping its own replies
    let mut seq = Seq::new();
    // key agreement done: flips the per-recv deadline from handshake to idle
    let mut handshaken = false;
    loop {
        // drain: stop admitting at the message boundary (a blocking recv
        // in progress still completes — the blocking path cannot interrupt
        // it, so drain latency here is one message, not zero)
        if let Some(reg) = registry {
            if reg.drain_state() != DrainState::Serving {
                break;
            }
        }
        let want = if handshaken { deadlines.idle } else { deadlines.handshake };
        if deadlines.handshake.is_some() || deadlines.idle.is_some() {
            // best-effort: transports without OS deadlines (in-proc) report
            // false and serve without reaping — the reactor path covers them
            let _ = transport.set_deadline(want, want);
        }
        let raw = match transport.recv() {
            Ok(m) => m,
            Err(e) if want.is_some() && is_deadline_error(&e) => {
                if let Some(reg) = registry {
                    reg.note_client_reaped();
                }
                bail!(
                    "client {client}: {} deadline elapsed; reaping the connection",
                    if handshaken { "idle" } else { "handshake" }
                );
            }
            Err(e) => return Err(e),
        };
        let msg = seq
            .accept(raw)
            .map_err(|e| C3Error::msg(format!("client {client}: {e}")))?;
        match msg {
            Msg::KeySeed { .. } => {
                // keys already derived from the shared seed at construction
                ensure!(
                    !codec.is_sharded(),
                    "client {client}: KeySeed handshake while key sharding is \
                     enabled (expected ShardHello)"
                );
                handshaken = true;
            }
            Msg::ShardHello => {
                let CloudCodec::Sharded(gate) = codec else {
                    bail!(
                        "client {client}: ShardHello but key sharding is not \
                         enabled on this cloud"
                    );
                };
                ensure!(!challenged, "client {client}: duplicate ShardHello");
                challenged = true;
                let nonce = gate.issue_nonce(client)?;
                transport.send(&Msg::ShardChallenge { nonce })?;
            }
            Msg::KeyShard { client_id, epoch, proof } => {
                let CloudCodec::Sharded(gate) = codec else {
                    bail!(
                        "client {client}: KeyShard handshake but key sharding \
                         is not enabled on this cloud"
                    );
                };
                ensure!(
                    shard.is_none(),
                    "client {client}: duplicate KeyShard handshake"
                );
                // construction is lazy so the backend/worker knobs apply
                // before the first keygen, which then runs on this client's
                // own serving thread at its first codec call — concurrent
                // admissions never serialize behind it
                let mut cc =
                    gate.admit(client, client_id, epoch, proof)?.client_codec_lazy();
                cc.set_workers(gate.workers);
                cc.set_fft_backend(gate.fft_backend());
                *shard = Some(cc);
                handshaken = true;
            }
            Msg::Resume { client_id, epoch, last_acked_step, proof } => {
                let CloudCodec::Sharded(gate) = codec else {
                    bail!(
                        "client {client}: Resume handshake but key sharding \
                         is not enabled on this cloud"
                    );
                };
                ensure!(
                    shard.is_none(),
                    "client {client}: Resume after key agreement"
                );
                let (sh, resume_step) =
                    gate.resume(client, client_id, epoch, last_acked_step, proof)?;
                let mut cc = sh.client_codec_lazy();
                cc.set_workers(gate.workers);
                cc.set_fft_backend(gate.fft_backend());
                *shard = Some(cc);
                handshaken = true;
                if let Some(reg) = registry {
                    reg.note_resume();
                }
                transport.send(&Msg::ResumeOk { resume_step })?;
            }
            Msg::Features { step, tensor } => {
                ensure!(
                    pending.is_none(),
                    "client {client}: Features while a step is pending"
                );
                ensure!(
                    !codec.is_sharded() || shard.is_some(),
                    "client {client}: Features before the KeyShard handshake"
                );
                check_uplink_geometry(codec.wire_d(), &tensor, client)?;
                pending = Some((step, tensor));
            }
            Msg::TrainLabels { step, .. } => {
                let (fstep, s) = pending
                    .take()
                    .with_context(|| format!("client {client}: labels before features"))?;
                ensure!(
                    fstep == step,
                    "client {client}: label step mismatch {step} != {fstep}"
                );
                // gẑ = dL/dẑ = ẑ/N, compressed for the downlink like the
                // real cloud compresses cut-layer gradients
                let (loss, gs) = match (codec, shard.as_mut()) {
                    (CloudCodec::Shared(rc), _) => {
                        let zhat = rc.decode(&s)?;
                        let loss = probe_loss(&zhat);
                        let gz = zhat.scale(1.0 / zhat.len().max(1) as f32);
                        (loss, rc.encode(&gz)?)
                    }
                    (CloudCodec::Sharded(_), Some(cc)) => {
                        let c3 = cc.for_step(step)?;
                        let zhat = c3.decode(&s);
                        let loss = probe_loss(&zhat);
                        let gz = zhat.scale(1.0 / zhat.len().max(1) as f32);
                        (loss, c3.encode(&gz))
                    }
                    (CloudCodec::Sharded(_), None) => {
                        bail!("client {client}: labels before the KeyShard handshake")
                    }
                };
                // advance the re-claim watermark: a reconnect after this
                // step must be admitted at the epoch it resumes in
                if let (CloudCodec::Sharded(gate), Some(cc)) = (codec, shard.as_ref()) {
                    gate.observe_step(cc.client_id(), step);
                }
                last_loss = loss;
                steps += 1;
                if let Some(reg) = registry {
                    reg.note_step(loss);
                }
                send_session_frame(transport, &mut seq, Msg::Gradients { step, tensor: gs })?;
                send_session_frame(
                    transport,
                    &mut seq,
                    Msg::StepStats { step, loss, ncorrect: 0.0 },
                )?;
            }
            Msg::EvalFeatures { step, tensor, labels } => {
                ensure!(
                    !codec.is_sharded() || shard.is_some(),
                    "client {client}: EvalFeatures before the KeyShard handshake"
                );
                check_uplink_geometry(codec.wire_d(), &tensor, client)?;
                let loss = match (codec, shard.as_mut()) {
                    (CloudCodec::Shared(rc), _) => probe_loss(&rc.decode(&tensor)?),
                    (CloudCodec::Sharded(_), Some(cc)) => {
                        probe_loss(&cc.for_step(step)?.decode(&tensor))
                    }
                    (CloudCodec::Sharded(_), None) => unreachable!("checked above"),
                };
                send_session_frame(
                    transport,
                    &mut seq,
                    Msg::EvalStats { step, loss, ncorrect: labels.len() as f32 },
                )?;
            }
            Msg::Shutdown => break,
            other => bail!("client {client}: unexpected message {other:?}"),
        }
    }
    Ok((steps, last_loss))
}

/// Send one cloud data frame, sequenced iff the edge locked the session
/// into sequencing (the cloud mirrors the edge's opt-in; handshake replies
/// stay bare everywhere).
fn send_session_frame(
    transport: &mut dyn Transport,
    seq: &mut Seq,
    msg: Msg,
) -> Result<()> {
    if seq.locked() {
        transport.send(&seq.stamp(msg))
    } else {
        transport.send(&msg)
    }
}

/// Thread-per-client serving over a live accept loop: unlike
/// [`serve_clients`] (which takes a fixed transport set), the cloud keeps
/// accepting for the whole session, so an edge that disconnects mid-stream
/// can reconnect, prove its shard again through `Msg::Resume`, and finish —
/// faults become recoveries.  Each accepted connection gets its own serving
/// thread and a monotonically increasing accept slot (the gate grows its
/// challenge table on demand).
///
/// A session that ends in a transport or protocol error — the *expected*
/// shape of a mid-stream disconnect under churn — releases its shard claim,
/// feeds [`OpsRegistry::note_client_failed`], and is otherwise tolerated:
/// the serve returns once `expected_clean` sessions ended with a clean
/// `Msg::Shutdown`, reporting exactly those.  Pass `deadlines` with an idle
/// bound so a half-open connection cannot park its serving thread forever.
pub fn serve_clients_accept(
    codec: CloudCodec<'_>,
    listener: std::net::TcpListener,
    expected_clean: usize,
    registry: &OpsRegistry,
    deadlines: SessionDeadlines,
) -> Result<MultiStats> {
    use std::sync::atomic::Ordering as AOrd;
    listener
        .set_nonblocking(true)
        .map_err(|e| C3Error::msg(format!("accept listener: {e}")))?;
    let clean = AtomicU64::new(0);
    let reports: Mutex<Vec<ClientReport>> = Mutex::new(Vec::new());
    std::thread::scope(|sc| -> Result<()> {
        let mut slot = 0usize;
        while (clean.load(AOrd::Acquire) as usize) < expected_clean {
            if registry.drain_state() != DrainState::Serving {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let ci = slot;
                    slot += 1;
                    let clean = &clean;
                    let reports = &reports;
                    sc.spawn(move || {
                        // an unwrappable stream is dropped; the edge retries
                        let Ok(mut tp) = crate::transport::tcp::Tcp::from_stream(stream)
                        else {
                            return;
                        };
                        match serve_one_deadlines(
                            codec,
                            &mut tp,
                            ci,
                            Some(registry),
                            deadlines,
                        ) {
                            Ok(rep) => {
                                if let Ok(mut r) = reports.lock() {
                                    r.push(rep);
                                }
                                clean.fetch_add(1, AOrd::AcqRel);
                            }
                            // churn casualty: claim already released, failure
                            // already counted — the reconnect finishes the job
                            Err(_) => {}
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(C3Error::msg(format!("accept: {e}"))),
            }
        }
        Ok(())
    })?;
    let mut reports = reports
        .into_inner()
        .map_err(|_| C3Error::msg("accept-serve report lock poisoned"))?;
    reports.sort_by_key(|r| r.client);
    if registry.drain_state() == DrainState::Draining {
        registry.mark_drained();
    }
    Ok(MultiStats { per_client: reports, reactor_io: None })
}

/// Serve N edges concurrently, one OS thread per client.
pub fn serve_clients<T: Transport>(
    codec: CloudCodec<'_>,
    transports: Vec<T>,
) -> Result<MultiStats> {
    let mut reports = std::thread::scope(|sc| -> Result<Vec<ClientReport>> {
        let handles: Vec<_> = transports
            .into_iter()
            .enumerate()
            .map(|(ci, mut tp)| sc.spawn(move || serve_one(codec, &mut tp, ci)))
            .collect();
        let mut reports = Vec::with_capacity(handles.len());
        for h in handles {
            reports.push(
                h.join()
                    .map_err(|_| C3Error::msg("cloud client thread panicked"))??,
            );
        }
        Ok(reports)
    })?;
    reports.sort_by_key(|r| r.client);
    Ok(MultiStats { per_client: reports, reactor_io: None })
}

/// [`serve_clients`] with live ops publication into a shared registry —
/// the blocking-path twin of [`serve_clients_reactor_ops`].  Every client
/// thread feeds the same [`OpsRegistry`] and honors a requested drain at
/// its next message boundary.
pub fn serve_clients_with_ops<T: Transport>(
    codec: CloudCodec<'_>,
    transports: Vec<T>,
    registry: &OpsRegistry,
) -> Result<MultiStats> {
    let mut reports = std::thread::scope(|sc| -> Result<Vec<ClientReport>> {
        let handles: Vec<_> = transports
            .into_iter()
            .enumerate()
            .map(|(ci, mut tp)| {
                sc.spawn(move || serve_one_ops(codec, &mut tp, ci, Some(registry)))
            })
            .collect();
        let mut reports = Vec::with_capacity(handles.len());
        for h in handles {
            reports.push(
                h.join()
                    .map_err(|_| C3Error::msg("cloud client thread panicked"))??,
            );
        }
        Ok(reports)
    })?;
    reports.sort_by_key(|r| r.client);
    if registry.drain_state() == DrainState::Draining {
        registry.mark_drained();
    }
    Ok(MultiStats { per_client: reports, reactor_io: None })
}

// ---------------------------------------------------------------------------
// Reactor serving: one I/O thread, a codec worker pool, N edges.
// ---------------------------------------------------------------------------

/// A unit of codec compute parsed from one client's protocol stream.
struct Job {
    client: usize,
    step: u64,
    kind: JobKind,
    /// The client's rotating key shard (sharded serving); `None` means the
    /// worker uses the shared codec.  One job in flight per client keeps
    /// the mutex uncontended — it exists to move the codec between worker
    /// threads, not to serialize concurrent access.
    shard: Option<Arc<Mutex<ClientCodec>>>,
}

enum JobKind {
    /// Features + labels arrived: decode, evaluate, encode the gradient.
    Train(Tensor),
    /// Eval request: decode and evaluate only (`usize` = label count).
    Eval(Tensor, usize),
}

/// What a codec worker hands back to the reactor thread.
struct Done {
    client: usize,
    result: Result<DoneOk>,
}

struct DoneOk {
    is_train: bool,
    loss: f32,
    /// Ready-to-queue wire frames (workers serialize replies too, keeping
    /// the reactor thread to pure I/O).
    frames: Vec<Vec<u8>>,
}

/// Per-client protocol state machine driven by reactor events.
#[derive(Default)]
struct ClientSm {
    /// A `ShardHello` arrived and the challenge went out (sharded serving
    /// only; rejects duplicate hellos).
    challenged: bool,
    /// The rotating per-client codec admitted by the KeyShard handshake
    /// (sharded serving only).
    shard: Option<Arc<Mutex<ClientCodec>>>,
    /// The shard id this client claimed.
    shard_id: Option<u64>,
    /// Features awaiting their TrainLabels companion.
    pending: Option<(u64, Tensor)>,
    /// Parsed jobs not yet dispatched to the worker pool.
    jobs: std::collections::VecDeque<Job>,
    /// A job for this client is on the worker pool.
    inflight: bool,
    steps: u64,
    last_loss: f32,
    /// Shutdown received; close once compute and outbox drain.
    finishing: bool,
    /// Connection observed closed by the peer.
    peer_gone: bool,
    closed: bool,
    /// Per-connection frame sequencing (unwraps inbound envelopes, stamps
    /// outbound data frames once the edge locks the session).
    seq: Seq,
    /// Key agreement completed (flips the reaping deadline from handshake
    /// to idle).
    handshaken: bool,
    /// Last inbound message (or accept) time; `None` until the serve loop
    /// arms deadlines for this client.
    last_activity: Option<std::time::Instant>,
    /// Why this client was failed, if it was.  One broken client never
    /// takes the pool down (matching thread-per-client, where a failing
    /// `serve_one` only errors its own thread); the aggregate error
    /// surfaces after every healthy client finishes.
    failed: Option<String>,
}

/// Fail one client without disturbing the rest: close its connection, drop
/// its queued work, release any shard it claimed (so a restarted edge can
/// re-handshake), and record the reason for the final aggregate error.
fn fail_client(
    codec: CloudCodec<'_>,
    st: &mut [ClientSm],
    reactor: &mut Reactor,
    open: &mut usize,
    client: usize,
    why: String,
    registry: &OpsRegistry,
) {
    let c = &mut st[client];
    if c.closed {
        return;
    }
    registry.note_client_failed();
    c.failed = Some(why);
    c.jobs.clear();
    c.pending = None;
    c.closed = true;
    // shard re-claim: the gate frees the claim only if THIS slot owns it
    // (and a rejected claimant never got a shard_id anyway)
    if let (CloudCodec::Sharded(gate), Some(id)) = (codec, c.shard_id) {
        gate.release(client, id);
    }
    reactor.close(client);
    *open -= 1;
}

/// One codec worker: pull jobs, run decode → probe step → encode with a
/// thread-local `C3Scratch` (zero codec allocations in steady state on the
/// host venue), serialize the reply frames, hand them back — then ring the
/// reactor's waker so an epoll-blocked I/O thread picks the result up
/// immediately instead of on its next timed tick.  Sharded jobs carry their
/// client's rotating codec; shared jobs use the pool-wide one.
fn codec_worker(
    codec: CloudCodec<'_>,
    jobs: &Mutex<std::sync::mpsc::Receiver<Job>>,
    done: std::sync::mpsc::Sender<Done>,
    waker: WakeHandle,
) {
    let engine = match codec {
        CloudCodec::Shared(rc) => rc.host_engine(),
        CloudCodec::Sharded(_) => None,
    };
    // scratch depends only on D, so one buffer serves every shard
    let scratch_d = match codec {
        CloudCodec::Shared(rc) => rc.host_engine().map(|c3| c3.keys.d),
        CloudCodec::Sharded(g) => Some(g.d()),
    };
    let mut scratch = scratch_d.map(C3Scratch::new);
    let mut zbuf: Vec<f32> = Vec::new();
    let mut sbuf: Vec<f32> = Vec::new();
    loop {
        let job = jobs.lock().expect("job queue lock").recv();
        let Ok(job) = job else { break };
        let client = job.client;
        let result = run_job(codec, engine, scratch.as_mut(), &mut zbuf, &mut sbuf, job);
        if done.send(Done { client, result }).is_err() {
            break;
        }
        // ring AFTER the result is visible on the channel: the pump clears
        // the eventfd before draining, so this completion cannot be lost
        // even if the ring lands exactly as the pump enters epoll_wait
        waker.wake();
    }
}

/// Route one job to the right engine: the client's own rotating shard, the
/// shared zero-allocation host engine, or the generic [`RunCodec`] fallback
/// (artifact/identity venues).
fn run_job(
    codec: CloudCodec<'_>,
    engine: Option<&C3>,
    scratch: Option<&mut C3Scratch>,
    zbuf: &mut Vec<f32>,
    sbuf: &mut Vec<f32>,
    job: Job,
) -> Result<DoneOk> {
    let Job { step, kind, shard, .. } = job;
    match shard {
        Some(cc) => {
            let scr = scratch.context("sharded job without worker scratch (internal)")?;
            let mut cc = cc
                .lock()
                .map_err(|_| C3Error::msg("per-client codec lock poisoned"))?;
            let c3 = cc.for_step(step)?;
            run_engine_job(c3, scr, zbuf, sbuf, step, kind)
        }
        None => match (engine, scratch) {
            (Some(c3), Some(scr)) => run_engine_job(c3, scr, zbuf, sbuf, step, kind),
            _ => {
                let CloudCodec::Shared(rc) = codec else {
                    bail!("sharded serve dispatched a shard-less job (internal)");
                };
                run_fallback_job(rc, step, kind)
            }
        },
    }
}

/// Decode → probe objective → (for training) gradient encode on the
/// zero-allocation host engine: per-worker scratch, recycled feature and
/// carrier buffers, workers serialize the reply frames too.
fn run_engine_job(
    c3: &C3,
    scr: &mut C3Scratch,
    zbuf: &mut Vec<f32>,
    sbuf: &mut Vec<f32>,
    step: u64,
    kind: JobKind,
) -> Result<DoneOk> {
    use crate::transport::wire;
    let (r, d) = (c3.keys.r, c3.keys.d);
    match kind {
        JobKind::Train(s) => {
            let g = s.shape()[0];
            zbuf.resize(g * r * d, 0.0);
            c3.decode_into(&s, zbuf, scr);
            let loss = probe_loss_slice(zbuf);
            // gẑ = dL/dẑ = ẑ/N, compressed for the downlink like the
            // real cloud compresses cut-layer gradients
            let inv = 1.0 / zbuf.len().max(1) as f32;
            for v in zbuf.iter_mut() {
                *v *= inv;
            }
            let gz = Tensor::from_vec(&[g * r, d], std::mem::take(zbuf));
            sbuf.resize(g * d, 0.0);
            c3.encode_into(&gz, sbuf, scr);
            *zbuf = gz.into_vec(); // reclaim the buffer for the next job
            let gmsg = Msg::Gradients {
                step,
                tensor: Tensor::from_vec(&[g, d], std::mem::take(sbuf)),
            };
            let frames = vec![
                wire::encode(&gmsg),
                wire::encode(&Msg::StepStats { step, loss, ncorrect: 0.0 }),
            ];
            // reclaim the encode buffer too: with both buffers recycled the
            // worker's steady state really is allocation-free on the codec
            // side (only the reply frames are fresh)
            let Msg::Gradients { tensor, .. } = gmsg else { unreachable!() };
            *sbuf = tensor.into_vec();
            Ok(DoneOk { is_train: true, loss, frames })
        }
        JobKind::Eval(s, nlabels) => {
            let g = s.shape()[0];
            zbuf.resize(g * r * d, 0.0);
            c3.decode_into(&s, zbuf, scr);
            let loss = probe_loss_slice(zbuf);
            let frames = vec![wire::encode(&Msg::EvalStats {
                step,
                loss,
                ncorrect: nlabels as f32,
            })];
            Ok(DoneOk { is_train: false, loss, frames })
        }
    }
}

/// The allocating [`RunCodec`] path for venues without a host engine
/// (artifact, identity).
fn run_fallback_job(codec: &RunCodec, step: u64, kind: JobKind) -> Result<DoneOk> {
    use crate::transport::wire;
    match kind {
        JobKind::Train(s) => {
            let zhat = codec.decode(&s)?;
            let loss = probe_loss(&zhat);
            let gz = zhat.scale(1.0 / zhat.len().max(1) as f32);
            let gs = codec.encode(&gz)?;
            let frames = vec![
                wire::encode(&Msg::Gradients { step, tensor: gs }),
                wire::encode(&Msg::StepStats { step, loss, ncorrect: 0.0 }),
            ];
            Ok(DoneOk { is_train: true, loss, frames })
        }
        JobKind::Eval(s, nlabels) => {
            let loss = probe_loss(&codec.decode(&s)?);
            let frames = vec![wire::encode(&Msg::EvalStats {
                step,
                loss,
                ncorrect: nlabels as f32,
            })];
            Ok(DoneOk { is_train: false, loss, frames })
        }
    }
}

/// Parse one client message into protocol state / compute jobs.  An `Err`
/// is a *per-client* protocol violation — the caller fails that client only.
fn handle_client_msg(
    codec: CloudCodec<'_>,
    c: &mut ClientSm,
    reactor: &mut Reactor,
    client: usize,
    msg: Msg,
    registry: &OpsRegistry,
) -> Result<()> {
    ensure!(!c.finishing, "client {client}: message after Shutdown");
    match msg {
        Msg::KeySeed { .. } => {
            // keys already derived from the shared seed at construction
            ensure!(
                !codec.is_sharded(),
                "client {client}: KeySeed handshake while key sharding is \
                 enabled (expected ShardHello)"
            );
            c.handshaken = true;
        }
        Msg::ShardHello => {
            let CloudCodec::Sharded(gate) = codec else {
                bail!(
                    "client {client}: ShardHello but key sharding is not \
                     enabled on this cloud"
                );
            };
            ensure!(!c.challenged, "client {client}: duplicate ShardHello");
            c.challenged = true;
            // issuing a nonce is cheap (one PRNG draw under the gate lock);
            // the challenge reply rides the normal outbox
            let nonce = gate.issue_nonce(client)?;
            reactor.queue_frame(
                client,
                crate::transport::wire::encode(&Msg::ShardChallenge { nonce }),
            );
        }
        Msg::KeyShard { client_id, epoch, proof } => {
            let CloudCodec::Sharded(gate) = codec else {
                bail!(
                    "client {client}: KeyShard handshake but key sharding is \
                     not enabled on this cloud"
                );
            };
            ensure!(
                c.shard.is_none(),
                "client {client}: duplicate KeyShard handshake"
            );
            // admission validates the claim (against this slot's own
            // challenge) only; keygen is deferred to the codec worker pool
            // (first job) so a handshake storm never stalls this single
            // I/O thread
            let sh = gate.admit(client, client_id, epoch, proof)?;
            let mut cc = sh.client_codec_lazy();
            cc.set_fft_backend(gate.fft_backend());
            c.shard = Some(Arc::new(Mutex::new(cc)));
            c.shard_id = Some(client_id);
            c.handshaken = true;
        }
        Msg::Resume { client_id, epoch, last_acked_step, proof } => {
            let CloudCodec::Sharded(gate) = codec else {
                bail!(
                    "client {client}: Resume handshake but key sharding is \
                     not enabled on this cloud"
                );
            };
            ensure!(
                c.shard.is_none(),
                "client {client}: Resume after key agreement"
            );
            let (sh, resume_step) =
                gate.resume(client, client_id, epoch, last_acked_step, proof)?;
            let mut cc = sh.client_codec_lazy();
            cc.set_fft_backend(gate.fft_backend());
            c.shard = Some(Arc::new(Mutex::new(cc)));
            c.shard_id = Some(client_id);
            c.handshaken = true;
            registry.note_resume();
            reactor.queue_frame(
                client,
                crate::transport::wire::encode(&Msg::ResumeOk { resume_step }),
            );
        }
        Msg::Features { step, tensor } => {
            ensure!(
                c.pending.is_none(),
                "client {client}: Features while a step is pending"
            );
            ensure!(
                !codec.is_sharded() || c.shard.is_some(),
                "client {client}: Features before the KeyShard handshake"
            );
            check_uplink_geometry(codec.wire_d(), &tensor, client)?;
            c.pending = Some((step, tensor));
        }
        Msg::TrainLabels { step, .. } => {
            let (fstep, s) = c
                .pending
                .take()
                .with_context(|| format!("client {client}: labels before features"))?;
            ensure!(
                fstep == step,
                "client {client}: label step mismatch {step} != {fstep}"
            );
            c.jobs.push_back(Job {
                client,
                step,
                kind: JobKind::Train(s),
                shard: c.shard.clone(),
            });
            // advance the re-claim watermark: a reconnect after this step
            // must be admitted at the epoch it resumes in
            if let (CloudCodec::Sharded(gate), Some(id)) = (codec, c.shard_id) {
                gate.observe_step(id, step);
            }
        }
        Msg::EvalFeatures { step, tensor, labels } => {
            ensure!(
                !codec.is_sharded() || c.shard.is_some(),
                "client {client}: EvalFeatures before the KeyShard handshake"
            );
            check_uplink_geometry(codec.wire_d(), &tensor, client)?;
            c.jobs.push_back(Job {
                client,
                step,
                kind: JobKind::Eval(tensor, labels.len()),
                shard: c.shard.clone(),
            });
        }
        Msg::Shutdown => {
            c.finishing = true;
            reactor.set_hold(client, true);
        }
        other => bail!("client {client}: unexpected message {other:?}"),
    }
    Ok(())
}

/// Apply one finished compute result: queue its reply frames and update the
/// client state machine.  A worker-side error fails that client only.
fn apply_done(
    codec: CloudCodec<'_>,
    done: Done,
    st: &mut [ClientSm],
    reactor: &mut Reactor,
    open: &mut usize,
    inflight_total: &mut usize,
    registry: &OpsRegistry,
) {
    let Done { client, result } = done;
    st[client].inflight = false;
    *inflight_total -= 1;
    match result {
        Ok(ok) => {
            let c = &mut st[client];
            if c.closed {
                return; // late result for an already-failed client
            }
            if ok.is_train {
                c.steps += 1;
                c.last_loss = ok.loss;
                registry.note_step(ok.loss);
            }
            for frame in ok.frames {
                // mirror the edge's sequencing opt-in: stamp the
                // pre-encoded worker frame without re-serializing it
                let frame = if c.seq.locked() {
                    crate::transport::wire::seq_frame(c.seq.take_tx(), &frame)
                } else {
                    frame
                };
                reactor.queue_frame(client, frame);
            }
        }
        Err(e) => {
            fail_client(codec, st, reactor, open, client, format!("codec worker: {e}"), registry);
        }
    }
}

// ---------------------------------------------------------------------------
// Ops control plane: the registry both serve paths publish into, plus the
// /metrics, /healthz and /drain handling the reactor loop answers off its
// own readiness pump (the transport::reactor ops listener).
// ---------------------------------------------------------------------------

/// Where a serving session stands in its graceful-drain lifecycle.  The
/// machine is one-way: `Serving → Draining → Drained`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainState {
    /// Normal operation: clients admitted and served.
    Serving,
    /// Drain requested: no new work admitted; in-flight compute finishes,
    /// outboxes flush, and shard claims release as each client retires.
    Draining,
    /// Every client retired and the serving call has returned (or is
    /// about to).
    Drained,
}

/// Live counters both serving styles publish while they run, shared with
/// scrapers through an `Arc` so ops state outlives the serve call itself.
/// All counters are monotone (Prometheus counter semantics); the drain
/// field is the one-way [`DrainState`] machine.
#[derive(Debug)]
pub struct OpsRegistry {
    steps_total: AtomicU64,
    clients_finished: AtomicU64,
    clients_failed: AtomicU64,
    reloads_total: AtomicU64,
    reconnects_total: AtomicU64,
    resumes_total: AtomicU64,
    clients_reaped_total: AtomicU64,
    drain: AtomicU8,
    step_loss: Mutex<Histogram>,
    retry_backoff_ms: Mutex<Histogram>,
}

impl Default for OpsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl OpsRegistry {
    /// Fresh registry: zero counters, [`DrainState::Serving`].
    pub fn new() -> Self {
        OpsRegistry {
            steps_total: AtomicU64::new(0),
            clients_finished: AtomicU64::new(0),
            clients_failed: AtomicU64::new(0),
            reloads_total: AtomicU64::new(0),
            reconnects_total: AtomicU64::new(0),
            resumes_total: AtomicU64::new(0),
            clients_reaped_total: AtomicU64::new(0),
            drain: AtomicU8::new(0),
            // probe losses span orders of magnitude across geometries, so
            // the buckets are log-spaced rather than latency-shaped
            step_loss: Mutex::new(Histogram::new(vec![
                1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 100.0,
            ])),
            // exponential backoff doubles per attempt, so the buckets do too
            retry_backoff_ms: Mutex::new(Histogram::new(vec![
                10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0,
            ])),
        }
    }

    /// Record one edge reconnect attempt (retry runner, after the first
    /// connection).
    pub fn note_reconnect(&self) {
        self.reconnects_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one accepted `Msg::Resume` (session picked back up with exact
    /// accounting).
    pub fn note_resume(&self) {
        self.resumes_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one client reaped by a cloud-side handshake/idle deadline.
    pub fn note_client_reaped(&self) {
        self.clients_reaped_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one retry backoff sleep, in milliseconds.
    pub fn observe_backoff_ms(&self, ms: f64) {
        if let Ok(mut h) = self.retry_backoff_ms.lock() {
            h.observe(ms);
        }
    }

    /// Edge reconnect attempts recorded so far.
    pub fn reconnects_total(&self) -> u64 {
        self.reconnects_total.load(Ordering::Relaxed)
    }

    /// Accepted session resumes so far.
    pub fn resumes_total(&self) -> u64 {
        self.resumes_total.load(Ordering::Relaxed)
    }

    /// Clients reaped by cloud-side deadlines so far.
    pub fn clients_reaped_total(&self) -> u64 {
        self.clients_reaped_total.load(Ordering::Relaxed)
    }

    /// Snapshot of the retry-backoff histogram (milliseconds).
    pub fn retry_backoff_snapshot(&self) -> Histogram {
        match self.retry_backoff_ms.lock() {
            Ok(h) => h.clone(),
            Err(e) => e.into_inner().clone(),
        }
    }

    /// Record one completed training step and its probe loss.
    pub fn note_step(&self, loss: f32) {
        self.steps_total.fetch_add(1, Ordering::Relaxed);
        if let Ok(mut h) = self.step_loss.lock() {
            h.observe(loss as f64);
        }
    }

    /// Record one client retiring cleanly.
    pub fn note_client_finished(&self) {
        self.clients_finished.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one client failed (protocol violation, transport error, …).
    pub fn note_client_failed(&self) {
        self.clients_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one applied SIGHUP knob reload.
    pub fn note_reload(&self) {
        self.reloads_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Training steps served so far, summed over clients.
    pub fn steps_total(&self) -> u64 {
        self.steps_total.load(Ordering::Relaxed)
    }

    /// Clients retired cleanly so far.
    pub fn clients_finished(&self) -> u64 {
        self.clients_finished.load(Ordering::Relaxed)
    }

    /// Clients failed so far.
    pub fn clients_failed(&self) -> u64 {
        self.clients_failed.load(Ordering::Relaxed)
    }

    /// SIGHUP reloads applied so far.
    pub fn reloads_total(&self) -> u64 {
        self.reloads_total.load(Ordering::Relaxed)
    }

    /// Request a graceful drain (idempotent; `POST /drain` lands here, and
    /// embedders may call it directly).  The serving loop stops admitting
    /// work, finishes what is in flight, flushes outboxes, releases shard
    /// claims and returns.  A registry already `Drained` stays drained.
    pub fn request_drain(&self) {
        let _ = self.drain.compare_exchange(0, 1, Ordering::AcqRel, Ordering::Acquire);
    }

    /// Current drain lifecycle state.
    pub fn drain_state(&self) -> DrainState {
        match self.drain.load(Ordering::Acquire) {
            0 => DrainState::Serving,
            1 => DrainState::Draining,
            _ => DrainState::Drained,
        }
    }

    /// Promote `Draining` to `Drained` once every client has retired.
    fn mark_drained(&self) {
        self.drain.store(2, Ordering::Release);
    }

    /// Snapshot of the per-step probe-loss histogram.
    pub fn step_loss_snapshot(&self) -> Histogram {
        match self.step_loss.lock() {
            Ok(h) => h.clone(),
            Err(e) => e.into_inner().clone(),
        }
    }
}

/// The SIGHUP-reloadable knob subset.  `None` fields leave the running
/// value untouched.  Deliberately small: the rotation cadence is *excluded*
/// (epoch derivation is lockstep between edges and cloud, so changing it
/// mid-run would desynchronize every key schedule), and so is the codec
/// worker count (the pool is scoped to the serve call); both are recorded
/// with their rationale in ARCHITECTURE.md.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpsReload {
    /// New per-client outbox bound, in frames (clamped to ≥ 1).
    pub max_outbox_frames: Option<usize>,
    /// New sweep-backend idle poll backoff, in microseconds.
    pub poll_sleep_us: Option<u64>,
}

/// Ops control-plane wiring for [`serve_clients_reactor_ops`].
pub struct OpsOptions {
    /// Pre-bound listener the reactor answers `GET /metrics`,
    /// `GET /healthz` and `POST /drain` on.  It is registered with the
    /// reactor's own readiness backend — no extra threads, no async
    /// runtime.  `None` serves without HTTP endpoints.
    pub listener: Option<std::net::TcpListener>,
    /// Counters the serve publishes into; keep a clone of the `Arc` to
    /// read them while (and after) the serve runs.
    pub registry: Arc<OpsRegistry>,
    /// Invoked once per observed SIGHUP (the handler is installed when
    /// this is `Some`); returns the knob values to apply.
    pub reload: Option<Box<dyn Fn() -> OpsReload + Send>>,
}

impl Default for OpsOptions {
    fn default() -> Self {
        OpsOptions { listener: None, registry: Arc::new(OpsRegistry::new()), reload: None }
    }
}

impl std::fmt::Debug for OpsOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OpsOptions")
            .field("listener", &self.listener)
            .field("registry", &self.registry)
            .field("reload", &self.reload.is_some())
            .finish()
    }
}

/// Render the Prometheus text-format `/metrics` payload from live serving
/// state.  Byte totals sum per-connection `LinkStats`, which survive close
/// — so every series here is monotone across a client's whole lifecycle.
fn render_metrics(codec: CloudCodec<'_>, reactor: &Reactor, registry: &OpsRegistry) -> String {
    let mut w = PromWriter::new();
    w.counter(
        "c3sl_steps_total",
        "Training steps served, summed over clients.",
        registry.steps_total(),
    );
    w.counter(
        "c3sl_clients_finished_total",
        "Clients that retired cleanly.",
        registry.clients_finished(),
    );
    w.counter(
        "c3sl_clients_failed_total",
        "Clients failed and disconnected.",
        registry.clients_failed(),
    );
    w.counter("c3sl_reloads_total", "SIGHUP knob reloads applied.", registry.reloads_total());
    w.counter(
        "c3sl_reconnects_total",
        "Edge reconnect attempts after a dropped connection.",
        registry.reconnects_total(),
    );
    w.counter(
        "c3sl_resumes_total",
        "Sessions resumed with exact accounting via Msg::Resume.",
        registry.resumes_total(),
    );
    w.counter(
        "c3sl_clients_reaped_total",
        "Clients reaped by cloud-side handshake/idle deadlines.",
        registry.clients_reaped_total(),
    );
    w.gauge(
        "c3sl_clients_open",
        "Client connections currently open.",
        reactor.open_count() as f64,
    );
    w.gauge(
        "c3sl_drain_state",
        "Drain lifecycle: 0 = serving, 1 = draining, 2 = drained.",
        match registry.drain_state() {
            DrainState::Serving => 0.0,
            DrainState::Draining => 1.0,
            DrainState::Drained => 2.0,
        },
    );
    w.counter("c3sl_reactor_wakeups_total", "Readiness pump wakeups.", reactor.wakeups());
    w.family(
        "c3sl_reactor_backend",
        "Readiness backend actually in use (series value is always 1).",
        "gauge",
    );
    w.sample("c3sl_reactor_backend", &[("backend", reactor.backend().name())], 1.0);
    let (mut tx, mut rx) = (0u64, 0u64);
    for ci in 0..reactor.client_count() {
        let s = reactor.stats(ci);
        tx += s.tx();
        rx += s.rx();
    }
    w.counter("c3sl_tx_bytes_total", "Bytes sent to clients (cloud downlink).", tx);
    w.counter("c3sl_rx_bytes_total", "Bytes received from clients (cloud uplink).", rx);
    w.histogram("c3sl_step_loss", "Per-step probe loss.", &registry.step_loss_snapshot());
    w.histogram(
        "c3sl_retry_backoff_ms",
        "Edge retry backoff sleeps, in milliseconds.",
        &registry.retry_backoff_snapshot(),
    );
    if let CloudCodec::Sharded(gate) = codec {
        w.family(
            "c3sl_shard_claimed",
            "1 when the shard id is currently claimed by a client.",
            "gauge",
        );
        for id in 0..gate.shards() {
            let shard = id.to_string();
            let v = if gate.claimant(id as u64).is_some() { 1.0 } else { 0.0 };
            w.sample("c3sl_shard_claimed", &[("shard", &shard)], v);
        }
        w.family(
            "c3sl_shard_last_step",
            "Re-claim watermark per shard: highest uplinked training step (-1 before the first).",
            "gauge",
        );
        for id in 0..gate.shards() {
            let shard = id.to_string();
            let v = gate.last_step(id as u64).map_or(-1.0, |s| s as f64);
            w.sample("c3sl_shard_last_step", &[("shard", &shard)], v);
        }
    }
    w.finish()
}

/// Render the `/healthz` body.  `degraded: true` reports a reactor whose
/// requested epoll backend broke and degraded itself to the timed sweep —
/// the run is still correct, just no longer event-driven.
fn render_healthz(reactor: &Reactor, registry: &OpsRegistry) -> String {
    let requested = reactor.config().backend;
    let actual = reactor.backend();
    format!(
        "status: ok\nbackend: {}\nrequested: {}\ndegraded: {}\ndrain: {}\nopen_clients: {}\n",
        actual.name(),
        requested.name(),
        actual != requested,
        match registry.drain_state() {
            DrainState::Serving => "serving",
            DrainState::Draining => "draining",
            DrainState::Drained => "drained",
        },
        reactor.open_count(),
    )
}

/// Answer every ops request the reactor's pump surfaced this pass; returns
/// whether any was served (progress, for the idle policy).  `POST /drain`
/// flips the registry to `Draining` — the serve loop folds that into its
/// clients in the same pass.
fn handle_ops_requests(
    codec: CloudCodec<'_>,
    reactor: &mut Reactor,
    registry: &OpsRegistry,
) -> bool {
    let reqs = reactor.take_ops_requests();
    let mut served = false;
    for req in reqs {
        served = true;
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/metrics") => {
                let body = render_metrics(codec, reactor, registry);
                reactor.ops_respond(
                    req.conn,
                    200,
                    "OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    body.as_bytes(),
                );
            }
            ("GET", "/healthz") => {
                let body = render_healthz(reactor, registry);
                reactor.ops_respond(
                    req.conn,
                    200,
                    "OK",
                    "text/plain; charset=utf-8",
                    body.as_bytes(),
                );
            }
            ("POST", "/drain") => {
                registry.request_drain();
                reactor.ops_respond(
                    req.conn,
                    200,
                    "OK",
                    "text/plain; charset=utf-8",
                    b"draining\n",
                );
            }
            (_, "/drain") => {
                reactor.ops_respond(
                    req.conn,
                    405,
                    "Method Not Allowed",
                    "text/plain; charset=utf-8",
                    b"drain is a POST\n",
                );
            }
            _ => {
                reactor.ops_respond(
                    req.conn,
                    404,
                    "Not Found",
                    "text/plain; charset=utf-8",
                    b"unknown ops path\n",
                );
            }
        }
    }
    served
}

/// The epoll backend's idle block, in milliseconds.  A pure safety net:
/// every real wake arrives as an event (socket readiness, in-proc doorbell,
/// the worker-pool eventfd), so this tick only bounds recovery from a
/// hypothetically missed event — and sets the idle wakeup floor the scale
/// bench measures (~10/s, against the sweep backend's ~10k timed polls/s).
const EPOLL_IDLE_TIMEOUT_MS: i32 = 100;

/// Serve N edges from ONE I/O thread plus `workers` codec threads: the
/// reactor pumps frames, per-client state machines parse the protocol, a
/// shared job queue feeds the codec pool, and replies flow back through
/// bounded per-client outboxes.  Reports the same per-client accounting as
/// [`serve_clients`] — the two serving styles are interchangeable to the
/// edges and to the byte-accounting tests.  With [`CloudCodec::Sharded`]
/// the pool runs per-client `ClientCodec` instances (admitted by the
/// KeyShard handshake, rotated on epoch boundaries) instead of one shared
/// codec.
///
/// On the `epoll` readiness backend ([`ReactorConfig::backend`], the Linux
/// default) the I/O thread *blocks* in `epoll_wait` whenever a full pass
/// finds no work, and the codec workers ring an eventfd waker after every
/// finished job — so an idle fleet costs no CPU and a finished reply never
/// waits out a timed tick.  On the portable `sweep` backend the loop keeps
/// its original `poll_us` backoff.  [`MultiStats::reactor_io`] reports the
/// backend actually used, the pump wakeup count and the I/O-thread CPU time.
pub fn serve_clients_reactor(
    codec: CloudCodec<'_>,
    conns: Vec<Box<dyn ReactorConn>>,
    workers: usize,
    cfg: ReactorConfig,
) -> Result<MultiStats> {
    serve_clients_reactor_ops(codec, conns, workers, cfg, OpsOptions::default())
}

/// [`serve_clients_reactor`] with the ops control plane attached: the
/// listener in `ops` (if any) becomes one more pollable fd on the
/// reactor's readiness backend, and `GET /metrics`, `GET /healthz` and
/// `POST /drain` are answered from the serve loop itself — no extra
/// threads.  SIGHUP applies the [`OpsReload`] knob subset via the `ops`
/// reload callback, and every counter the loop touches lands in the
/// shared [`OpsRegistry`] as it happens.
pub fn serve_clients_reactor_ops(
    codec: CloudCodec<'_>,
    conns: Vec<Box<dyn ReactorConn>>,
    workers: usize,
    cfg: ReactorConfig,
    ops: OpsOptions,
) -> Result<MultiStats> {
    let OpsOptions { listener, registry, reload } = ops;
    if conns.is_empty() {
        return Ok(MultiStats::default());
    }
    let cpu0 = thread_cpu_time();
    let mut reactor = Reactor::new(conns, cfg);
    if let Some(listener) = listener {
        reactor
            .serve_ops(listener)
            .map_err(|e| C3Error::msg(format!("registering ops listener: {e}")))?;
    }
    if reload.is_some() {
        install_hangup_handler();
    }
    let waker = reactor.waker();
    let (job_tx, job_rx) = std::sync::mpsc::channel::<Job>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<Done>();
    let job_rx = Mutex::new(job_rx);
    let served = std::thread::scope(|sc| {
        for _ in 0..workers.max(1) {
            let done_tx = done_tx.clone();
            let waker = waker.clone();
            let job_rx = &job_rx;
            sc.spawn(move || codec_worker(codec, job_rx, done_tx, waker));
        }
        // only the workers hold Done senders now, so a dead pool is
        // observable as a disconnected done_rx
        drop(done_tx);
        // job_tx moves into the loop and drops on return, which is what
        // releases the workers (and lets this scope join them)
        reactor_serve_loop(
            codec,
            &mut reactor,
            job_tx,
            &done_rx,
            &registry,
            reload.as_deref(),
            ServeMode::Fixed,
            SessionDeadlines::default(),
        )
    });
    let mut stats = served?;
    stats.reactor_io = Some(ReactorIoStats {
        backend: reactor.backend(),
        wakeups: reactor.wakeups(),
        io_cpu_seconds: match (cpu0, thread_cpu_time()) {
            (Some(a), Some(b)) => Some((b - a).max(0.0)),
            _ => None,
        },
    });
    Ok(stats)
}

/// Reactor serving over a live TCP accept loop — the one-I/O-thread twin of
/// [`serve_clients_accept`].  The data listener registers with the
/// reactor's own readiness backend (one more pollable fd, like the ops
/// listener), every accepted connection becomes a fresh dynamic slot, and
/// the serve returns once `expected_clean` sessions retired with a clean
/// `Msg::Shutdown`.  Mid-stream disconnects release their shard claims and
/// feed the failure counters without aborting the serve, so an edge driving
/// [`crate::coordinator::resilience::run_edge_retry`] reconnects, proves
/// its shard through `Msg::Resume`, and finishes with exact accounting.
/// `deadlines` reaps connections that stall before key agreement or go
/// quiet mid-session (checked on the reactor's bounded idle tick).
pub fn serve_clients_reactor_accept(
    codec: CloudCodec<'_>,
    listener: std::net::TcpListener,
    expected_clean: usize,
    workers: usize,
    cfg: ReactorConfig,
    ops: OpsOptions,
    deadlines: SessionDeadlines,
) -> Result<MultiStats> {
    let OpsOptions { listener: ops_listener, registry, reload } = ops;
    let cpu0 = thread_cpu_time();
    let mut reactor = Reactor::new(Vec::new(), cfg);
    reactor
        .serve_accept(listener)
        .map_err(|e| C3Error::msg(format!("registering data accept listener: {e}")))?;
    if let Some(ops_listener) = ops_listener {
        reactor
            .serve_ops(ops_listener)
            .map_err(|e| C3Error::msg(format!("registering ops listener: {e}")))?;
    }
    if reload.is_some() {
        install_hangup_handler();
    }
    let waker = reactor.waker();
    let (job_tx, job_rx) = std::sync::mpsc::channel::<Job>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<Done>();
    let job_rx = Mutex::new(job_rx);
    let served = std::thread::scope(|sc| {
        for _ in 0..workers.max(1) {
            let done_tx = done_tx.clone();
            let waker = waker.clone();
            let job_rx = &job_rx;
            sc.spawn(move || codec_worker(codec, job_rx, done_tx, waker));
        }
        drop(done_tx);
        reactor_serve_loop(
            codec,
            &mut reactor,
            job_tx,
            &done_rx,
            &registry,
            reload.as_deref(),
            ServeMode::Accept { expected_clean },
            deadlines,
        )
    });
    let mut stats = served?;
    stats.reactor_io = Some(ReactorIoStats {
        backend: reactor.backend(),
        wakeups: reactor.wakeups(),
        io_cpu_seconds: match (cpu0, thread_cpu_time()) {
            (Some(a), Some(b)) => Some((b - a).max(0.0)),
            _ => None,
        },
    });
    Ok(stats)
}

/// Termination policy for [`reactor_serve_loop`].
#[derive(Clone, Copy)]
enum ServeMode {
    /// Serve a fixed connection set until every client retires.
    Fixed,
    /// Live accept loop: serve until this many sessions ended with a clean
    /// `Msg::Shutdown` (churn casualties release their claims, feed the
    /// failure counters, and are otherwise tolerated — the reconnect
    /// finishes the job).
    Accept {
        /// Clean retirements to serve before returning.
        expected_clean: usize,
    },
}

fn reactor_serve_loop(
    codec: CloudCodec<'_>,
    reactor: &mut Reactor,
    job_tx: std::sync::mpsc::Sender<Job>,
    done_rx: &std::sync::mpsc::Receiver<Done>,
    registry: &OpsRegistry,
    reload: Option<&(dyn Fn() -> OpsReload + Send)>,
    mode: ServeMode,
    deadlines: SessionDeadlines,
) -> Result<MultiStats> {
    use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
    let n = reactor.client_count();
    let mut st: Vec<ClientSm> = (0..n).map(|_| ClientSm::default()).collect();
    let mut reports: Vec<Option<ClientReport>> = (0..n).map(|_| None).collect();
    let reap_enabled = deadlines.handshake.is_some() || deadlines.idle.is_some();
    if reap_enabled {
        // fixed connections are "accepted" at serve start; accept-mode
        // clients get their timestamp from Event::Accepted
        let now = std::time::Instant::now();
        for c in st.iter_mut() {
            c.last_activity = Some(now);
        }
    }
    let mut events: Vec<Event> = Vec::new();
    let mut open = n;
    let mut clean = 0usize;
    let mut inflight_total = 0usize;
    // event-driven: once a full pass finds no work, the NEXT pass blocks in
    // epoll_wait — sockets, doorbells and the worker waker cut it short
    let mut idle = false;
    // SIGHUPs observed before the serve started are not reload requests
    let mut seen_hups = hangup_count();

    loop {
        match mode {
            ServeMode::Fixed => {
                if open == 0 {
                    break;
                }
            }
            ServeMode::Accept { expected_clean } => {
                if clean >= expected_clean {
                    break;
                }
                // a requested drain with nobody left to retire is terminal
                // even though the clean target was never reached
                if registry.drain_state() != DrainState::Serving && open == 0 {
                    break;
                }
            }
        }
        // Reactor::new normalized the bounds; re-read them every pass so a
        // SIGHUP retune below reaches step 3's hold and step 5's backoff
        let cfg = reactor.config();
        // re-checked every pass: a reactor whose epoll_wait breaks degrades
        // itself to the sweep backend mid-serve, and the idle policy below
        // must follow it (a blocking-style idle on a sweep pump would spin)
        let event_driven = reactor.backend() == ReadinessBackend::Epoll;
        // 1) one discovery pass (blocking only when event-driven and idle);
        //    per-client failures (protocol violations, transport errors,
        //    mid-protocol hangups) close that client only
        let timeout_ms = if event_driven && idle { EPOLL_IDLE_TIMEOUT_MS } else { 0 };
        let mut worked = reactor.poll_wait(&mut events, timeout_ms);
        for ev in events.drain(..) {
            match ev {
                Event::Accepted { client } => {
                    // a reconnecting (or brand-new) edge: grow the state
                    // tables to cover its fresh slot
                    while st.len() <= client {
                        st.push(ClientSm::default());
                        reports.push(None);
                    }
                    st[client].last_activity = Some(std::time::Instant::now());
                    open += 1;
                }
                Event::Msg { client, msg } => {
                    if st[client].closed {
                        continue;
                    }
                    if reap_enabled {
                        st[client].last_activity = Some(std::time::Instant::now());
                    }
                    // sequencing layer: unwrap (and validate) before the
                    // protocol sees the message — gaps, duplicates, swaps
                    // and lapsed stamping all fail this client loudly here
                    let msg = match st[client].seq.accept(msg) {
                        Ok(m) => m,
                        Err(e) => {
                            fail_client(
                                codec,
                                &mut st,
                                reactor,
                                &mut open,
                                client,
                                e.to_string(),
                                registry,
                            );
                            continue;
                        }
                    };
                    if let Err(e) = handle_client_msg(
                        codec,
                        &mut st[client],
                        reactor,
                        client,
                        msg,
                        registry,
                    ) {
                        fail_client(
                            codec,
                            &mut st,
                            reactor,
                            &mut open,
                            client,
                            e.to_string(),
                            registry,
                        );
                    }
                }
                Event::Closed { client } => {
                    if st[client].finishing || st[client].closed {
                        st[client].peer_gone = true;
                    } else {
                        fail_client(
                            codec,
                            &mut st,
                            reactor,
                            &mut open,
                            client,
                            "connection closed mid-protocol".into(),
                            registry,
                        );
                    }
                }
                Event::Error { client, error } => {
                    fail_client(
                        codec,
                        &mut st,
                        reactor,
                        &mut open,
                        client,
                        error.to_string(),
                        registry,
                    );
                }
            }
        }

        // 2) collect finished compute without blocking
        loop {
            match done_rx.try_recv() {
                Ok(done) => {
                    worked = true;
                    apply_done(
                        codec,
                        done,
                        &mut st,
                        reactor,
                        &mut open,
                        &mut inflight_total,
                        registry,
                    );
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    ensure!(
                        inflight_total == 0,
                        "codec worker pool died with {inflight_total} jobs in flight"
                    );
                    break;
                }
            }
        }

        // 2b) ops control plane: answer any /metrics, /healthz, /drain
        //     requests the pump surfaced, apply SIGHUP knob reloads, and
        //     fold a requested drain into every still-serving client — each
        //     then retires through the normal step-4 path (compute and
        //     outbox drain, report filled, shard claim released), so drain
        //     accounting is exactly the clean-shutdown accounting
        worked |= handle_ops_requests(codec, reactor, registry);
        if let Some(reload) = reload {
            let hups = hangup_count();
            if hups != seen_hups {
                seen_hups = hups;
                let r = reload();
                if let Some(frames) = r.max_outbox_frames {
                    reactor.set_max_outbox_frames(frames);
                }
                if let Some(us) = r.poll_sleep_us {
                    reactor.set_poll_sleep_us(us);
                }
                registry.note_reload();
                worked = true;
            }
        }
        if registry.drain_state() == DrainState::Draining {
            for ci in 0..st.len() {
                let c = &mut st[ci];
                if !c.closed && !c.finishing {
                    c.finishing = true;
                    c.pending = None;
                    reactor.set_hold(ci, true);
                    worked = true;
                }
            }
        }

        // 2c) cloud-side deadlines: reap a client that stalls before key
        //     agreement (handshake deadline) or goes quiet mid-session
        //     (idle deadline) — its shard claim releases with the failure,
        //     so a reconnecting edge can resume the session.  The pump's
        //     idle block is bounded (EPOLL_IDLE_TIMEOUT_MS), so this check
        //     runs at least every ~100 ms even on a silent fleet.
        if reap_enabled {
            let now = std::time::Instant::now();
            for ci in 0..st.len() {
                let (reap, handshaken) = {
                    let c = &st[ci];
                    if c.closed || c.finishing {
                        (false, false)
                    } else {
                        let limit = if c.handshaken {
                            deadlines.idle
                        } else {
                            deadlines.handshake
                        };
                        match (c.last_activity, limit) {
                            (Some(t0), Some(lim)) => {
                                (now.duration_since(t0) > lim, c.handshaken)
                            }
                            _ => (false, false),
                        }
                    }
                };
                if reap {
                    registry.note_client_reaped();
                    fail_client(
                        codec,
                        &mut st,
                        reactor,
                        &mut open,
                        ci,
                        format!(
                            "{} deadline elapsed; reaping the connection",
                            if handshaken { "idle" } else { "handshake" }
                        ),
                        registry,
                    );
                    worked = true;
                }
            }
        }

        // 3) dispatch ready jobs (one in flight per client keeps replies in
        //    step order) and refresh job-queue backpressure holds
        for ci in 0..st.len() {
            let c = &mut st[ci];
            if c.closed {
                continue;
            }
            if !c.inflight {
                if let Some(job) = c.jobs.pop_front() {
                    job_tx
                        .send(job)
                        .map_err(|_| C3Error::msg("codec worker pool unavailable"))?;
                    c.inflight = true;
                    inflight_total += 1;
                    worked = true;
                }
            }
            if !c.finishing {
                let hold = c.jobs.len() >= cfg.max_pending_jobs;
                reactor.set_hold(ci, hold);
            }
        }

        // 4) retire clients whose protocol, compute and outbox all drained,
        //    releasing any shard claim for a future reconnect
        for ci in 0..st.len() {
            let c = &mut st[ci];
            if !c.closed
                && c.finishing
                && !c.inflight
                && c.jobs.is_empty()
                && (c.peer_gone || reactor.outbox_len(ci) == 0)
            {
                let stats = reactor.stats(ci);
                reports[ci] = Some(ClientReport {
                    client: ci,
                    shard: c.shard_id,
                    steps: c.steps,
                    tx_bytes: stats.tx(),
                    rx_bytes: stats.rx(),
                    tx_msgs: stats.tx_msgs.load(std::sync::atomic::Ordering::Relaxed),
                    rx_msgs: stats.rx_msgs.load(std::sync::atomic::Ordering::Relaxed),
                    last_loss: c.last_loss,
                });
                if let (CloudCodec::Sharded(gate), Some(id)) = (codec, c.shard_id) {
                    gate.release(ci, id);
                }
                reactor.close(ci);
                c.closed = true;
                open -= 1;
                clean += 1;
                registry.note_client_finished();
                worked = true;
            }
        }

        // 5) idle policy.  Event-driven: flag the loop so the next pass
        //    blocks in epoll_wait (the worker waker and every connection fd
        //    cut that block short — no completion ever waits out a tick).
        //    Sweep: park on the completion channel, waking immediately on
        //    finished compute and at worst poll_us later for socket data.
        if worked {
            idle = false;
        } else {
            // accept mode idles with zero open clients too, parked on the
            // (registered) data listener instead of spinning
            if event_driven {
                idle = true;
            } else {
                match done_rx
                    .recv_timeout(std::time::Duration::from_micros(cfg.poll_sleep_us.max(1)))
                {
                    Ok(done) => apply_done(
                        codec,
                        done,
                        &mut st,
                        reactor,
                        &mut open,
                        &mut inflight_total,
                        registry,
                    ),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        ensure!(
                            inflight_total == 0,
                            "codec worker pool died with {inflight_total} jobs in flight"
                        );
                        reactor.idle_sleep();
                    }
                }
            }
        }
    }

    // a requested drain has fully completed once every client retired —
    // promote before surfacing failures so scrapers holding the registry
    // observe the terminal state even for a partly-failed fleet
    if registry.drain_state() == DrainState::Draining {
        registry.mark_drained();
    }

    // every healthy client has fully retired; only now surface failures,
    // matching serve_clients (whose per-client threads all finish before
    // the aggregate join reports the first error).  Accept mode tolerates
    // failed sessions by design — they are the churn the resume protocol
    // recovers from, already recorded on the registry's failure counters.
    if matches!(mode, ServeMode::Fixed) {
        let failures: Vec<String> = st
            .iter()
            .enumerate()
            .filter_map(|(ci, c)| c.failed.as_ref().map(|why| format!("client {ci}: {why}")))
            .collect();
        ensure!(
            failures.is_empty(),
            "reactor serve: {} client(s) failed: {}",
            failures.len(),
            failures.join("; ")
        );
    }

    let per_client: Vec<ClientReport> = match mode {
        ServeMode::Fixed => reports
            .into_iter()
            .map(|r| r.expect("every retired client leaves a report"))
            .collect(),
        // accept mode: exactly the clean retirements leave reports
        ServeMode::Accept { .. } => reports.into_iter().flatten().collect(),
    };
    Ok(MultiStats {
        per_client,
        reactor_io: None, // filled by serve_clients_reactor
    })
}

/// One synthetic edge: hold a (B, D) feature buffer, uplink `encode(z)`,
/// apply the decoded downlink gradient with a toy SGD step, repeat.  The
/// probe loss contracts geometrically when the codec round trip is faithful,
/// which is exactly what the multi-edge tests assert.
///
/// Key agreement happens first ([`EdgeCodec`]), and the edge speaks first
/// in every mode: `Msg::KeySeed` announces the shared construction seed, or
/// — sharded — the edge opens with `Msg::ShardHello`, receives the cloud's
/// fresh `Msg::ShardChallenge`, and answers with the `Msg::KeyShard` claim
/// whose proof binds the nonce.  Either way the keys themselves never cross
/// the wire, and a cloud that honors the handshake arrives at the same
/// KeySet this edge encodes with.
pub fn run_edge(
    keys: EdgeCodec<'_>,
    transport: &mut dyn Transport,
    steps: u64,
    data_seed: u64,
    batch: usize,
    d: usize,
) -> Result<EdgeReport> {
    run_edge_resumed(keys, transport, 0, steps, data_seed, batch, d)
}

/// [`run_edge`] resuming at training step `first_step` instead of 0 — a
/// reconnecting edge picking its session back up where the previous
/// connection died.  The sharded handshake announces (and proves) the key
/// epoch of `first_step` rather than epoch 0, matching the gate's re-claim
/// admission window, and step numbering continues from `first_step` so the
/// cloud's per-shard watermark keeps advancing monotonically.  The probe
/// state `z` is re-drawn from `data_seed` — the toy objective carries no
/// cross-connection optimizer state, only the step cursor matters.
pub fn run_edge_resumed(
    keys: EdgeCodec<'_>,
    transport: &mut dyn Transport,
    first_step: u64,
    steps: u64,
    data_seed: u64,
    batch: usize,
    d: usize,
) -> Result<EdgeReport> {
    ensure!(steps >= 1, "edge needs at least one step");
    let mut rng = Rng::new(data_seed);
    let mut zdata = vec![0.0f32; batch * d];
    rng.fill_normal(&mut zdata, 0.0, 1.0);
    let mut z = Tensor::from_vec(&[batch, d], zdata);

    let mut engine = match keys {
        EdgeCodec::Shared { codec, key_seed } => {
            transport.send(&Msg::KeySeed { seed: key_seed })?;
            EdgeEngine::Shared(codec)
        }
        EdgeCodec::Sharded { shard, workers, fft } => {
            transport.send(&Msg::ShardHello)?;
            let nonce = match transport.recv()? {
                Msg::ShardChallenge { nonce } => nonce,
                other => bail!("edge expected ShardChallenge, got {other:?}"),
            };
            let epoch = shard.epoch_of_step(first_step);
            transport.send(&Msg::KeyShard {
                client_id: shard.client_id(),
                epoch,
                proof: shard.proof(epoch, nonce),
            })?;
            let mut cc = shard.client_codec_lazy();
            cc.set_workers(workers);
            cc.set_fft_backend(fft);
            EdgeEngine::Sharded(cc)
        }
    };

    // Effective update: z ← (I − c·A²)z with A = D∘E.  decode = encodeᵀ
    // makes A PSD, but its top eigenvalue is max_f Σ_i |K̂_i(f)|² (well above
    // 1 for random keys), so c must be small for every mode to contract:
    // c·μ_max² < 2.  c = 0.005 leaves a wide margin at the R/D used here
    // while still shrinking the probe loss measurably over a few steps.
    let lr = 0.005f32 * (batch * d) as f32;
    let (mut first_loss, mut last_loss) = (0.0f32, 0.0f32);
    // every data frame rides a Sequenced envelope (the handshake above went
    // bare): a dropped, duplicated or swapped frame in either direction is
    // a loud sequencing error instead of a silent wrong-step decode
    let mut seq = Seq::new();
    for step in first_step..first_step.saturating_add(steps) {
        let s = engine.encode(step, &z)?;
        transport.send(&seq.stamp(Msg::Features { step, tensor: s }))?;
        transport.send(&seq.stamp(Msg::TrainLabels { step, labels: Labels(vec![0; batch]) }))?;

        let gs = match seq
            .accept(transport.recv()?)
            .map_err(|e| C3Error::msg(format!("edge: {e}")))?
        {
            Msg::Gradients { step: gstep, tensor } => {
                ensure!(gstep == step, "gradient step mismatch: {gstep} != {step}");
                tensor
            }
            other => bail!("edge expected Gradients, got {other:?}"),
        };
        let loss = match seq
            .accept(transport.recv()?)
            .map_err(|e| C3Error::msg(format!("edge: {e}")))?
        {
            Msg::StepStats { loss, .. } => loss,
            other => bail!("edge expected StepStats, got {other:?}"),
        };

        let gz = engine.decode(step, &gs)?;
        ensure!(
            gz.shape() == z.shape(),
            "gradient shape {:?} vs features {:?}",
            gz.shape(),
            z.shape()
        );
        z = z.sub(&gz.scale(lr));

        if step == first_step {
            first_loss = loss;
        }
        last_loss = loss;
    }
    transport.send(&seq.stamp(Msg::Shutdown))?;
    let stats = transport.stats();
    Ok(EdgeReport {
        steps,
        first_loss,
        last_loss,
        tx_bytes: stats.tx(),
        rx_bytes: stats.rx(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{inproc_pair, inproc_reactor_pair, InProc};

    #[test]
    fn single_client_roundtrip_decreases_probe_loss() {
        let (mut etp, ctp) = inproc_pair();
        let cloud_codec = RunCodec::host(7, 2, 128, 1);
        let edge_codec = RunCodec::host(7, 2, 128, 1);
        let (cloud, edge) = std::thread::scope(|sc| {
            let cloud_codec = &cloud_codec;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Shared(cloud_codec), &mut tp, 0)
            });
            let edge = run_edge(
                EdgeCodec::Shared { codec: &edge_codec, key_seed: 7 },
                &mut etp,
                8,
                3,
                4,
                128,
            )
            .unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.steps, 8);
        assert_eq!(cloud.shard, None);
        assert_eq!(edge.steps, 8);
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease: {} -> {}",
            edge.first_loss,
            edge.last_loss
        );
        // the two halves of the link must agree byte-for-byte
        assert_eq!(cloud.rx_bytes, edge.tx_bytes);
        assert_eq!(cloud.tx_bytes, edge.rx_bytes);
    }

    #[test]
    fn sharded_single_client_roundtrip_with_rotation() {
        // The full sharded contract through the blocking path: KeyShard
        // handshake, per-client keys, and an epoch rotation mid-run (12
        // steps at 6 steps/epoch) — no step lost, loss decreasing, bytes
        // balanced.  Geometry note: first/last loss are measured under
        // *different* key draws, so the final epoch holds enough steps (5
        // updates before the last measurement) and D is large enough that
        // contraction dominates the key-draw variance of the probe loss.
        let (mut etp, ctp) = inproc_pair();
        let ring = KeyRing::new(0x5EED, 2, 512, 6);
        let gate = ShardGate::new(ring, 1);
        let (cloud, edge) = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            let edge = run_edge(
                EdgeCodec::Sharded {
                    shard: ring.edge_shard(0),
                    workers: 1,
                    fft: FftBackend::default(),
                },
                &mut etp,
                12,
                3,
                4,
                512,
            )
            .unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.steps, 12);
        assert_eq!(cloud.shard, Some(0));
        assert_eq!(edge.steps, 12);
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease across rotations: {} -> {}",
            edge.first_loss,
            edge.last_loss
        );
        assert_eq!(cloud.rx_bytes, edge.tx_bytes);
        assert_eq!(cloud.tx_bytes, edge.rx_bytes);
    }

    #[test]
    fn sharded_roundtrip_with_packed_backend() {
        // The sharded contract with the PACKED FFT kernels on both endpoints
        // (gate side via with_fft_backend, edge side via EdgeCodec::Sharded
        // { fft }): challenge handshake, rotation mid-run, no step lost, and
        // the probe objective still contracts through the packed codec.
        let (mut etp, ctp) = inproc_pair();
        let ring = KeyRing::new(0x9ACC, 2, 512, 6);
        let gate = ShardGate::new(ring, 1).with_fft_backend(FftBackend::Packed);
        assert_eq!(gate.fft_backend(), FftBackend::Packed);
        let (cloud, edge) = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            let edge = run_edge(
                EdgeCodec::Sharded {
                    shard: ring.edge_shard(0),
                    workers: 1,
                    fft: FftBackend::Packed,
                },
                &mut etp,
                12,
                3,
                4,
                512,
            )
            .unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.steps, 12);
        assert_eq!(cloud.shard, Some(0));
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease on the packed backend: {} -> {}",
            edge.first_loss,
            edge.last_loss
        );
        assert_eq!(cloud.rx_bytes, edge.tx_bytes);
        assert_eq!(cloud.tx_bytes, edge.rx_bytes);
    }

    #[test]
    fn shard_gate_rejects_bad_announcements() {
        let ring = KeyRing::new(1, 2, 64, 0);
        let gate = ShardGate::new(ring, 2);
        let n0 = gate.issue_nonce(0).unwrap();
        let n1 = gate.issue_nonce(1).unwrap();
        assert_ne!(n0, n1, "each slot gets its own challenge");
        // wrong (out-of-range) shard id
        let err = gate.admit(0, 5, 0, ring.shard_proof(5, 0, n0)).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // stale epoch
        let err = gate.admit(0, 0, 3, ring.shard_proof(0, 3, n0)).unwrap_err();
        assert!(err.to_string().contains("stale key epoch"), "{err}");
        // proof derived from a different master
        let other = KeyRing::new(2, 2, 64, 0);
        let err = gate.admit(0, 0, 0, other.shard_proof(0, 0, n0)).unwrap_err();
        assert!(err.to_string().contains("proof mismatch"), "{err}");
        // announcing the raw sub-seed (the pre-proof secret) must also fail:
        // the wire value is a PRF of the seed, never the seed itself
        let err = gate.admit(0, 0, 0, ring.subseed(0, 0)).unwrap_err();
        assert!(err.to_string().contains("proof mismatch"), "{err}");
        // a correct proof answering the OTHER slot's challenge must fail —
        // each proof is bound to its own connection's nonce
        let err = gate.admit(0, 0, 0, ring.shard_proof(0, 0, n1)).unwrap_err();
        assert!(err.to_string().contains("proof mismatch"), "{err}");
        // a valid claim succeeds, its duplicate is rejected...
        assert!(gate.admit(0, 0, 0, ring.shard_proof(0, 0, n0)).is_ok());
        let err = gate.admit(1, 0, 0, ring.shard_proof(0, 0, n1)).unwrap_err();
        assert!(err.to_string().contains("already claimed"), "{err}");
        // ...the duplicate's VERIFIED proof burned slot 1's challenge (a
        // challenge answers at most one proof, whatever the claim outcome)...
        let err = gate.admit(1, 1, 0, ring.shard_proof(1, 0, n1)).unwrap_err();
        assert!(err.to_string().contains("no challenge issued"), "{err}");
        // ...and after a re-hello the other shard is still claimable — no
        // rejection burned it
        let n1b = gate.issue_nonce(1).unwrap();
        assert!(gate.admit(1, 1, 0, ring.shard_proof(1, 0, n1b)).is_ok());
        // accept slots are NOT capped by the shard count: a connection
        // beyond the served shards still gets its challenge, and rejection
        // happens at the claim with the real reason
        let n5 = gate.issue_nonce(5).unwrap();
        let err = gate.admit(5, 5, 0, ring.shard_proof(5, 0, n5)).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn shard_gate_release_enables_reclaim_but_never_steals_live_claims() {
        let ring = KeyRing::new(0x0C1A_11ED, 2, 64, 0);
        let gate = ShardGate::new(ring, 1);
        let n0 = gate.issue_nonce(0).unwrap();
        assert!(gate.admit(0, 0, 0, ring.shard_proof(0, 0, n0)).is_ok());
        // a LIVE claim cannot be stolen, even with a perfectly valid proof
        // answering the thief's own fresh challenge
        let n1 = gate.issue_nonce(1).unwrap();
        let err = gate.admit(1, 0, 0, ring.shard_proof(0, 0, n1)).unwrap_err();
        assert!(err.to_string().contains("already claimed"), "{err}");
        // the rejected thief holds no shard handle, so its connection
        // teardown releases nothing — the winner's claim survives
        let n2 = gate.issue_nonce(2).unwrap();
        let err = gate.admit(2, 0, 0, ring.shard_proof(0, 0, n2)).unwrap_err();
        assert!(err.to_string().contains("already claimed"), "{err}");
        // the ownership check is MECHANICAL, not call-site discipline: a
        // losing slot releasing the shard it was denied frees nothing...
        gate.release(1, 0);
        let n2b = gate.issue_nonce(4).unwrap();
        let err = gate.admit(4, 0, 0, ring.shard_proof(0, 0, n2b)).unwrap_err();
        assert!(err.to_string().contains("already claimed"), "{err}");
        // ...and an out-of-range release is a best-effort no-op, never a
        // panic
        gate.release(0, 7);
        // once the HOLDER's slot releases, the claim frees and a restarted
        // edge re-handshakes it (fresh challenge, fresh proof)
        gate.release(0, 0);
        // ...but the holder's RECORDED proof is spent: its challenge was
        // burned at admission, so a wire observer replaying the frame on
        // the same accept slot after the release gets nothing
        let err = gate.admit(0, 0, 0, ring.shard_proof(0, 0, n0)).unwrap_err();
        assert!(err.to_string().contains("no challenge issued"), "{err}");
        let n3 = gate.issue_nonce(3).unwrap();
        assert!(gate.admit(3, 0, 0, ring.shard_proof(0, 0, n3)).is_ok());
    }

    #[test]
    fn serve_one_releases_shard_on_error_and_on_clean_shutdown() {
        let ring = KeyRing::new(0x5E55_10F1, 2, 64, 0);
        let gate = ShardGate::new(ring, 1);

        // session 1: the handshake completes, then the edge vanishes
        // mid-protocol — the serve errors AND releases the claim
        let (mut etp, ctp) = inproc_pair();
        let res = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            etp.send(&Msg::ShardHello).unwrap();
            let nonce = match etp.recv().unwrap() {
                Msg::ShardChallenge { nonce } => nonce,
                other => panic!("expected ShardChallenge, got {other:?}"),
            };
            etp.send(&Msg::KeyShard {
                client_id: 0,
                epoch: 0,
                proof: ring.shard_proof(0, 0, nonce),
            })
            .unwrap();
            drop(etp); // hangup mid-protocol
            cloud.join().unwrap()
        });
        assert!(res.is_err(), "mid-protocol hangup must error the session");

        // session 2: the restarted edge re-claims the SAME shard id and
        // trains a full run — it is not locked out by the dead session
        let (mut etp, ctp) = inproc_pair();
        let (cloud, edge) = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 1)
            });
            let edge = run_edge(
                EdgeCodec::Sharded {
                    shard: ring.edge_shard(0),
                    workers: 1,
                    fft: FftBackend::default(),
                },
                &mut etp,
                4,
                3,
                4,
                64,
            )
            .unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.shard, Some(0));
        assert_eq!(cloud.steps, 4);
        assert_eq!(edge.steps, 4);

        // session 2 ended cleanly (Shutdown) — released again, claimable
        let n = gate.issue_nonce(5).unwrap();
        assert!(gate.admit(5, 0, 0, ring.shard_proof(0, 0, n)).is_ok());
    }

    #[test]
    fn reclaim_watermark_opens_the_current_epoch_per_shard() {
        // rotation every 2 steps: epoch_of = 0,0,1,1,2,2,3,...
        let ring = KeyRing::new(0x0E0C_4A11, 2, 64, 2);
        let gate = ShardGate::new(ring, 2);

        // a never-trained shard claims only at the session-start epoch
        let n = gate.issue_nonce(0).unwrap();
        let err = gate.admit(0, 0, 1, ring.shard_proof(0, 1, n)).unwrap_err();
        assert!(err.to_string().contains("stale key epoch"), "{err}");
        let n = gate.issue_nonce(0).unwrap();
        assert!(gate.admit(0, 0, 0, ring.shard_proof(0, 0, n)).is_ok());

        // shard 0 trains through step 5, out-of-order observations
        // included, then its connection drops
        for step in [0u64, 1, 2, 4, 3, 5, 4] {
            gate.observe_step(0, step);
        }
        gate.release(0, 0);

        // the resume point is step 6: epoch_of(5) = 2 and epoch_of(6) = 3
        // are both claimable (the drop landed exactly on a boundary), the
        // session-start epoch no longer is
        let n = gate.issue_nonce(0).unwrap();
        let err = gate.admit(0, 0, 0, ring.shard_proof(0, 0, n)).unwrap_err();
        assert!(err.to_string().contains("stale key epoch"), "{err}");
        let n = gate.issue_nonce(0).unwrap();
        assert!(gate.admit(0, 0, 2, ring.shard_proof(0, 2, n)).is_ok());
        gate.release(0, 0);
        let n = gate.issue_nonce(0).unwrap();
        assert!(gate.admit(0, 0, 3, ring.shard_proof(0, 3, n)).is_ok());

        // the watermark is PER SHARD: sibling shard 1 never trained, so its
        // fresh epoch-0 claim is untouched by shard 0's progress
        let n1 = gate.issue_nonce(1).unwrap();
        assert!(gate.admit(1, 1, 0, ring.shard_proof(1, 0, n1)).is_ok());

        // out-of-range observations are a best-effort no-op, never a panic
        gate.observe_step(7, 100);
    }

    #[test]
    fn revoked_claim_is_refused_despite_valid_proof_and_scoped_per_epoch() {
        // rotation every 2 steps: epoch_of = 0,0,1,1,2,...
        let ring = KeyRing::new(0x0E0C_4A13, 2, 64, 2);
        let gate = ShardGate::new(ring, 2);

        // revocation is an operator decision recorded ahead of the claim
        assert!(gate.revoke(0, 0), "first revocation is new");
        assert!(!gate.revoke(0, 0), "re-revoking the same pair is a no-op");
        assert!(gate.is_revoked(0, 0));
        assert!(!gate.is_revoked(0, 1), "scoped to the revoked epoch");
        assert!(!gate.is_revoked(1, 0), "scoped to the revoked shard");

        // the refused claim carries a VALID proof for the announced epoch —
        // the rejection is policy, not cryptography, and says so
        let n = gate.issue_nonce(0).unwrap();
        let err = gate.admit(0, 0, 0, ring.shard_proof(0, 0, n)).unwrap_err();
        assert!(err.to_string().contains("revoked"), "{err}");
        // the verified proof still burned the challenge: a wire observer
        // cannot replay the refused frame against a fresh policy decision
        let err = gate.admit(0, 0, 0, ring.shard_proof(0, 0, n)).unwrap_err();
        assert!(err.to_string().contains("no challenge issued"), "{err}");

        // the sibling shard is untouched by shard 0's revocation
        let n1 = gate.issue_nonce(1).unwrap();
        assert!(gate.admit(1, 1, 0, ring.shard_proof(1, 0, n1)).is_ok());

        // rotation moves shard 0 past the revoked epoch: once its watermark
        // opens epoch 1 (steps 2..), the shard claims again — revocation
        // retired the COMPROMISED epoch, not the shard
        gate.observe_step(0, 2);
        let n = gate.issue_nonce(0).unwrap();
        assert!(gate.admit(0, 0, 1, ring.shard_proof(0, 1, n)).is_ok());
    }

    #[test]
    fn claimant_and_last_step_expose_exact_gate_accounting() {
        let ring = KeyRing::new(0x0E0C_4A14, 2, 64, 0);
        let gate = ShardGate::new(ring, 1);
        assert_eq!(gate.claimant(0), None);
        assert_eq!(gate.claimant(9), None, "out of range reads as unclaimed");
        assert_eq!(gate.last_step(0), None);
        assert_eq!(gate.last_step(9), None);

        let n = gate.issue_nonce(3).unwrap();
        assert!(gate.admit(3, 0, 0, ring.shard_proof(0, 0, n)).is_ok());
        assert_eq!(gate.claimant(0), Some(3), "claim records the accept slot");
        gate.observe_step(0, 4);
        gate.observe_step(0, 2);
        assert_eq!(gate.last_step(0), Some(4), "watermark is monotonic");

        gate.release(3, 0);
        assert_eq!(gate.claimant(0), None, "release restores exact accounting");
        assert_eq!(gate.last_step(0), Some(4), "the watermark outlives claims");
    }

    #[test]
    fn run_edge_resumed_reclaims_at_the_resume_epoch_end_to_end() {
        // rotation every 2 steps; the shard already trained steps 0..=3 in
        // a previous (simulated) connection, so its resume cursor is step 4
        // and the handshake must announce epoch_of(4) = 2, not epoch 0.
        let ring = KeyRing::new(0x0E0C_4A15, 2, 64, 2);
        let gate = ShardGate::new(ring, 1);
        gate.observe_step(0, 3);

        let (mut etp, ctp) = inproc_pair();
        let (cloud, edge) = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            let edge = run_edge_resumed(
                EdgeCodec::Sharded {
                    shard: ring.edge_shard(0),
                    workers: 1,
                    fft: FftBackend::default(),
                },
                &mut etp,
                4,
                2,
                3,
                4,
                64,
            )
            .unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.shard, Some(0));
        assert_eq!(cloud.steps, 2, "the resumed session served steps 4..6");
        assert_eq!(edge.steps, 2);
        assert_eq!(gate.last_step(0), Some(5), "the watermark kept advancing");
        assert_eq!(gate.claimant(0), None, "clean shutdown released the claim");
    }

    #[test]
    fn reconnect_under_rotation_resumes_at_current_epoch() {
        // End-to-end over the blocking serve path: rotation every 2 steps,
        // the edge trains into epoch 1, drops, and reconnects.  The gate
        // must reject a stale epoch-0 re-claim and admit the claim at the
        // epoch the edge actually resumes in.
        let ring = KeyRing::new(0x0E0C_4A12, 2, 64, 2);
        let gate = ShardGate::new(ring, 1);
        let shard = ring.edge_shard(0);
        let (b, d) = (4usize, 64usize);
        let mut rng = Rng::new(11);
        let mut zdata = vec![0.0f32; b * d];
        rng.fill_normal(&mut zdata, 0.0, 1.0);
        let z = Tensor::from_vec(&[b, d], zdata);

        let drive_steps = |etp: &mut InProc, cc: &mut ClientCodec, steps: std::ops::Range<u64>| {
            for step in steps {
                let s = cc.for_step(step).unwrap().encode(&z);
                etp.send(&Msg::Features { step, tensor: s }).unwrap();
                etp.send(&Msg::TrainLabels { step, labels: Labels(vec![0; b]) })
                    .unwrap();
                match etp.recv().unwrap() {
                    Msg::Gradients { step: gs, .. } => assert_eq!(gs, step),
                    other => panic!("expected Gradients, got {other:?}"),
                }
                match etp.recv().unwrap() {
                    Msg::StepStats { .. } => {}
                    other => panic!("expected StepStats, got {other:?}"),
                }
            }
        };
        let handshake = |etp: &mut InProc, epoch: u64| {
            etp.send(&Msg::ShardHello).unwrap();
            let nonce = match etp.recv().unwrap() {
                Msg::ShardChallenge { nonce } => nonce,
                other => panic!("expected ShardChallenge, got {other:?}"),
            };
            etp.send(&Msg::KeyShard {
                client_id: 0,
                epoch,
                proof: shard.proof(epoch, nonce),
            })
            .unwrap();
        };

        // session 1: claim at epoch 0, train steps 0..4 (the codec rotates
        // into epoch 1 at step 2), then vanish mid-session
        let (mut etp, ctp) = inproc_pair();
        let res = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            handshake(&mut etp, 0);
            let mut cc = shard.client_codec();
            drive_steps(&mut etp, &mut cc, 0..4);
            drop(etp); // vanish — the serve errors and releases the claim
            cloud.join().unwrap()
        });
        assert!(res.is_err(), "hangup must error session 1");

        // session 2a: a re-claim at the stale session-start epoch is
        // rejected — the shard's watermark has moved on
        let (mut etp, ctp) = inproc_pair();
        let res = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 1)
            });
            handshake(&mut etp, 0);
            cloud.join().unwrap()
        });
        let err = res.expect_err("stale epoch-0 re-claim must be rejected");
        assert!(err.to_string().contains("stale key epoch"), "{err}");

        // session 2b: the claim at the CURRENT epoch (resume step 4 →
        // epoch 2) is admitted and training resumes in lockstep
        let resume = 4u64;
        let epoch = shard.epoch_of_step(resume);
        assert_eq!(epoch, 2, "steps 0..4 complete → the edge resumes in epoch 2");
        let (mut etp, ctp) = inproc_pair();
        let report = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 2)
            });
            handshake(&mut etp, epoch);
            // a fresh codec handle: for_step(4) derives epoch-2 keys
            // directly, matching the cloud's freshly admitted shard
            let mut cc = shard.client_codec();
            drive_steps(&mut etp, &mut cc, resume..resume + 2);
            etp.send(&Msg::Shutdown).unwrap();
            cloud.join().unwrap()
        })
        .unwrap();
        assert_eq!(report.shard, Some(0));
        assert_eq!(report.steps, 2);
    }

    #[test]
    fn replayed_proof_rejected_in_a_later_session() {
        // The adversarial replay the challenge leg closes: an observer
        // records a valid KeyShard proof in session 1, then replays it in a
        // later session that reuses the same master.  The new session's
        // gate issues a different challenge, so the recorded proof no
        // longer verifies and the shard id cannot be squatted.
        let ring = KeyRing::new(0xABAD_5EED, 2, 64, 0);
        let shard = ring.edge_shard(0);

        // session 1: the honest edge answers the challenge and is admitted
        let session1 = ShardGate::new(ring, 1);
        let n1 = session1.issue_nonce(0).unwrap();
        let recorded_proof = shard.proof(0, n1);
        assert!(session1.admit(0, 0, 0, recorded_proof).is_ok());

        // session 2, same master: the replayed proof answers the WRONG
        // challenge and is rejected — the slot stays claimable
        let session2 = ShardGate::new(ring, 1);
        let n2 = session2.issue_nonce(0).unwrap();
        assert_ne!(n1, n2, "fresh session must issue a fresh challenge");
        let err = session2.admit(0, 0, 0, recorded_proof).unwrap_err();
        assert!(err.to_string().contains("proof mismatch"), "{err}");
        // ...and the honest edge still gets in afterwards
        assert!(session2.admit(0, 0, 0, shard.proof(0, n2)).is_ok());

        // a claim sent before any challenge was issued is an internal error,
        // not a panic
        let session3 = ShardGate::new(ring, 1);
        let err = session3.admit(0, 0, 0, recorded_proof).unwrap_err();
        assert!(err.to_string().contains("no challenge issued"), "{err}");
    }

    #[test]
    fn handshake_kind_must_match_serving_mode() {
        // KeySeed while sharding is enabled → rejected
        let (mut etp, ctp) = inproc_pair();
        let ring = KeyRing::new(3, 2, 64, 0);
        let gate = ShardGate::new(ring, 1);
        let res = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            etp.send(&Msg::KeySeed { seed: 9 }).unwrap();
            cloud.join().unwrap()
        });
        let err = res.expect_err("KeySeed must be rejected under sharding");
        assert!(err.to_string().contains("expected ShardHello"), "{err}");

        // KeyShard while sharding is NOT enabled → rejected
        let (mut etp, ctp) = inproc_pair();
        let codec = RunCodec::host(1, 2, 64, 1);
        let res = std::thread::scope(|sc| {
            let codec = &codec;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Shared(codec), &mut tp, 0)
            });
            etp.send(&Msg::KeyShard { client_id: 0, epoch: 0, proof: 1 }).unwrap();
            cloud.join().unwrap()
        });
        let err = res.expect_err("KeyShard must be rejected without sharding");
        assert!(err.to_string().contains("not enabled"), "{err}");

        // ShardHello while sharding is NOT enabled → rejected LOUDLY: this
        // is what a sharded edge mis-paired with a shared cloud sends first,
        // and it must surface as an error, never a silent two-sided hang
        let (mut etp, ctp) = inproc_pair();
        let codec = RunCodec::host(1, 2, 64, 1);
        let res = std::thread::scope(|sc| {
            let codec = &codec;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Shared(codec), &mut tp, 0)
            });
            etp.send(&Msg::ShardHello).unwrap();
            cloud.join().unwrap()
        });
        let err = res.expect_err("ShardHello must be rejected without sharding");
        assert!(err.to_string().contains("not enabled"), "{err}");

        // duplicate ShardHello → rejected
        let (mut etp, ctp) = inproc_pair();
        let gate = ShardGate::new(ring, 1);
        let res = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            etp.send(&Msg::ShardHello).unwrap();
            etp.send(&Msg::ShardHello).unwrap();
            let _challenge = etp.recv().unwrap();
            cloud.join().unwrap()
        });
        let err = res.expect_err("duplicate ShardHello must be rejected");
        assert!(err.to_string().contains("duplicate ShardHello"), "{err}");

        // Features before the KeyShard handshake → rejected
        let (mut etp, ctp) = inproc_pair();
        let gate = ShardGate::new(ring, 1);
        let res = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(CloudCodec::Sharded(gate), &mut tp, 0)
            });
            etp.send(&Msg::Features { step: 0, tensor: Tensor::zeros(&[2, 64]) })
                .unwrap();
            cloud.join().unwrap()
        });
        let err = res.expect_err("Features before handshake must be rejected");
        assert!(err.to_string().contains("before the KeyShard"), "{err}");
    }

    #[test]
    fn reactor_single_client_matches_thread_per_client_contract() {
        let (mut etp, cloud_conn) = inproc_reactor_pair();
        let cloud_codec = RunCodec::host(7, 2, 128, 1);
        let edge_codec = RunCodec::host(7, 2, 128, 1);
        let (cloud, edge) = std::thread::scope(|sc| {
            let cloud_codec = &cloud_codec;
            let cloud = sc.spawn(move || {
                let conns: Vec<Box<dyn ReactorConn>> = vec![Box::new(cloud_conn)];
                serve_clients_reactor(
                    CloudCodec::Shared(cloud_codec),
                    conns,
                    2,
                    ReactorConfig::default(),
                )
            });
            let edge = run_edge(
                EdgeCodec::Shared { codec: &edge_codec, key_seed: 7 },
                &mut etp,
                8,
                3,
                4,
                128,
            )
            .unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.per_client.len(), 1);
        let c = &cloud.per_client[0];
        assert_eq!(c.steps, 8);
        assert_eq!(c.shard, None);
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease: {} -> {}",
            edge.first_loss,
            edge.last_loss
        );
        // both halves of the link agree byte-for-byte, like serve_one
        assert_eq!(c.rx_bytes, edge.tx_bytes);
        assert_eq!(c.tx_bytes, edge.rx_bytes);
        assert_eq!(c.rx_msgs, 8 * 2 + 2);
        assert_eq!(c.tx_msgs, 8 * 2);
    }

    #[test]
    fn reactor_sharded_single_client_with_rotation() {
        let (mut etp, cloud_conn) = inproc_reactor_pair();
        let ring = KeyRing::new(0xAB, 2, 512, 6);
        let gate = ShardGate::new(ring, 1);
        let (cloud, edge) = std::thread::scope(|sc| {
            let gate = &gate;
            let cloud = sc.spawn(move || {
                let conns: Vec<Box<dyn ReactorConn>> = vec![Box::new(cloud_conn)];
                serve_clients_reactor(
                    CloudCodec::Sharded(gate),
                    conns,
                    2,
                    ReactorConfig::default(),
                )
            });
            let edge = run_edge(
                EdgeCodec::Sharded {
                    shard: ring.edge_shard(0),
                    workers: 1,
                    fft: FftBackend::default(),
                },
                &mut etp,
                12,
                3,
                4,
                512,
            )
            .unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        let c = &cloud.per_client[0];
        assert_eq!(c.steps, 12);
        assert_eq!(c.shard, Some(0));
        assert_eq!(c.rx_bytes, edge.tx_bytes);
        assert_eq!(c.tx_bytes, edge.rx_bytes);
        // ShardHello + KeyShard + per-step Features/TrainLabels up,
        // ShardChallenge + Gradients/StepStats down, plus Shutdown
        assert_eq!(c.rx_msgs, 12 * 2 + 3);
        assert_eq!(c.tx_msgs, 12 * 2 + 1);
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease across rotations"
        );
    }

    #[test]
    fn reactor_rejects_bad_geometry_uplink() {
        let (mut etp, cloud_conn) = inproc_reactor_pair();
        let cloud_codec = RunCodec::host(1, 2, 64, 1);
        let err = std::thread::scope(|sc| {
            let cloud_codec = &cloud_codec;
            let cloud = sc.spawn(move || {
                let conns: Vec<Box<dyn ReactorConn>> = vec![Box::new(cloud_conn)];
                serve_clients_reactor(
                    CloudCodec::Shared(cloud_codec),
                    conns,
                    1,
                    ReactorConfig::default(),
                )
            });
            // wrong feature dim (32 != 64) must fail the serve, not panic a
            // shared worker
            etp.send(&Msg::Features { step: 0, tensor: Tensor::zeros(&[2, 32]) }).unwrap();
            cloud.join().unwrap()
        });
        assert!(err.is_err(), "bad geometry must surface as an error");
    }

    #[test]
    fn reactor_isolates_one_broken_client() {
        // One client vanishing mid-protocol must not take the pool down:
        // the healthy edges train to completion, and the failure surfaces
        // only in the aggregate result afterwards (same contract as the
        // thread-per-client pool, where serve_one fails its own thread).
        let (mut e1, c1) = inproc_reactor_pair();
        let (mut e2, c2) = inproc_reactor_pair();
        let (e3, c3) = inproc_reactor_pair();
        let cloud_codec = RunCodec::host(3, 2, 64, 1);
        let edge_codec = RunCodec::host(3, 2, 64, 1);
        let (serve_result, a, b) = std::thread::scope(|sc| {
            let cloud_codec = &cloud_codec;
            let cloud = sc.spawn(move || {
                let conns: Vec<Box<dyn ReactorConn>> =
                    vec![Box::new(c1), Box::new(c2), Box::new(c3)];
                serve_clients_reactor(
                    CloudCodec::Shared(cloud_codec),
                    conns,
                    2,
                    ReactorConfig::default(),
                )
            });
            drop(e3); // client 2 hangs up without ever speaking
            let a = run_edge(
                EdgeCodec::Shared { codec: &edge_codec, key_seed: 3 },
                &mut e1,
                5,
                1,
                4,
                64,
            )
            .unwrap();
            let b = run_edge(
                EdgeCodec::Shared { codec: &edge_codec, key_seed: 3 },
                &mut e2,
                5,
                2,
                4,
                64,
            )
            .unwrap();
            (cloud.join().unwrap(), a, b)
        });
        assert!(a.last_loss < a.first_loss, "edge 0 must finish training");
        assert!(b.last_loss < b.first_loss, "edge 1 must finish training");
        let err = serve_result.expect_err("broken client must surface as an error");
        assert!(err.to_string().contains("client 2"), "{err}");
    }

    #[test]
    fn serve_clients_reports_per_client() {
        let (mut e1, c1) = inproc_pair();
        let (mut e2, c2) = inproc_pair();
        let cloud_codec = RunCodec::host(9, 2, 64, 1);
        let edge_codec = RunCodec::host(9, 2, 64, 1);
        let stats = std::thread::scope(|sc| {
            let cloud =
                sc.spawn(|| serve_clients(CloudCodec::Shared(&cloud_codec), vec![c1, c2]));
            let a = run_edge(
                EdgeCodec::Shared { codec: &edge_codec, key_seed: 9 },
                &mut e1,
                3,
                1,
                4,
                64,
            )
            .unwrap();
            let b = run_edge(
                EdgeCodec::Shared { codec: &edge_codec, key_seed: 9 },
                &mut e2,
                4,
                2,
                4,
                64,
            )
            .unwrap();
            let stats = cloud.join().unwrap().unwrap();
            assert_eq!(stats.total_rx(), a.tx_bytes + b.tx_bytes);
            stats
        });
        assert_eq!(stats.per_client.len(), 2);
        assert_eq!(stats.per_client[0].client, 0);
        assert_eq!(stats.per_client[1].client, 1);
        assert_eq!(stats.total_steps(), 3 + 4);
    }

    #[test]
    fn ops_registry_counts_and_drain_lifecycle() {
        let reg = OpsRegistry::new();
        assert_eq!(reg.drain_state(), DrainState::Serving);
        reg.note_step(0.5);
        reg.note_step(2.0);
        reg.note_client_finished();
        reg.note_client_failed();
        reg.note_reload();
        assert_eq!(reg.steps_total(), 2);
        assert_eq!(reg.clients_finished(), 1);
        assert_eq!(reg.clients_failed(), 1);
        assert_eq!(reg.reloads_total(), 1);
        let h = reg.step_loss_snapshot();
        assert_eq!(h.total, 2);
        assert_eq!(h.min, 0.5);
        // one-way lifecycle: request_drain is idempotent and never
        // regresses a Drained registry back to Draining
        reg.request_drain();
        reg.request_drain();
        assert_eq!(reg.drain_state(), DrainState::Draining);
        reg.mark_drained();
        reg.request_drain();
        assert_eq!(reg.drain_state(), DrainState::Drained);
    }

    #[test]
    fn ops_metrics_render_covers_gate_and_reactor() {
        let ring = KeyRing::new(7, 2, 8, 0);
        let gate = ShardGate::new(ring, 2);
        let codec = CloudCodec::Sharded(&gate);
        let (_edge, conn) = inproc_reactor_pair();
        let reactor =
            Reactor::new(vec![Box::new(conn) as Box<dyn ReactorConn>], ReactorConfig::default());
        let reg = OpsRegistry::new();
        reg.note_step(1.5);
        let body = render_metrics(codec, &reactor, &reg);
        assert!(body.contains("# TYPE c3sl_steps_total counter"), "{body}");
        assert!(body.contains("\nc3sl_steps_total 1\n"), "{body}");
        assert!(body.contains("\nc3sl_clients_open 1\n"), "{body}");
        assert!(body.contains("\nc3sl_drain_state 0\n"), "{body}");
        assert!(body.contains("c3sl_reactor_backend{backend=\""), "{body}");
        assert!(body.contains("c3sl_shard_claimed{shard=\"0\"} 0\n"), "{body}");
        assert!(body.contains("c3sl_shard_last_step{shard=\"1\"} -1\n"), "{body}");
        assert!(body.contains("c3sl_step_loss_bucket{le=\"+Inf\"} 1\n"), "{body}");
        assert!(body.contains("\nc3sl_step_loss_count 1\n"), "{body}");
        let hz = render_healthz(&reactor, &reg);
        assert!(hz.starts_with("status: ok\n"), "{hz}");
        assert!(hz.contains("drain: serving\n"), "{hz}");
        assert!(hz.contains("open_clients: 1\n"), "{hz}");
    }

    #[test]
    fn drain_request_retires_a_live_fleet_cleanly() {
        // Two edges planning far more steps than the drain allows: the
        // registry flips to Draining mid-run, every client retires through
        // the normal path (report filled), and the serve returns Ok even
        // though the edges die on their severed connections.
        let (mut e1, c1) = inproc_reactor_pair();
        let (mut e2, c2) = inproc_reactor_pair();
        let cloud_codec = RunCodec::host(11, 2, 64, 1);
        let edge_codec = RunCodec::host(11, 2, 64, 1);
        let ops = OpsOptions::default();
        let registry = ops.registry.clone();
        let stats = std::thread::scope(|sc| {
            let cloud = sc.spawn(|| {
                serve_clients_reactor_ops(
                    CloudCodec::Shared(&cloud_codec),
                    vec![Box::new(c1) as Box<dyn ReactorConn>, Box::new(c2)],
                    1,
                    ReactorConfig::default(),
                    ops,
                )
            });
            let reg = registry.clone();
            sc.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(30));
                reg.request_drain();
            });
            // the edges either get cut mid-run (Err) or finish early (Ok);
            // both are acceptable endings for a drained fleet
            let ec = &edge_codec;
            let a = sc.spawn(move || {
                run_edge(EdgeCodec::Shared { codec: ec, key_seed: 11 }, &mut e1, 100_000, 1, 4, 64)
            });
            let b = sc.spawn(move || {
                run_edge(EdgeCodec::Shared { codec: ec, key_seed: 11 }, &mut e2, 100_000, 2, 4, 64)
            });
            let _ = a.join().expect("edge thread must not panic");
            let _ = b.join().expect("edge thread must not panic");
            cloud.join().expect("cloud thread must not panic")
        })
        .expect("drained serve returns cleanly");
        assert_eq!(stats.per_client.len(), 2, "every client leaves a report");
        assert_eq!(registry.drain_state(), DrainState::Drained);
        assert_eq!(registry.clients_finished(), 2);
        // the registry counted exactly the steps the reports account for
        assert_eq!(registry.steps_total(), stats.total_steps());
    }
}
