//! Coordinator: the split-learning runtime (the paper's system realized as
//! two actors).
//!
//! ```text
//!            EDGE (f_theta)                      CLOUD (f_psi)
//!   ┌──────────────────────────┐       ┌──────────────────────────────┐
//!   │ loader → edge_fwd → enc ─┼─────▶ │ dec → cloud_step ─┐          │
//!   │ edge_adam ◀─ edge_bwd ◀─ dec ◀───┼── enc(gẑ) ◀───────┘          │
//!   └──────────────────────────┘       └──────────────────────────────┘
//!          uplink: S^g (+labels)          downlink: encoded gradients
//! ```
//!
//! Both directions are compressed (paper §1: "compresses a batch of features
//! and gradients").  Because decode = encodeᵀ (DESIGN.md §1), the distributed
//! gradient path is numerically identical to the paper's single-process
//! Algorithm 1.
//!
//! The two actors speak `transport::Msg` over any `Transport` (in-proc
//! channels, TCP between processes), so byte accounting reflects real
//! serialized traffic.  Keys are derived from a shared seed on both sides —
//! the R×D key matrix itself never crosses the wire.

pub mod cloud;
pub mod driver;
pub mod edge;
pub mod multi;
pub mod resilience;
pub mod run_codec;

pub use cloud::CloudWorker;
pub use driver::{run_experiment, run_multi_edge, MultiEdgeSpec, MultiRunOutput, RunOutput};
pub use edge::EdgeWorker;
pub use multi::{
    ClientReport, CloudCodec, EdgeCodec, EdgeReport, MultiStats, SessionDeadlines, ShardGate,
};
pub use resilience::{run_edge_retry, RetryPolicy};
pub use run_codec::RunCodec;
