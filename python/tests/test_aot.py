# AOT pipeline tests: lowering produces valid HLO text + accurate manifests.
#
# These lower a real (tiny) artifact set into a temp dir and check the
# contract the rust runtime depends on: HLO text parses as an HloModule,
# manifests record the exact arg/output shapes, and the flat-argument
# ordering matches the parameter leaf lists.

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M, split


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    (cfg,) = M.resolve("vggt_b32")
    manifest = aot.emit_model(cfg, out)
    codec = aot.emit_codec(cfg, 4, "pallas", out)
    return cfg, out, manifest, codec


class TestModelEmission:
    def test_all_artifacts_written(self, emitted):
        cfg, out, manifest, _ = emitted
        for name, art in manifest["artifacts"].items():
            path = os.path.join(out, cfg.key, art["file"])
            assert os.path.exists(path), name
            text = open(path).read()
            assert text.lstrip().startswith("HloModule"), f"{name} is not HLO text"
            assert art["hlo_bytes"] == len(text)

    def test_manifest_roundtrips_as_json(self, emitted):
        cfg, out, _, _ = emitted
        with open(os.path.join(out, cfg.key, "manifest.json")) as f:
            j = json.load(f)
        assert j["batch"] == cfg.batch
        assert j["d_tx"] == j["d_cut"]  # no bnpp on this config
        assert len(j["edge_params"]) == j["edge_param_leaves"]
        assert len(j["cloud_params"]) == j["cloud_param_leaves"]

    def test_edge_fwd_signature(self, emitted):
        cfg, out, manifest, _ = emitted
        art = manifest["artifacts"]["edge_fwd"]
        ne = manifest["edge_param_leaves"]
        assert len(art["args"]) == ne + 1
        assert art["args"][-1]["shape"] == [cfg.batch, 3, cfg.image, cfg.image]
        assert art["outputs"][0]["shape"] == [cfg.batch, manifest["d_tx"]]

    def test_cloud_step_signature(self, emitted):
        cfg, out, manifest, _ = emitted
        art = manifest["artifacts"]["cloud_step"]
        nc = manifest["cloud_param_leaves"]
        # args: cloud params + zhat + y;  outputs: loss, nc, grads..., gz
        assert len(art["args"]) == nc + 2
        assert len(art["outputs"]) == 2 + nc + 1
        assert art["outputs"][0]["shape"] == []  # scalar loss
        assert art["outputs"][-1]["shape"] == [cfg.batch, manifest["d_tx"]]

    def test_adam_signature(self, emitted):
        cfg, out, manifest, _ = emitted
        ne = manifest["edge_param_leaves"]
        art = manifest["artifacts"]["edge_adam"]
        assert len(art["args"]) == 4 * ne + 2
        assert len(art["outputs"]) == 3 * ne

    def test_param_specs_match_init_outputs(self, emitted):
        cfg, out, manifest, _ = emitted
        init = manifest["artifacts"]["edge_init"]
        assert [o["shape"] for o in init["outputs"]] == [
            p["shape"] for p in manifest["edge_params"]
        ]


class TestCodecEmission:
    def test_codec_artifacts(self, emitted):
        cfg, out, _, codec = emitted
        assert codec["r"] == 4
        assert codec["g"] * 4 == codec["batch"]
        enc = codec["artifacts"]["c3_encode"]
        assert enc["args"][0]["shape"] == [codec["batch"], codec["d"]]
        assert enc["args"][1]["shape"] == [4, codec["d"]]
        assert enc["outputs"][0]["shape"] == [codec["g"], codec["d"]]
        dec = codec["artifacts"]["c3_decode"]
        assert dec["outputs"][0]["shape"] == [codec["batch"], codec["d"]]

    def test_gen_keys_artifact(self, emitted):
        cfg, out, _, codec = emitted
        gk = codec["artifacts"]["gen_keys"]
        assert gk["args"][0] == {"shape": [2], "dtype": "u32"}
        assert gk["outputs"][0]["shape"] == [4, codec["d"]]

    def test_bad_ratio_rejected(self, emitted):
        cfg, out, _, _ = emitted
        with pytest.raises(ValueError):
            aot.emit_codec(cfg, 5, "pallas", out)  # 32 % 5 != 0


class TestKernelChoice:
    def test_fft_and_pallas_encode_agree(self):
        (cfg,) = M.resolve("vggt_b32")
        _, _, d, _ = cfg.build()
        b, r = cfg.batch, 4
        fp = split.make_c3_encode(b, r, d, "pallas")
        ff = split.make_c3_encode(b, r, d, "fft")
        rng = jax.random.PRNGKey(0)
        z = jax.random.normal(rng, (b, d))
        from compile.kernels import ref
        keys = ref.generate_keys(jax.random.PRNGKey(1), r, d)
        import numpy as np
        np.testing.assert_allclose(fp(z, keys)[0], ff(z, keys)[0],
                                   rtol=5e-4, atol=5e-4)
