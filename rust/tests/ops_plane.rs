//! Ops control plane end to end: live fleets scraped over real TCP while
//! they serve, on BOTH readiness backends.
//!
//! The control plane's whole design claim is that `/metrics`, `/healthz`
//! and `POST /drain` are answered from the reactor's own readiness loop —
//! one more pollable fd, no extra thread — so these tests always scrape
//! *mid-run*, while the serve loop is simultaneously pumping training
//! traffic.  Covered here:
//!
//!   * mid-run `/metrics` scrapes are exact (a synchronous edge steps the
//!     fleet one training step at a time, so every scrape has one correct
//!     answer) and every counter is monotone and consistent with the final
//!     `MultiStats`;
//!   * `/healthz` flips `degraded: true` when a requested epoll backend
//!     cannot be realized and the reactor falls back to the sweep;
//!   * `POST /drain` under real load retires every client through the
//!     normal accounting path — reports filled, shard claims released,
//!     registry and `MultiStats` in exact agreement — with fd hygiene
//!     checked across rounds on Linux;
//!   * a rogue edge's loud failure is visible to scrapers while the rest
//!     of the fleet keeps serving;
//!   * a SIGHUP lands the reload-knob subset mid-run and is counted.
//!
//! Every test serializes on one mutex: the descriptor table and the SIGHUP
//! handler are process-global, and concurrent fleets would make both lie.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use c3sl::coordinator::multi::{
    self, CloudCodec, DrainState, EdgeCodec, OpsOptions, OpsRegistry,
};
use c3sl::coordinator::{
    run_edge_retry, RetryPolicy, RunCodec, SessionDeadlines, ShardGate,
};
use c3sl::hdc::keyring::KeyRing;
use c3sl::hdc::FftBackend;
use c3sl::tensor::{Labels, Tensor};
use c3sl::transport::faulty::{FaultyLink, Impairments};
use c3sl::transport::reactor::{NbTcp, ReactorConfig, ReactorConn};
use c3sl::transport::readiness::ReadinessBackend;
use c3sl::transport::tcp::Tcp;
use c3sl::transport::{inproc_reactor_pair_with, Msg, Transport};
use c3sl::util::error::C3Error;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(target_os = "linux")]
fn fd_count() -> usize {
    std::fs::read_dir("/proc/self/fd")
        .expect("procfs must be mounted on Linux")
        .count()
}

/// One blocking HTTP/1.0 exchange against the ops endpoint: write the
/// request, read to EOF (the plane always closes), return (status, body).
fn ops_http(addr: &SocketAddr, request: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect ops endpoint");
    s.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    s.write_all(request.as_bytes()).expect("write ops request");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read ops response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("no status line in {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn ops_get(addr: &SocketAddr, path: &str) -> (u16, String) {
    ops_http(addr, &format!("GET {path} HTTP/1.0\r\n\r\n"))
}

fn ops_post(addr: &SocketAddr, path: &str) -> (u16, String) {
    ops_http(addr, &format!("POST {path} HTTP/1.0\r\n\r\n"))
}

/// The value of one sample line `name value` (label text included in
/// `name` for labelled series) in a Prometheus text body.
fn metric_value(body: &str, name: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        l.strip_prefix(name)?.strip_prefix(' ')?.parse().ok()
    })
}

// ---------------------------------------------------------------------------
// 1. Mid-run scrapes are exact, monotone, and consistent with MultiStats
// ---------------------------------------------------------------------------

fn midrun_scrape_round(backend: ReadinessBackend) {
    let steps = 5u64;
    let (r, d, batch) = (2usize, 64usize, 4usize);
    let key_seed = 0xC3_5EEDu64;
    let codec = RunCodec::host(key_seed, r, d, 1);
    let listener = Tcp::bind("127.0.0.1:0").expect("bind fleet listener");
    let addr = listener.local_addr().expect("fleet addr").to_string();
    let ops_listener = TcpListener::bind("127.0.0.1:0").expect("bind ops listener");
    let ops_addr = ops_listener.local_addr().expect("ops addr");
    let registry = Arc::new(OpsRegistry::new());

    let stats = std::thread::scope(|sc| {
        let codec = &codec;
        let listener = &listener;
        let reg = registry.clone();
        let cloud = sc.spawn(move || {
            let streams =
                Tcp::accept_streams(listener, 1, Duration::from_secs(30)).expect("accept edge");
            let conns: Vec<Box<dyn ReactorConn>> = streams
                .into_iter()
                .map(|s| {
                    Box::new(NbTcp::from_stream(s).expect("nonblocking edge"))
                        as Box<dyn ReactorConn>
                })
                .collect();
            let cfg = ReactorConfig { backend, ..ReactorConfig::default() };
            let ops = OpsOptions { listener: Some(ops_listener), registry: reg, reload: None };
            multi::serve_clients_reactor_ops(CloudCodec::Shared(codec), conns, 2, cfg, ops)
                .expect("instrumented fleet serves cleanly")
        });

        // a synchronous edge: one training step at a time, a scrape between
        // each — so there is exactly one correct value for every scrape
        let mut tp = Tcp::connect(&addr).expect("edge connect");
        tp.send(&Msg::KeySeed { seed: key_seed }).expect("key seed");
        let mut last_rx = 0.0f64;
        for step in 0..steps {
            tp.send(&Msg::Features { step, tensor: Tensor::zeros(&[batch / r, d]) })
                .expect("features");
            tp.send(&Msg::TrainLabels { step, labels: Labels(vec![0; batch]) })
                .expect("labels");
            match tp.recv().expect("gradient reply") {
                Msg::Gradients { step: g, .. } => assert_eq!(g, step),
                other => panic!("expected Gradients, got {other:?}"),
            }
            match tp.recv().expect("stats reply") {
                Msg::StepStats { step: s, .. } => assert_eq!(s, step),
                other => panic!("expected StepStats, got {other:?}"),
            }
            let (code, body) = ops_get(&ops_addr, "/metrics");
            assert_eq!(code, 200, "mid-run scrape must succeed ({})", backend.name());
            assert_eq!(
                metric_value(&body, "c3sl_steps_total"),
                Some((step + 1) as f64),
                "exact step counter after step {step} on {}: {body}",
                backend.name()
            );
            assert_eq!(metric_value(&body, "c3sl_clients_open"), Some(1.0), "{body}");
            assert_eq!(metric_value(&body, "c3sl_clients_failed_total"), Some(0.0), "{body}");
            assert_eq!(metric_value(&body, "c3sl_drain_state"), Some(0.0), "{body}");
            assert!(body.contains("# TYPE c3sl_steps_total counter"), "{body}");
            assert!(body.contains("# TYPE c3sl_step_loss histogram"), "{body}");
            assert!(
                body.contains(&format!(
                    "c3sl_reactor_backend{{backend=\"{}\"}} 1",
                    backend.name()
                )),
                "{body}"
            );
            let rx = metric_value(&body, "c3sl_rx_bytes_total").expect("rx series");
            assert!(rx > 0.0 && rx >= last_rx, "rx bytes must be monotone: {rx} < {last_rx}");
            last_rx = rx;
        }

        let (hcode, health) = ops_get(&ops_addr, "/healthz");
        assert_eq!(hcode, 200);
        assert!(health.starts_with("status: ok\n"), "healthz: {health}");
        assert!(
            health.contains(&format!("backend: {}\n", backend.name())),
            "healthz: {health}"
        );
        assert!(health.contains("degraded: false\n"), "healthz: {health}");
        assert!(health.contains("drain: serving\n"), "healthz: {health}");
        assert!(health.contains("open_clients: 1\n"), "healthz: {health}");
        // canned errors, also served mid-run from the same loop
        assert_eq!(ops_get(&ops_addr, "/nope").0, 404);
        assert_eq!(ops_get(&ops_addr, "/drain").0, 405, "GET /drain must be refused");

        tp.send(&Msg::Shutdown).expect("shutdown");
        cloud.join().expect("cloud thread")
    });

    assert_eq!(stats.per_client.len(), 1);
    assert_eq!(stats.total_steps(), steps);
    assert_eq!(registry.steps_total(), steps, "registry mirrors the final MultiStats");
    assert_eq!(registry.clients_finished(), 1);
    assert_eq!(registry.clients_failed(), 0);
    assert_eq!(registry.drain_state(), DrainState::Serving);
}

#[test]
fn midrun_metrics_scrape_is_exact_on_both_backends() {
    let _guard = serial();
    for backend in [ReadinessBackend::Sweep, ReadinessBackend::Epoll] {
        if backend.supported() {
            midrun_scrape_round(backend);
        }
    }
}

// ---------------------------------------------------------------------------
// 2. /healthz reports a degraded reactor (requested epoll, realized sweep)
// ---------------------------------------------------------------------------

#[test]
fn healthz_flips_degraded_when_epoll_cannot_realize() {
    let _guard = serial();
    if !ReadinessBackend::Epoll.supported() {
        return; // nothing to degrade from on sweep-only platforms
    }
    let (r, d, batch) = (2usize, 64usize, 4usize);
    let key_seed = 0xDE62_ADEDu64;
    let codec = RunCodec::host(key_seed, r, d, 1);
    // an fd-less in-proc connection: the epoll backend cannot register it,
    // so the reactor must degrade to the sweep and keep serving
    let (mut edge, nb) = inproc_reactor_pair_with(false);
    let ops_listener = TcpListener::bind("127.0.0.1:0").expect("bind ops listener");
    let ops_addr = ops_listener.local_addr().expect("ops addr");
    let registry = Arc::new(OpsRegistry::new());

    std::thread::scope(|sc| {
        let codec = &codec;
        let reg = registry.clone();
        let cloud = sc.spawn(move || {
            let conns: Vec<Box<dyn ReactorConn>> = vec![Box::new(nb)];
            let cfg =
                ReactorConfig { backend: ReadinessBackend::Epoll, ..ReactorConfig::default() };
            let ops = OpsOptions { listener: Some(ops_listener), registry: reg, reload: None };
            multi::serve_clients_reactor_ops(CloudCodec::Shared(codec), conns, 1, cfg, ops)
                .expect("degraded serve still completes")
        });

        // hold the session open with one real training step, then scrape
        edge.send(&Msg::KeySeed { seed: key_seed }).expect("key seed");
        edge.send(&Msg::Features { step: 0, tensor: Tensor::zeros(&[batch / r, d]) })
            .expect("features");
        edge.send(&Msg::TrainLabels { step: 0, labels: Labels(vec![0; batch]) })
            .expect("labels");
        match edge.recv().expect("gradient reply") {
            Msg::Gradients { .. } => {}
            other => panic!("expected Gradients, got {other:?}"),
        }
        match edge.recv().expect("stats reply") {
            Msg::StepStats { .. } => {}
            other => panic!("expected StepStats, got {other:?}"),
        }

        let (code, health) = ops_get(&ops_addr, "/healthz");
        assert_eq!(code, 200);
        assert!(health.starts_with("status: ok\n"), "healthz: {health}");
        assert!(health.contains("backend: sweep\n"), "healthz: {health}");
        assert!(health.contains("requested: epoll\n"), "healthz: {health}");
        assert!(health.contains("degraded: true\n"), "healthz: {health}");
        let (_, body) = ops_get(&ops_addr, "/metrics");
        assert!(body.contains("c3sl_reactor_backend{backend=\"sweep\"} 1"), "{body}");

        edge.send(&Msg::Shutdown).expect("shutdown");
        cloud.join().expect("cloud thread");
    });
    assert_eq!(registry.clients_finished(), 1);
    assert_eq!(registry.steps_total(), 1);
}

// ---------------------------------------------------------------------------
// 3. POST /drain under load: exact accounting, claims released, fd hygiene
// ---------------------------------------------------------------------------

fn drain_round(backend: ReadinessBackend) {
    let n = 2usize;
    let (r, d, batch) = (2usize, 64usize, 4usize);
    let ring = KeyRing::new(0x00D1_2A17, r, d, 0);
    let gate = ShardGate::new(ring, n);
    let listener = Tcp::bind("127.0.0.1:0").expect("bind fleet listener");
    let addr = listener.local_addr().expect("fleet addr").to_string();
    let ops_listener = TcpListener::bind("127.0.0.1:0").expect("bind ops listener");
    let ops_addr = ops_listener.local_addr().expect("ops addr");
    let registry = Arc::new(OpsRegistry::new());

    let (served, edge_results) = std::thread::scope(|sc| {
        let gate = &gate;
        let listener = &listener;
        let addr = &addr;
        let reg = registry.clone();
        let cloud = sc.spawn(move || {
            let streams =
                Tcp::accept_streams(listener, n, Duration::from_secs(30)).expect("accept edges");
            let conns: Vec<Box<dyn ReactorConn>> = streams
                .into_iter()
                .map(|s| {
                    Box::new(NbTcp::from_stream(s).expect("nonblocking edge"))
                        as Box<dyn ReactorConn>
                })
                .collect();
            let cfg = ReactorConfig { backend, ..ReactorConfig::default() };
            let ops = OpsOptions { listener: Some(ops_listener), registry: reg, reload: None };
            multi::serve_clients_reactor_ops(CloudCodec::Sharded(gate), conns, 2, cfg, ops)
        });
        let edges: Vec<_> = (0..n)
            .map(|i| {
                sc.spawn(move || {
                    let mut tp = Tcp::connect(addr).expect("edge connect");
                    multi::run_edge(
                        EdgeCodec::Sharded {
                            shard: ring.edge_shard(i as u64),
                            workers: 1,
                            fft: FftBackend::default(),
                        },
                        &mut tp,
                        1_000_000, // far beyond what will run: drain cuts it
                        0xDA7A + i as u64,
                        batch,
                        d,
                    )
                    .map_err(|e| e.to_string())
                })
            })
            .collect();

        // let the fleet reach steady load — steps flowing, every shard
        // claimed and visible to scrapers — before pulling the lever
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let (code, body) = ops_get(&ops_addr, "/metrics");
            assert_eq!(code, 200, "mid-run scrape must succeed");
            let steps = metric_value(&body, "c3sl_steps_total").expect("steps series");
            let all_claimed = (0..n).all(|id| {
                metric_value(&body, &format!("c3sl_shard_claimed{{shard=\"{id}\"}}"))
                    == Some(1.0)
            });
            if steps >= 4.0 && all_claimed {
                break;
            }
            assert!(Instant::now() < deadline, "fleet never reached load: {body}");
        }
        let (code, body) = ops_post(&ops_addr, "/drain");
        assert_eq!(code, 200, "drain request must be accepted");
        assert_eq!(body, "draining\n");

        let edge_results: Vec<_> =
            edges.into_iter().map(|h| h.join().expect("edge thread")).collect();
        (cloud.join().expect("cloud thread"), edge_results)
    });

    let stats = served.expect("drained serve returns the full accounting");
    assert_eq!(stats.per_client.len(), n, "every drained client leaves a report");
    assert!(stats.total_steps() >= 4, "drain must not erase served steps");
    assert_eq!(registry.drain_state(), DrainState::Drained);
    assert_eq!(registry.clients_finished(), n as u64);
    assert_eq!(registry.clients_failed(), 0);
    assert_eq!(
        registry.steps_total(),
        stats.total_steps(),
        "registry and MultiStats must agree on drained accounting"
    );
    for (i, res) in edge_results.iter().enumerate() {
        assert!(
            res.is_err(),
            "edge {i} had 1M steps planned — drain must cut it, got {res:?}"
        );
    }
    for id in 0..n {
        assert!(
            gate.claimant(id as u64).is_none(),
            "shard {id} still claimed after drain"
        );
    }
}

#[test]
fn drain_under_load_retires_cleanly_on_both_backends() {
    let _guard = serial();
    // a warm-up round settles one-time allocations under the fd baseline
    drain_round(ReadinessBackend::Sweep);
    #[cfg(target_os = "linux")]
    let baseline = fd_count();
    for backend in [ReadinessBackend::Sweep, ReadinessBackend::Epoll] {
        if backend.supported() {
            drain_round(backend);
        }
    }
    #[cfg(target_os = "linux")]
    assert_eq!(
        fd_count(),
        baseline,
        "the ops plane must return every descriptor after drained rounds"
    );
}

// ---------------------------------------------------------------------------
// 4. Chaos scrape: a rogue edge fails loudly while scrapers watch
// ---------------------------------------------------------------------------

#[test]
fn rogue_edge_failure_is_visible_to_scrapers_and_isolated() {
    let _guard = serial();
    let steps = 3u64;
    let (r, d, batch) = (2usize, 64usize, 4usize);
    let key_seed = 0x000B_5E55u64;
    let codec = RunCodec::host(key_seed, r, d, 1);
    let listener = Tcp::bind("127.0.0.1:0").expect("bind fleet listener");
    let addr = listener.local_addr().expect("fleet addr").to_string();
    let ops_listener = TcpListener::bind("127.0.0.1:0").expect("bind ops listener");
    let ops_addr = ops_listener.local_addr().expect("ops addr");
    let registry = Arc::new(OpsRegistry::new());

    let served = std::thread::scope(|sc| {
        let codec = &codec;
        let listener = &listener;
        let addr = &addr;
        let reg = registry.clone();
        let cloud = sc.spawn(move || {
            let streams =
                Tcp::accept_streams(listener, 2, Duration::from_secs(30)).expect("accept edges");
            let conns: Vec<Box<dyn ReactorConn>> = streams
                .into_iter()
                .map(|s| {
                    Box::new(NbTcp::from_stream(s).expect("nonblocking edge"))
                        as Box<dyn ReactorConn>
                })
                .collect();
            let cfg = ReactorConfig {
                backend: ReadinessBackend::platform_default(),
                ..ReactorConfig::default()
            };
            let ops = OpsOptions { listener: Some(ops_listener), registry: reg, reload: None };
            multi::serve_clients_reactor_ops(CloudCodec::Shared(codec), conns, 2, cfg, ops)
        });
        let rogue = sc.spawn(move || {
            let mut tp = Tcp::connect(addr).expect("rogue connect");
            tp.send(&Msg::KeySeed { seed: key_seed }).expect("rogue key seed");
            // protocol violation: labels with no features in flight — the
            // cloud must cut this client, loudly, without touching the rest
            tp.send(&Msg::TrainLabels { step: 0, labels: Labels(vec![0; batch]) })
                .expect("rogue labels");
            while tp.recv().is_ok() {}
        });

        let mut tp = Tcp::connect(addr).expect("healthy connect");
        tp.send(&Msg::KeySeed { seed: key_seed }).expect("key seed");
        // scrape until the cut shows, with the healthy client still open
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let (code, body) = ops_get(&ops_addr, "/metrics");
            assert_eq!(code, 200);
            if metric_value(&body, "c3sl_clients_failed_total") == Some(1.0) {
                assert_eq!(metric_value(&body, "c3sl_clients_open"), Some(1.0), "{body}");
                assert_eq!(
                    metric_value(&body, "c3sl_clients_finished_total"),
                    Some(0.0),
                    "{body}"
                );
                break;
            }
            assert!(Instant::now() < deadline, "rogue cut never surfaced: {body}");
        }

        // the survivor keeps training, and only its steps are counted
        for step in 0..steps {
            tp.send(&Msg::Features { step, tensor: Tensor::zeros(&[batch / r, d]) })
                .expect("features");
            tp.send(&Msg::TrainLabels { step, labels: Labels(vec![0; batch]) })
                .expect("labels");
            match tp.recv().expect("gradient reply") {
                Msg::Gradients { step: g, .. } => assert_eq!(g, step),
                other => panic!("expected Gradients, got {other:?}"),
            }
            match tp.recv().expect("stats reply") {
                Msg::StepStats { step: s, .. } => assert_eq!(s, step),
                other => panic!("expected StepStats, got {other:?}"),
            }
        }
        let (_, body) = ops_get(&ops_addr, "/metrics");
        assert_eq!(metric_value(&body, "c3sl_steps_total"), Some(steps as f64), "{body}");

        tp.send(&Msg::Shutdown).expect("shutdown");
        rogue.join().expect("rogue thread");
        cloud.join().expect("cloud thread")
    });

    let err = match served {
        Ok(stats) => panic!("rogue fleet must surface the failure, got {stats:?}"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("1 client(s) failed"), "aggregate error: {err}");
    assert!(err.contains("labels before features"), "aggregate error: {err}");
    assert_eq!(registry.clients_failed(), 1);
    assert_eq!(registry.clients_finished(), 1);
    assert_eq!(registry.steps_total(), steps);
}

// ---------------------------------------------------------------------------
// 5. Recovery counters: a live mid-run scrape sees the reconnect, the
//    resume, and the backoff sleep of an in-process recovery
// ---------------------------------------------------------------------------

#[test]
fn recovery_counters_surface_on_live_metrics_scrapes() {
    let _guard = serial();
    let n = 2usize;
    let (r, d, batch, steps) = (2usize, 64usize, 4usize, 4u64);
    let ring = KeyRing::new(0x0C3_4EC0, r, d, 0);
    let gate = ShardGate::new(ring, n);
    let listener = Tcp::bind("127.0.0.1:0").expect("bind fleet listener");
    let addr = listener.local_addr().expect("fleet addr").to_string();
    let ops_listener = TcpListener::bind("127.0.0.1:0").expect("bind ops listener");
    let ops_addr = ops_listener.local_addr().expect("ops addr");
    let registry = Arc::new(OpsRegistry::new());
    let deadlines = SessionDeadlines {
        handshake: Some(Duration::from_secs(30)),
        idle: Some(Duration::from_secs(30)),
    };

    let served = std::thread::scope(|sc| {
        let gate = &gate;
        let addr = &addr;
        let reg = registry.clone();
        let cloud = sc.spawn(move || {
            let cfg = ReactorConfig {
                backend: ReadinessBackend::platform_default(),
                ..ReactorConfig::default()
            };
            let ops = OpsOptions { listener: Some(ops_listener), registry: reg, reload: None };
            multi::serve_clients_reactor_accept(
                CloudCodec::Sharded(gate),
                listener,
                n,
                2,
                cfg,
                ops,
                deadlines,
            )
        });

        // the recovering edge: its first connection is severed at frame 4
        // (step 1's Features) after exactly one acknowledged step — the
        // retry runner backs off, reconnects, resumes, and every event
        // lands in the same registry the ops loop scrapes from
        let retry_registry = registry.clone();
        let recovering = sc.spawn(move || {
            let policy = RetryPolicy {
                max_attempts: 4,
                base_backoff_ms: 40,
                max_backoff_ms: 200,
                jitter_frac: 0.2,
                connect_timeout_ms: 5_000,
                read_timeout_ms: 5_000,
                write_timeout_ms: 5_000,
                seed: 0xB0FF,
            };
            run_edge_retry(
                ring.edge_shard(0),
                1,
                FftBackend::default(),
                |attempt| {
                    let tp = Tcp::connect(addr)
                        .map_err(|e| C3Error::msg(format!("connect {addr}: {e}")))?;
                    if attempt == 0 {
                        let imp =
                            Impairments { disconnect_at: Some(4), ..Impairments::off() };
                        Ok(Box::new(FaultyLink::new(tp, 0xFA_17, imp, Impairments::off()))
                            as Box<dyn Transport>)
                    } else {
                        Ok(Box::new(tp) as Box<dyn Transport>)
                    }
                },
                steps,
                0xDA7A,
                batch,
                d,
                &policy,
                Some(&*retry_registry),
            )
        });

        // a second edge claims its shard and then just sits there, holding
        // the serve (and with it the ops loop) open for the live scrape
        let mut tp = Tcp::connect(addr).expect("holder connect");
        tp.send(&Msg::ShardHello).expect("hello");
        let nonce = match tp.recv().expect("challenge") {
            Msg::ShardChallenge { nonce } => nonce,
            other => panic!("expected ShardChallenge, got {other:?}"),
        };
        let shard = ring.edge_shard(1);
        let epoch = shard.epoch_of_step(0);
        tp.send(&Msg::KeyShard { client_id: 1, epoch, proof: shard.proof(epoch, nonce) })
            .expect("claim");

        let report = recovering
            .join()
            .expect("recovering edge thread")
            .expect("recovery must complete every step");
        assert_eq!(report.steps, steps, "no step lost to the disconnect");

        // recovery fully accounted, fleet still serving: scrape it live
        let (code, body) = ops_get(&ops_addr, "/metrics");
        assert_eq!(code, 200, "live scrape must succeed");
        assert_eq!(metric_value(&body, "c3sl_reconnects_total"), Some(1.0), "{body}");
        assert_eq!(metric_value(&body, "c3sl_resumes_total"), Some(1.0), "{body}");
        assert_eq!(metric_value(&body, "c3sl_clients_reaped_total"), Some(0.0), "{body}");
        assert!(body.contains("# TYPE c3sl_retry_backoff_ms histogram"), "{body}");
        assert_eq!(
            metric_value(&body, "c3sl_retry_backoff_ms_count"),
            Some(1.0),
            "exactly one backoff sleep: {body}"
        );

        // retire the holder cleanly so the serve completes its accounting
        tp.send(&Msg::Features { step: 0, tensor: Tensor::zeros(&[batch / r, d]) })
            .expect("features");
        tp.send(&Msg::TrainLabels { step: 0, labels: Labels(vec![0; batch]) })
            .expect("labels");
        match tp.recv().expect("gradient reply") {
            Msg::Gradients { .. } => {}
            other => panic!("expected Gradients, got {other:?}"),
        }
        match tp.recv().expect("stats reply") {
            Msg::StepStats { .. } => {}
            other => panic!("expected StepStats, got {other:?}"),
        }
        tp.send(&Msg::Shutdown).expect("shutdown");
        cloud.join().expect("cloud thread")
    });

    let stats = served.expect("accept serve returns the clean accounting");
    assert_eq!(stats.per_client.len(), n, "two clean retirements, casualty excluded");
    assert_eq!(registry.reconnects_total(), 1);
    assert_eq!(registry.resumes_total(), 1);
    assert_eq!(registry.clients_reaped_total(), 0);
    for id in 0..n as u64 {
        assert!(gate.claimant(id).is_none(), "shard {id} still claimed after the run");
    }
}

// ---------------------------------------------------------------------------
// 6. SIGHUP reload: the knob subset lands mid-run and is counted
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
#[test]
fn sighup_reload_applies_knobs_midrun() {
    use c3sl::coordinator::multi::OpsReload;
    use c3sl::transport::readiness::raise_hangup;

    let _guard = serial();
    let steps = 3u64;
    let (r, d, batch) = (2usize, 64usize, 4usize);
    let key_seed = 0x51_647Fu64;
    let codec = RunCodec::host(key_seed, r, d, 1);
    let listener = Tcp::bind("127.0.0.1:0").expect("bind fleet listener");
    let addr = listener.local_addr().expect("fleet addr").to_string();
    let ops_listener = TcpListener::bind("127.0.0.1:0").expect("bind ops listener");
    let ops_addr = ops_listener.local_addr().expect("ops addr");
    let registry = Arc::new(OpsRegistry::new());

    std::thread::scope(|sc| {
        let codec = &codec;
        let listener = &listener;
        let reg = registry.clone();
        let cloud = sc.spawn(move || {
            let streams =
                Tcp::accept_streams(listener, 1, Duration::from_secs(30)).expect("accept edge");
            let conns: Vec<Box<dyn ReactorConn>> = streams
                .into_iter()
                .map(|s| {
                    Box::new(NbTcp::from_stream(s).expect("nonblocking edge"))
                        as Box<dyn ReactorConn>
                })
                .collect();
            let cfg =
                ReactorConfig { backend: ReadinessBackend::Sweep, ..ReactorConfig::default() };
            let ops = OpsOptions {
                listener: Some(ops_listener),
                registry: reg,
                reload: Some(Box::new(|| OpsReload {
                    max_outbox_frames: Some(32),
                    poll_sleep_us: Some(250),
                })),
            };
            multi::serve_clients_reactor_ops(CloudCodec::Shared(codec), conns, 1, cfg, ops)
                .expect("reloaded fleet serves cleanly")
        });

        let mut tp = Tcp::connect(&addr).expect("edge connect");
        tp.send(&Msg::KeySeed { seed: key_seed }).expect("key seed");
        let mut step_once = |step: u64| {
            tp.send(&Msg::Features { step, tensor: Tensor::zeros(&[batch / r, d]) })
                .expect("features");
            tp.send(&Msg::TrainLabels { step, labels: Labels(vec![0; batch]) })
                .expect("labels");
            match tp.recv().expect("gradient reply") {
                Msg::Gradients { step: g, .. } => assert_eq!(g, step),
                other => panic!("expected Gradients, got {other:?}"),
            }
            match tp.recv().expect("stats reply") {
                Msg::StepStats { step: s, .. } => assert_eq!(s, step),
                other => panic!("expected StepStats, got {other:?}"),
            }
        };

        // one full step proves the serve loop — and with it the SIGHUP
        // handler install — is live before the signal is raised
        step_once(0);
        raise_hangup();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let (_, body) = ops_get(&ops_addr, "/metrics");
            if metric_value(&body, "c3sl_reloads_total").expect("reload series") >= 1.0 {
                break;
            }
            assert!(Instant::now() < deadline, "SIGHUP reload never applied: {body}");
        }
        for step in 1..steps {
            step_once(step);
        }
        tp.send(&Msg::Shutdown).expect("shutdown");
        cloud.join().expect("cloud thread");
    });

    assert_eq!(registry.reloads_total(), 1, "exactly one reload for one SIGHUP");
    assert_eq!(registry.steps_total(), steps, "training is undisturbed by the reload");
}
