//! Manifest parsing: the JSON descriptions aot.py writes next to each
//! artifact set (argument/result shapes, parameter leaf counts, geometry).
use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{C3Error, Context, Result};

use crate::util::json::{self, Json};

/// Shape + dtype of one argument or result tensor, as declared by aot.py.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Element type name as written in the manifest (e.g. `"f32"`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count (product of the dims).
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| C3Error::msg("spec missing shape"))?
            .iter()
            .map(|v| {
                // strict as_usize: negative / NaN / fractional dims are load
                // errors here, not silently saturated small numbers
                v.as_usize().ok_or_else(|| C3Error::msg("bad dim (not a non-negative integer)"))
            })
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| C3Error::msg("spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One lowered HLO artifact: its file plus declared argument/output shapes.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// HLO text filename, relative to the manifest's directory.
    pub file: String,
    /// Argument tensors, in call order.
    pub args: Vec<TensorSpec>,
    /// Output tensors, in result-tuple order.
    pub outputs: Vec<TensorSpec>,
}

fn parse_artifacts(j: &Json) -> Result<BTreeMap<String, ArtifactSpec>> {
    let obj = j
        .get("artifacts")
        .and_then(|a| a.as_obj())
        .ok_or_else(|| C3Error::msg("manifest missing artifacts"))?;
    let mut out = BTreeMap::new();
    for (name, spec) in obj {
        let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
            spec.get(key)
                .and_then(|a| a.as_arr())
                .ok_or_else(|| C3Error::msg(format!("artifact {name} missing {key}")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        out.insert(
            name.clone(),
            ArtifactSpec {
                file: spec
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| C3Error::msg(format!("artifact {name} missing file")))?
                    .to_string(),
                args: parse_list("args")?,
                outputs: parse_list("outputs")?,
            },
        );
    }
    Ok(out)
}

/// Manifest of a model artifact set (edge/cloud nets + steps + adam).
#[derive(Clone, Debug)]
pub struct ModelManifest {
    /// Artifact-set key (directory naming convention, e.g. `vggt_b32`).
    pub key: String,
    /// Architecture name (`vgg16`, `resnet50`, or a slim variant).
    pub arch: String,
    /// Input image resolution (square).
    pub image: usize,
    /// Number of output classes.
    pub classes: usize,
    /// Batch size the artifacts were lowered for.
    pub batch: usize,
    /// Flattened dimension of the transmitted cut tensor (after any
    /// BottleNet++ reduction).
    pub d_tx: usize,
    /// Flattened dimension of the raw cut-layer tensor.
    pub d_cut: usize,
    /// BottleNet++ compression ratio baked into the model, if any.
    pub bnpp_ratio: Option<usize>,
    /// Edge-side parameter leaves, in argument order.
    pub edge_params: Vec<TensorSpec>,
    /// Cloud-side parameter leaves, in argument order.
    pub cloud_params: Vec<TensorSpec>,
    /// Every lowered artifact in the set, keyed by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ModelManifest {
    /// Parse `dir/manifest.json`; errors name the missing field.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = json::parse(&text).context("parsing model manifest")?;
        let field = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| C3Error::msg(format!("manifest missing or non-integer {k}")))
        };
        let spec_list = |k: &str| -> Result<Vec<TensorSpec>> {
            j.get(k)
                .and_then(|a| a.as_arr())
                .ok_or_else(|| C3Error::msg(format!("manifest missing {k}")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ModelManifest {
            key: j.get("key").and_then(|v| v.as_str()).unwrap_or("?").into(),
            arch: j.get("arch").and_then(|v| v.as_str()).unwrap_or("?").into(),
            image: field("image")?,
            classes: field("classes")?,
            batch: field("batch")?,
            d_tx: field("d_tx")?,
            d_cut: field("d_cut")?,
            bnpp_ratio: j.get("bnpp_ratio").and_then(|v| v.as_usize()),
            edge_params: spec_list("edge_params")?,
            cloud_params: spec_list("cloud_params")?,
            artifacts: parse_artifacts(&j)?,
        })
    }

    /// Look up an artifact by name; errors with the model key on a miss.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| C3Error::msg(format!("model {} has no artifact {name}", self.key)))
    }

    /// Total edge-side parameter count, summed over leaves.
    pub fn edge_param_count(&self) -> usize {
        self.edge_params.iter().map(|s| s.elems()).sum()
    }

    /// Total cloud-side parameter count, summed over leaves.
    pub fn cloud_param_count(&self) -> usize {
        self.cloud_params.iter().map(|s| s.elems()).sum()
    }
}

/// Manifest of a C3 codec artifact set.
#[derive(Clone, Debug)]
pub struct CodecManifest {
    /// Compression ratio R (batch images folded per carrier).
    pub r: usize,
    /// Carrier groups per batch (G = B/R).
    pub g: usize,
    /// Carrier dimensionality D (flattened cut-tensor length).
    pub d: usize,
    /// Batch size the codec artifacts were lowered for.
    pub batch: usize,
    /// Kernel family the encoder/decoder were lowered with.
    pub kernel: String,
    /// Every lowered codec artifact, keyed by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl CodecManifest {
    /// Parse `dir/manifest.json`; errors name the missing field.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = json::parse(&text).context("parsing codec manifest")?;
        let field = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| C3Error::msg(format!("codec manifest missing or non-integer {k}")))
        };
        Ok(CodecManifest {
            r: field("r")?,
            g: field("g")?,
            d: field("d")?,
            batch: field("batch")?,
            kernel: j.get("kernel").and_then(|v| v.as_str()).unwrap_or("?").into(),
            artifacts: parse_artifacts(&j)?,
        })
    }

    /// Look up a codec artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| C3Error::msg(format!("codec has no artifact {name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "key": "vggt_b32", "arch": "vgg_tiny", "width": 1.0,
      "image": 16, "classes": 10, "batch": 32,
      "d_tx": 1024, "d_cut": 1024, "bnpp_ratio": null,
      "edge_param_leaves": 2, "cloud_param_leaves": 1,
      "edge_params": [
        {"shape": [32, 3, 3, 3], "dtype": "f32"},
        {"shape": [32], "dtype": "f32"}],
      "cloud_params": [{"shape": [128, 10], "dtype": "f32"}],
      "artifacts": {
        "edge_fwd": {
          "file": "edge_fwd.hlo.txt",
          "args": [{"shape": [32, 3, 3, 3], "dtype": "f32"},
                   {"shape": [32], "dtype": "f32"},
                   {"shape": [32, 3, 16, 16], "dtype": "f32"}],
          "outputs": [{"shape": [32, 1024], "dtype": "f32"}],
          "hlo_bytes": 100, "lower_seconds": 0.1
        }
      }
    }"#;

    #[test]
    fn parses_model_manifest() {
        let dir = std::env::temp_dir().join("c3sl_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = ModelManifest::load(&dir).unwrap();
        assert_eq!(m.batch, 32);
        assert_eq!(m.d_tx, 1024);
        assert_eq!(m.bnpp_ratio, None);
        assert_eq!(m.edge_params.len(), 2);
        assert_eq!(m.edge_param_count(), 32 * 27 + 32);
        let a = m.artifact("edge_fwd").unwrap();
        assert_eq!(a.args.len(), 3);
        assert_eq!(a.outputs[0].shape, vec![32, 1024]);
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
