//! TCP transport: length-prefixed frames over a socket, so the edge and the
//! cloud can run as separate OS processes (or separate machines).
//!
//! Frame on the socket: [len u32 LE][frame bytes] where the inner frame is
//! wire::encode's output.  The length prefix is peer-controlled input and is
//! validated with [`super::check_frame_len`] before any allocation: zero
//! (no valid message encodes to zero bytes) and anything above
//! `wire::MAX_FRAME_BYTES` are rejected as protocol violations.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::{check_frame_len, LinkStats, Msg, Transport, TransportError};
use crate::transport::wire;

/// Blocking TCP endpoint speaking length-prefixed wire frames.
pub struct Tcp {
    stream: TcpStream,
    stats: Arc<LinkStats>,
}

impl Tcp {
    /// Wrap an already-connected stream (enables multi-client accept loops).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<Self> {
        stream.set_nodelay(true)?;
        Ok(Tcp { stream, stats: Arc::new(LinkStats::default()) })
    }

    /// Bind without accepting — the multi-client cloud holds the listener
    /// and calls [`Tcp::accept`] once per edge.
    pub fn bind(addr: &str) -> std::io::Result<TcpListener> {
        TcpListener::bind(addr)
    }

    /// Accept the next edge on an existing listener.
    pub fn accept(listener: &TcpListener) -> std::io::Result<Self> {
        let (stream, _peer) = listener.accept()?;
        Tcp::from_stream(stream)
    }

    /// Accept exactly `n` raw streams, polling against a deadline so a client
    /// that never connects cannot hang the cloud's accept loop forever.
    /// Leaves the listener in nonblocking mode; the returned streams are
    /// normalized to blocking — the caller picks the serving style (wrap in
    /// blocking [`Tcp`] via [`Tcp::accept_n`], or hand them to the reactor as
    /// [`super::reactor::NbTcp`] connections).
    pub fn accept_streams(
        listener: &TcpListener,
        n: usize,
        timeout: std::time::Duration,
    ) -> std::io::Result<Vec<TcpStream>> {
        listener.set_nonblocking(true)?;
        let deadline = std::time::Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    // accepted sockets must not inherit nonblocking mode
                    stream.set_nonblocking(false)?;
                    out.push(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if std::time::Instant::now() >= deadline {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            format!("accepted {} of {n} edges before timeout", out.len()),
                        ));
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        Ok(out)
    }

    /// Accept exactly `n` edges as blocking [`Tcp`] endpoints (the
    /// thread-per-client cloud); see [`Tcp::accept_streams`].
    pub fn accept_n(
        listener: &TcpListener,
        n: usize,
        timeout: std::time::Duration,
    ) -> std::io::Result<Vec<Self>> {
        Tcp::accept_streams(listener, n, timeout)?
            .into_iter()
            .map(Tcp::from_stream)
            .collect()
    }

    /// Listen on `addr` and accept one peer (single-edge cloud).
    pub fn listen(addr: &str) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Tcp::accept(&listener)
    }

    /// Transmit one already-encoded frame, optionally trickling the body in
    /// `chunk`-byte writes separated by `gap` (the fault injector's
    /// slow-loris pacing; `chunk == 0` writes in one piece).  Byte
    /// accounting matches [`Transport::send`]: 4-byte prefix + frame.
    pub(crate) fn write_frame_paced(
        &mut self,
        frame: &[u8],
        chunk: usize,
        gap: std::time::Duration,
    ) -> Result<(), TransportError> {
        let len = frame.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        if chunk == 0 || gap.is_zero() {
            self.stream.write_all(frame)?;
        } else {
            let mut first = true;
            for piece in frame.chunks(chunk) {
                if !first {
                    std::thread::sleep(gap);
                }
                first = false;
                self.stream.write_all(piece)?;
                self.stream.flush()?;
            }
        }
        self.stats
            .tx_bytes
            .fetch_add(4 + frame.len() as u64, Ordering::Relaxed);
        self.stats.tx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Receive one raw frame without decoding it (the fault injector mutates
    /// frames between wire and decoder).  Length gate and byte accounting
    /// match [`Transport::recv`].
    pub(crate) fn read_frame_raw(&mut self) -> Result<Vec<u8>, TransportError> {
        let mut lenb = [0u8; 4];
        self.stream.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        check_frame_len(len)?;
        let mut frame = vec![0u8; len];
        self.stream.read_exact(&mut frame)?;
        self.stats
            .rx_bytes
            .fetch_add(4 + len as u64, Ordering::Relaxed);
        self.stats.rx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(frame)
    }

    /// Announce a `total`-byte frame but ship only `part` of it (paced),
    /// then sever the socket: the peer is left holding EOF inside a frame
    /// body.  Write errors are ignored (the link is dying by design) and
    /// nothing is charged to stats — the frame never completed.
    pub(crate) fn write_partial_then_sever(
        &mut self,
        part: &[u8],
        total: usize,
        chunk: usize,
        gap: std::time::Duration,
    ) {
        let _ = self.stream.write_all(&(total as u32).to_le_bytes());
        let pieces: Vec<&[u8]> =
            if chunk == 0 { vec![part] } else { part.chunks(chunk).collect() };
        let mut first = true;
        for piece in pieces {
            if !first && !gap.is_zero() {
                std::thread::sleep(gap);
            }
            first = false;
            if self.stream.write_all(piece).is_err() {
                break;
            }
            let _ = self.stream.flush();
        }
        self.sever_stream();
    }

    /// Hard-close both directions of the socket (mid-stream disconnect).
    /// Errors are ignored: severing an already-dead socket is a no-op.
    pub(crate) fn sever_stream(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }

    /// One connection attempt bounded by `timeout`.  Unlike [`Tcp::connect`]
    /// this never retries — the caller (the edge's `RetryPolicy` runner)
    /// owns the retry/backoff schedule and only needs each individual
    /// attempt to give up in bounded time.
    pub fn connect_within(addr: &str, timeout: std::time::Duration) -> std::io::Result<Self> {
        use std::net::ToSocketAddrs;
        let mut last_err = None;
        for sa in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(Tcp { stream, stats: Arc::new(LinkStats::default()) });
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "address resolved to nothing")
        }))
    }

    /// Connect to a listening peer (edge side), retrying briefly while the
    /// server comes up.
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let mut last_err = None;
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(Tcp { stream, stats: Arc::new(LinkStats::default()) });
                }
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
        Err(last_err.unwrap())
    }
}

impl Transport for Tcp {
    fn send(&mut self, msg: &Msg) -> Result<(), TransportError> {
        let frame = wire::encode(msg);
        let len = frame.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(&frame)?;
        self.stats
            .tx_bytes
            .fetch_add(4 + frame.len() as u64, Ordering::Relaxed);
        self.stats.tx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn recv(&mut self) -> Result<Msg, TransportError> {
        let mut lenb = [0u8; 4];
        self.stream.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        // Validate the peer-controlled length BEFORE allocating: a corrupt
        // or malicious prefix must not force a ~4 GiB allocation, and a
        // zero-length prefix is rejected here as a protocol violation
        // instead of passing an empty frame through to the decoder.
        check_frame_len(len)?;
        let mut frame = vec![0u8; len];
        self.stream.read_exact(&mut frame)?;
        self.stats
            .rx_bytes
            .fetch_add(4 + len as u64, Ordering::Relaxed);
        self.stats.rx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(wire::decode(&frame)?)
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }

    fn set_deadline(
        &mut self,
        read: Option<std::time::Duration>,
        write: Option<std::time::Duration>,
    ) -> bool {
        // Real socket deadlines: a breached one surfaces from recv/send as
        // TransportError::TimedOut via the io-error mapping.
        self.stream.set_read_timeout(read).is_ok() && self.stream.set_write_timeout(write).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn tcp_roundtrip_between_threads() {
        let addr = "127.0.0.1:39381";
        let server = std::thread::spawn(move || {
            let mut t = Tcp::listen(addr).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
            t.recv().unwrap() // shutdown
        });
        let mut c = Tcp::connect(addr).unwrap();
        let m = Msg::Features {
            step: 9,
            tensor: Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]),
        };
        c.send(&m).unwrap();
        assert_eq!(c.recv().unwrap(), m);
        c.send(&Msg::Shutdown).unwrap();
        assert_eq!(server.join().unwrap(), Msg::Shutdown);
        assert!(c.stats().tx() > 0 && c.stats().rx() > 0);
    }

    #[test]
    fn oversized_frame_rejected_before_allocation() {
        let addr = "127.0.0.1:39382";
        let listener = TcpListener::bind(addr).unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // malicious length prefix: ~4 GiB
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
            // hold the socket open until the client has judged the frame
            let mut buf = [0u8; 1];
            let _ = s.read(&mut buf);
        });
        let mut c = Tcp::connect(addr).unwrap();
        match c.recv() {
            Err(TransportError::FrameTooLarge(n)) => assert_eq!(n, u32::MAX as usize),
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn zero_length_prefix_rejected() {
        // Contract: a zero-length frame is a protocol violation (every Msg
        // carries at least its tag byte) — recv must fail with EmptyFrame,
        // not hand an empty frame to the decoder.
        let addr = "127.0.0.1:39386";
        let listener = TcpListener::bind(addr).unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&0u32.to_le_bytes()).unwrap();
            let mut buf = [0u8; 1];
            let _ = s.read(&mut buf);
        });
        let mut c = Tcp::connect(addr).unwrap();
        match c.recv() {
            Err(TransportError::EmptyFrame) => {}
            other => panic!("expected EmptyFrame, got {other:?}"),
        }
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn one_byte_frame_roundtrips() {
        // The smallest legitimate frame (Shutdown, 1 byte) must pass the
        // length gate and round-trip.
        assert_eq!(wire::encode(&Msg::Shutdown).len(), 1);
        let addr = "127.0.0.1:39387";
        let server = std::thread::spawn(move || {
            let mut t = Tcp::listen(addr).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap();
        });
        let mut c = Tcp::connect(addr).unwrap();
        c.send(&Msg::Shutdown).unwrap();
        assert_eq!(c.recv().unwrap(), Msg::Shutdown);
        server.join().unwrap();
    }

    #[test]
    fn frame_cap_admits_legitimate_tensors() {
        // MAX_FRAME_BYTES must sit above the largest frame wire can decode:
        // an EvalFeatures message holds a MAX_ELEMS tensor AND MAX_ELEMS
        // labels, 4 bytes each.
        assert!(wire::MAX_FRAME_BYTES as u64 >= 8 * wire::MAX_ELEMS);
        // and a real multi-MB tensor survives the capped path
        let addr = "127.0.0.1:39383";
        let server = std::thread::spawn(move || {
            let mut t = Tcp::listen(addr).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap();
        });
        let mut c = Tcp::connect(addr).unwrap();
        let m = Msg::Features {
            step: 0,
            tensor: Tensor::zeros(&[64, 4096]),
        };
        c.send(&m).unwrap();
        assert_eq!(c.recv().unwrap(), m);
        server.join().unwrap();
    }

    #[test]
    fn accept_n_times_out_instead_of_hanging() {
        let addr = "127.0.0.1:39385";
        let listener = Tcp::bind(addr).unwrap();
        let client = std::thread::spawn(move || Tcp::connect(addr).unwrap());
        // only 1 of 2 expected edges ever connects → bounded TimedOut error
        let err = Tcp::accept_n(&listener, 2, std::time::Duration::from_millis(300))
            .err()
            .expect("must not hang waiting for the missing client");
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(err.to_string().contains("1 of 2"), "{err}");
        client.join().unwrap();
    }

    #[test]
    fn read_deadline_surfaces_timed_out() {
        let addr = "127.0.0.1:39388";
        let listener = Tcp::bind(addr).unwrap();
        let server = std::thread::spawn(move || {
            // accept, then go silent: never send a byte
            let t = Tcp::accept(&listener).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(400));
            drop(t);
        });
        let mut c = Tcp::connect(addr).unwrap();
        assert!(c.set_deadline(Some(std::time::Duration::from_millis(50)), None));
        match c.recv() {
            Err(TransportError::TimedOut) => {}
            other => panic!("expected TimedOut, got {other:?}"),
        }
        // the link itself is still alive after the stall: clearing the
        // deadline and sending still works
        assert!(c.set_deadline(None, None));
        c.send(&Msg::Shutdown).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn connect_within_bounds_a_dead_address() {
        // RFC 5737 TEST-NET-1: unroutable, so the SYN goes unanswered and
        // only the caller's timeout ends the attempt.
        let t0 = std::time::Instant::now();
        let res = Tcp::connect_within("192.0.2.1:9", std::time::Duration::from_millis(100));
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "attempt must give up near the 100ms deadline, took {:?} ({:?})",
            t0.elapsed(),
            res.err()
        );
    }

    #[test]
    fn bind_accept_serves_multiple_clients() {
        let addr = "127.0.0.1:39384";
        let listener = Tcp::bind(addr).unwrap();
        let server = std::thread::spawn(move || {
            let mut seen = Vec::new();
            for _ in 0..2 {
                let mut t = Tcp::accept(&listener).unwrap();
                match t.recv().unwrap() {
                    Msg::KeySeed { seed } => seen.push(seed),
                    other => panic!("unexpected {other:?}"),
                }
            }
            seen.sort_unstable();
            seen
        });
        let mut a = Tcp::connect(addr).unwrap();
        a.send(&Msg::KeySeed { seed: 1 }).unwrap();
        let mut b = Tcp::connect(addr).unwrap();
        b.send(&Msg::KeySeed { seed: 2 }).unwrap();
        assert_eq!(server.join().unwrap(), vec![1, 2]);
    }
}
