//! Integration tests for the multi-client coordinator: N concurrent edges
//! training end to end against one cloud over the in-proc (+SimLink) and TCP
//! transports, with per-client and aggregate byte accounting.  Every
//! byte-accounting scenario runs through BOTH serving styles — the
//! thread-per-client pool and the nonblocking reactor — which must be
//! indistinguishable to the edges.  No AOT artifacts needed (host codec
//! venue).

use c3sl::config::TransportKind;
use c3sl::coordinator::{run_multi_edge, MultiEdgeSpec, MultiRunOutput};
use c3sl::tensor::{Labels, Tensor};
use c3sl::transport::sim::LinkModel;
use c3sl::transport::tcp::Tcp;
use c3sl::transport::{Msg, Transport};

fn spec(edges: usize, transport: TransportKind, addr: &str) -> MultiEdgeSpec {
    MultiEdgeSpec {
        edges,
        steps: 6,
        r: 2,
        d: 256,
        batch: 8,
        seed: 5,
        workers: 2,
        transport,
        tcp_addr: addr.into(),
        ..MultiEdgeSpec::default()
    }
}

fn reactor_spec(edges: usize, transport: TransportKind, addr: &str) -> MultiEdgeSpec {
    MultiEdgeSpec { reactor: true, ..spec(edges, transport, addr) }
}

fn check_accounting_steps(out: &MultiRunOutput, edges: usize, steps: u64) {
    assert_eq!(out.cloud.per_client.len(), edges);
    assert_eq!(out.edges.len(), edges);
    for c in &out.cloud.per_client {
        assert_eq!(c.steps, steps, "client {} steps", c.client);
        assert!(c.rx_bytes > 0 && c.tx_bytes > 0);
        // per step: Features + TrainLabels up, Gradients + StepStats down,
        // plus the KeySeed handshake and Shutdown
        assert_eq!(c.rx_msgs, steps * 2 + 2, "client {} rx msgs", c.client);
        assert_eq!(c.tx_msgs, steps * 2, "client {} tx msgs", c.client);
    }
    // the aggregate must be exactly the sum of the per-client halves
    let edge_tx: u64 = out.edges.iter().map(|e| e.tx_bytes).sum();
    let edge_rx: u64 = out.edges.iter().map(|e| e.rx_bytes).sum();
    assert_eq!(out.cloud.total_rx(), edge_tx, "cloud rx == sum of edge uplinks");
    assert_eq!(out.cloud.total_tx(), edge_rx, "cloud tx == sum of edge downlinks");
    assert_eq!(out.cloud.total_steps(), steps * edges as u64);
    // and training must make progress through the lossy codec on every edge
    for (i, e) in out.edges.iter().enumerate() {
        assert!(
            e.last_loss < e.first_loss,
            "edge {i}: probe loss did not decrease ({} -> {})",
            e.first_loss,
            e.last_loss
        );
        assert!(e.first_loss.is_finite() && e.last_loss.is_finite());
    }
}

fn check_accounting(out: &MultiRunOutput, edges: usize) {
    check_accounting_steps(out, edges, 6);
}

#[test]
fn two_inproc_edges_train_concurrently() {
    let out = run_multi_edge(&spec(2, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 2);
    // identical edges (different seeds) see byte-identical frame sizes:
    // same geometry → same serialized bytes per client
    let tx0 = out.cloud.per_client[0].rx_bytes;
    for c in &out.cloud.per_client {
        assert_eq!(c.rx_bytes, tx0, "uniform geometry → uniform per-client bytes");
    }
}

#[test]
fn four_inproc_edges_with_link_model() {
    let mut s = spec(4, TransportKind::InProc, "");
    s.link = Some(LinkModel::wifi());
    let out = run_multi_edge(&s).unwrap();
    check_accounting(&out, 4);
}

#[test]
fn two_tcp_edges_train_concurrently() {
    let out = run_multi_edge(&spec(2, TransportKind::Tcp, "127.0.0.1:39413")).unwrap();
    check_accounting(&out, 2);
}

#[test]
fn three_tcp_edges_aggregate_accounting() {
    let out = run_multi_edge(&spec(3, TransportKind::Tcp, "127.0.0.1:39414")).unwrap();
    check_accounting(&out, 3);
}

#[test]
fn single_edge_multi_path_still_works() {
    // edges=1 must behave exactly like a 1-client pool
    let out = run_multi_edge(&spec(1, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 1);
}

#[test]
fn rejects_bad_geometry() {
    let mut s = spec(2, TransportKind::InProc, "");
    s.batch = 7; // not divisible by r=2
    assert!(run_multi_edge(&s).is_err());
    let mut s = spec(2, TransportKind::InProc, "");
    s.edges = 0;
    assert!(run_multi_edge(&s).is_err());
}

// ---------------------------------------------------------------------------
// Reactor serving path: the same contract through one I/O thread
// ---------------------------------------------------------------------------

#[test]
fn reactor_inproc_edges_train_concurrently() {
    let out = run_multi_edge(&reactor_spec(4, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 4);
}

#[test]
fn reactor_tcp_edges_train_concurrently() {
    let out = run_multi_edge(&reactor_spec(3, TransportKind::Tcp, "127.0.0.1:39415")).unwrap();
    check_accounting(&out, 3);
}

#[test]
fn reactor_matches_thread_per_client_traffic() {
    // Identical geometry through both serving styles must put identical
    // bytes on the wire — scheduling is not allowed to change the protocol.
    let threads = run_multi_edge(&spec(2, TransportKind::InProc, "")).unwrap();
    let reactor = run_multi_edge(&reactor_spec(2, TransportKind::InProc, "")).unwrap();
    assert_eq!(threads.cloud.total_rx(), reactor.cloud.total_rx());
    assert_eq!(threads.cloud.total_tx(), reactor.cloud.total_tx());
    assert_eq!(threads.cloud.total_steps(), reactor.cloud.total_steps());
}

#[test]
fn reactor_scales_to_256_inproc_edges() {
    // The ROADMAP scale axis: 256 concurrent edges against ONE reactor I/O
    // thread (+4 codec workers), with exact per-client byte accounting and a
    // decreasing probe objective on every edge.  Small geometry keeps this
    // inside the smoke budget.
    let out = run_multi_edge(&MultiEdgeSpec {
        edges: 256,
        steps: 2,
        r: 2,
        d: 64,
        batch: 4,
        seed: 11,
        workers: 4,
        transport: TransportKind::InProc,
        reactor: true,
        ..MultiEdgeSpec::default()
    })
    .unwrap();
    check_accounting_steps(&out, 256, 2);
}

#[test]
fn reactor_survives_slow_and_pipelining_client() {
    // One misbehaving client exercises the backpressure machinery: it
    // pipelines several steps up-front without reading a single reply, then
    // stalls, then drains.  Its parsed-job queue exceeds max_pending_jobs
    // (hold kicks in) and its replies pile into the bounded outbox.  The
    // well-behaved lockstep edges must train to completion regardless, and
    // every byte must still be accounted for exactly.
    let addr = "127.0.0.1:39416";
    let steps = 4u64;
    let mut s = reactor_spec(3, TransportKind::Tcp, addr);
    s.steps = steps;
    s.poll.max_outbox_frames = 2; // small bound → backpressure actually engages
    s.poll.max_pending_jobs = 2;

    // The driver runs the 3 normal edges; the rogue client speaks the wire
    // protocol by hand on its own connection.  It runs MORE steps than the
    // lockstep edges so its byte counts are unique — the report-matching
    // assertion below identifies it unambiguously.
    let rogue_steps = steps + 2;
    let key_seed = s.seed ^ 0xC3_C3_C3_C3u64;
    let rogue = std::thread::spawn(move || {
        let mut tp = Tcp::connect(addr).unwrap();
        tp.send(&Msg::KeySeed { seed: key_seed }).unwrap();
        // pipeline all steps without reading anything back
        for step in 0..rogue_steps {
            let z = Tensor::zeros(&[4, 256]); // (G=4, D) carriers, R=2 → B=8
            tp.send(&Msg::Features { step, tensor: z }).unwrap();
            tp.send(&Msg::TrainLabels { step, labels: Labels(vec![0; 8]) }).unwrap();
        }
        // stall: replies must wait in the cloud's bounded outbox
        std::thread::sleep(std::time::Duration::from_millis(150));
        for step in 0..rogue_steps {
            match tp.recv().unwrap() {
                Msg::Gradients { step: gstep, .. } => assert_eq!(gstep, step),
                other => panic!("rogue expected Gradients, got {other:?}"),
            }
            match tp.recv().unwrap() {
                Msg::StepStats { step: sstep, .. } => assert_eq!(sstep, step),
                other => panic!("rogue expected StepStats, got {other:?}"),
            }
        }
        tp.send(&Msg::Shutdown).unwrap();
        tp.stats()
    });

    // serve 4 connections (3 lockstep edges + the rogue) on one reactor
    let (cloud, edges) = run_multi_edge_with_extra(&s, addr, steps);
    let rogue_stats = rogue.join().unwrap();

    // normal edges all trained to completion
    assert_eq!(edges.len(), 3);
    for (i, e) in edges.iter().enumerate() {
        assert_eq!(e.steps, steps);
        assert!(
            e.last_loss < e.first_loss,
            "edge {i}: loss did not decrease under a stalling neighbour"
        );
    }
    // the rogue was served every step, and its bytes balance exactly; its
    // distinct step count makes the byte-count match unique among clients
    let matches: Vec<_> = cloud
        .per_client
        .iter()
        .filter(|c| c.rx_bytes == rogue_stats.tx() && c.tx_bytes == rogue_stats.rx())
        .collect();
    assert_eq!(matches.len(), 1, "exactly one report mirrors the rogue's accounting");
    assert_eq!(matches[0].steps, rogue_steps);
    // aggregate: cloud rx == all uplinks (3 drivers + rogue)
    let edge_tx: u64 = edges.iter().map(|e| e.tx_bytes).sum::<u64>() + rogue_stats.tx();
    assert_eq!(cloud.total_rx(), edge_tx);
}

/// Drive a reactor cloud expecting `spec.edges + 1` connections while this
/// function spawns only `spec.edges` lockstep edges — the extra slot is for
/// the test's hand-rolled client racing on the same listener.
fn run_multi_edge_with_extra(
    spec: &MultiEdgeSpec,
    addr: &str,
    steps: u64,
) -> (c3sl::coordinator::MultiStats, Vec<c3sl::coordinator::EdgeReport>) {
    use c3sl::coordinator::multi;
    use c3sl::coordinator::RunCodec;
    use c3sl::transport::reactor::{NbTcp, ReactorConn};

    let key_seed = spec.seed ^ 0xC3_C3_C3_C3u64;
    let cloud_codec = RunCodec::host(key_seed, spec.r, spec.d, spec.workers);
    let edge_codec = RunCodec::host(key_seed, spec.r, spec.d, spec.workers);
    let n = spec.edges + 1;
    let listener = Tcp::bind(addr).unwrap();
    let poll = spec.poll;
    let workers = spec.workers;
    std::thread::scope(|sc| {
        let cloud = sc.spawn(move || {
            let streams =
                Tcp::accept_streams(&listener, n, std::time::Duration::from_secs(30)).unwrap();
            let conns: Vec<Box<dyn ReactorConn>> = streams
                .into_iter()
                .map(|s| Box::new(NbTcp::from_stream(s).unwrap()) as Box<dyn ReactorConn>)
                .collect();
            multi::serve_clients_reactor(&cloud_codec, conns, workers, poll).unwrap()
        });
        let mut handles = Vec::new();
        for i in 0..spec.edges {
            let codec = &edge_codec;
            handles.push(sc.spawn(move || {
                let mut tp = Tcp::connect(addr).unwrap();
                multi::run_edge(
                    codec,
                    &mut tp,
                    steps,
                    key_seed,
                    spec.seed.wrapping_add(i as u64),
                    spec.batch,
                    spec.d,
                )
                .unwrap()
            }));
        }
        let edges: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (cloud.join().unwrap(), edges)
    })
}

#[test]
fn compression_shows_on_the_wire() {
    // R=4 halves-of-halves the uplink feature bytes vs R=1-equivalent:
    // features are (B/R, D) instead of (B, D).
    let mut s4 = spec(2, TransportKind::InProc, "");
    s4.r = 4;
    s4.batch = 8;
    let out4 = run_multi_edge(&s4).unwrap();
    let mut s1 = spec(2, TransportKind::InProc, "");
    s1.r = 1;
    s1.batch = 8;
    let out1 = run_multi_edge(&s1).unwrap();
    let up4 = out4.cloud.total_rx() as f64;
    let up1 = out1.cloud.total_rx() as f64;
    assert!(
        up1 / up4 > 3.0,
        "R=4 should cut uplink ~4x: {up1} vs {up4}"
    );
}
