//! Utility substrates: PRNG, JSON, timing, property-testing harness, CSV.

pub mod csv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod timer;
