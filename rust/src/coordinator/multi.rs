//! Multi-client coordinator: one cloud serving N concurrent edges,
//! thread-per-client, with per-client and aggregate `LinkStats`.
//!
//! The PJRT model halves are artifact-gated (runtime::xla_stub), so this
//! scenario exercises the full *codec + transport + accounting* stack
//! host-natively: each edge holds a feature buffer z, uplinks `encode(z)`
//! with labels, and the cloud decodes, evaluates the quadratic probe
//! objective L = ½·mean(ẑ²), encodes the gradient gẑ = ẑ/N and downlinks it
//! with the step stats — the same message protocol the single-edge
//! CloudWorker speaks.  The edge applies the decoded gradient to z (toy
//! SGD), so the objective genuinely decreases end-to-end *through* the lossy
//! codec in both directions — the property the tests assert.
//!
//! Both endpoints build their `RunCodec` from the shared key seed; the R×D
//! key matrix never crosses the wire (same key-agreement contract as the
//! single-edge coordinator).

use super::run_codec::RunCodec;
use crate::tensor::{Labels, Tensor};
use crate::transport::{Msg, Transport};
use crate::util::error::{C3Error, Context, Result};
use crate::util::rng::Rng;
use crate::{bail, ensure};

/// Per-client report from the multi-edge cloud (its half of the link).
#[derive(Clone, Debug)]
pub struct ClientReport {
    /// Accept-order client index.
    pub client: usize,
    pub steps: u64,
    pub tx_bytes: u64,
    pub rx_bytes: u64,
    pub tx_msgs: u64,
    pub rx_msgs: u64,
    pub last_loss: f32,
}

/// Aggregated multi-client stats.
#[derive(Clone, Debug, Default)]
pub struct MultiStats {
    pub per_client: Vec<ClientReport>,
}

impl MultiStats {
    pub fn total_tx(&self) -> u64 {
        self.per_client.iter().map(|c| c.tx_bytes).sum()
    }

    pub fn total_rx(&self) -> u64 {
        self.per_client.iter().map(|c| c.rx_bytes).sum()
    }

    pub fn total_steps(&self) -> u64 {
        self.per_client.iter().map(|c| c.steps).sum()
    }
}

/// Per-edge report (the edge's half of the link).
#[derive(Clone, Debug)]
pub struct EdgeReport {
    pub steps: u64,
    pub first_loss: f32,
    pub last_loss: f32,
    pub tx_bytes: u64,
    pub rx_bytes: u64,
}

fn probe_loss(zhat: &Tensor) -> f32 {
    let n = zhat.len().max(1) as f32;
    0.5 * zhat.data().iter().map(|v| v * v).sum::<f32>() / n
}

/// Serve one edge until it sends Shutdown: decode uplink features, evaluate
/// the probe objective, encode the gradients back.
pub fn serve_one(
    codec: &RunCodec,
    transport: &mut dyn Transport,
    client: usize,
) -> Result<ClientReport> {
    let mut pending: Option<(u64, Tensor)> = None;
    let mut steps = 0u64;
    let mut last_loss = 0.0f32;
    loop {
        match transport.recv()? {
            Msg::KeySeed { .. } => {
                // keys already derived from the shared seed at construction
            }
            Msg::Features { step, tensor } => {
                ensure!(
                    pending.is_none(),
                    "client {client}: Features while a step is pending"
                );
                pending = Some((step, tensor));
            }
            Msg::TrainLabels { step, .. } => {
                let (fstep, s) = pending
                    .take()
                    .with_context(|| format!("client {client}: labels before features"))?;
                ensure!(
                    fstep == step,
                    "client {client}: label step mismatch {step} != {fstep}"
                );
                let zhat = codec.decode(&s)?;
                let loss = probe_loss(&zhat);
                // gẑ = dL/dẑ = ẑ/N, compressed for the downlink like the
                // real cloud compresses cut-layer gradients
                let gz = zhat.scale(1.0 / zhat.len().max(1) as f32);
                let gs = codec.encode(&gz)?;
                last_loss = loss;
                steps += 1;
                transport.send(&Msg::Gradients { step, tensor: gs })?;
                transport.send(&Msg::StepStats { step, loss, ncorrect: 0.0 })?;
            }
            Msg::EvalFeatures { step, tensor, labels } => {
                let zhat = codec.decode(&tensor)?;
                let loss = probe_loss(&zhat);
                transport.send(&Msg::EvalStats {
                    step,
                    loss,
                    ncorrect: labels.len() as f32,
                })?;
            }
            Msg::Shutdown => break,
            other => bail!("client {client}: unexpected message {other:?}"),
        }
    }
    let stats = transport.stats();
    Ok(ClientReport {
        client,
        steps,
        tx_bytes: stats.tx(),
        rx_bytes: stats.rx(),
        tx_msgs: stats.tx_msgs.load(std::sync::atomic::Ordering::Relaxed),
        rx_msgs: stats.rx_msgs.load(std::sync::atomic::Ordering::Relaxed),
        last_loss,
    })
}

/// Serve N edges concurrently, one OS thread per client.
pub fn serve_clients<T: Transport>(codec: &RunCodec, transports: Vec<T>) -> Result<MultiStats> {
    let mut reports = std::thread::scope(|sc| -> Result<Vec<ClientReport>> {
        let handles: Vec<_> = transports
            .into_iter()
            .enumerate()
            .map(|(ci, mut tp)| sc.spawn(move || serve_one(codec, &mut tp, ci)))
            .collect();
        let mut reports = Vec::with_capacity(handles.len());
        for h in handles {
            reports.push(
                h.join()
                    .map_err(|_| C3Error::msg("cloud client thread panicked"))??,
            );
        }
        Ok(reports)
    })?;
    reports.sort_by_key(|r| r.client);
    Ok(MultiStats { per_client: reports })
}

/// One synthetic edge: hold a (B, D) feature buffer, uplink `encode(z)`,
/// apply the decoded downlink gradient with a toy SGD step, repeat.  The
/// probe loss contracts geometrically when the codec round trip is faithful,
/// which is exactly what the multi-edge tests assert.
pub fn run_edge(
    codec: &RunCodec,
    transport: &mut dyn Transport,
    steps: u64,
    key_seed: u64,
    data_seed: u64,
    batch: usize,
    d: usize,
) -> Result<EdgeReport> {
    ensure!(steps >= 1, "edge needs at least one step");
    let mut rng = Rng::new(data_seed);
    let mut zdata = vec![0.0f32; batch * d];
    rng.fill_normal(&mut zdata, 0.0, 1.0);
    let mut z = Tensor::from_vec(&[batch, d], zdata);

    // Key agreement: announce the seed the codec keys derive from (the keys
    // never cross the wire).  This is the codec-construction seed, NOT the
    // per-edge data seed — a cloud that honors the handshake must arrive at
    // the same KeySet this edge encodes with.
    transport.send(&Msg::KeySeed { seed: key_seed })?;

    // Effective update: z ← (I − c·A²)z with A = D∘E.  decode = encodeᵀ
    // makes A PSD, but its top eigenvalue is max_f Σ_i |K̂_i(f)|² (well above
    // 1 for random keys), so c must be small for every mode to contract:
    // c·μ_max² < 2.  c = 0.005 leaves a wide margin at the R/D used here
    // while still shrinking the probe loss measurably over a few steps.
    let lr = 0.005f32 * (batch * d) as f32;
    let (mut first_loss, mut last_loss) = (0.0f32, 0.0f32);
    for step in 0..steps {
        let s = codec.encode(&z)?;
        transport.send(&Msg::Features { step, tensor: s })?;
        transport.send(&Msg::TrainLabels { step, labels: Labels(vec![0; batch]) })?;

        let gs = match transport.recv()? {
            Msg::Gradients { step: gstep, tensor } => {
                ensure!(gstep == step, "gradient step mismatch: {gstep} != {step}");
                tensor
            }
            other => bail!("edge expected Gradients, got {other:?}"),
        };
        let loss = match transport.recv()? {
            Msg::StepStats { loss, .. } => loss,
            other => bail!("edge expected StepStats, got {other:?}"),
        };

        let gz = codec.decode(&gs)?;
        ensure!(
            gz.shape() == z.shape(),
            "gradient shape {:?} vs features {:?}",
            gz.shape(),
            z.shape()
        );
        z = z.sub(&gz.scale(lr));

        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
    }
    transport.send(&Msg::Shutdown)?;
    let stats = transport.stats();
    Ok(EdgeReport {
        steps,
        first_loss,
        last_loss,
        tx_bytes: stats.tx(),
        rx_bytes: stats.rx(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::inproc_pair;

    #[test]
    fn single_client_roundtrip_decreases_probe_loss() {
        let (mut etp, ctp) = inproc_pair();
        let cloud_codec = RunCodec::host(7, 2, 128, 1);
        let edge_codec = RunCodec::host(7, 2, 128, 1);
        let (cloud, edge) = std::thread::scope(|sc| {
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(&cloud_codec, &mut tp, 0)
            });
            let edge = run_edge(&edge_codec, &mut etp, 8, 7, 3, 4, 128).unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.steps, 8);
        assert_eq!(edge.steps, 8);
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease: {} -> {}",
            edge.first_loss,
            edge.last_loss
        );
        // the two halves of the link must agree byte-for-byte
        assert_eq!(cloud.rx_bytes, edge.tx_bytes);
        assert_eq!(cloud.tx_bytes, edge.rx_bytes);
    }

    #[test]
    fn serve_clients_reports_per_client() {
        let (mut e1, c1) = inproc_pair();
        let (mut e2, c2) = inproc_pair();
        let cloud_codec = RunCodec::host(9, 2, 64, 1);
        let edge_codec = RunCodec::host(9, 2, 64, 1);
        let stats = std::thread::scope(|sc| {
            let cloud = sc.spawn(|| serve_clients(&cloud_codec, vec![c1, c2]));
            let a = run_edge(&edge_codec, &mut e1, 3, 9, 1, 4, 64).unwrap();
            let b = run_edge(&edge_codec, &mut e2, 4, 9, 2, 4, 64).unwrap();
            let stats = cloud.join().unwrap().unwrap();
            assert_eq!(stats.total_rx(), a.tx_bytes + b.tx_bytes);
            stats
        });
        assert_eq!(stats.per_client.len(), 2);
        assert_eq!(stats.per_client[0].client, 0);
        assert_eq!(stats.per_client[1].client, 1);
        assert_eq!(stats.total_steps(), 3 + 4);
    }
}
