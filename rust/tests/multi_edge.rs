//! Integration tests for the multi-client coordinator: N concurrent edges
//! training end to end against one cloud over the in-proc (+SimLink) and TCP
//! transports, with per-client and aggregate byte accounting.  Every
//! byte-accounting scenario runs through BOTH serving styles — the
//! thread-per-client pool and the nonblocking reactor — which must be
//! indistinguishable to the edges.  The sharded scenarios additionally pin
//! the per-client key-shard contract: `Msg::KeyShard` handshake, epoch
//! rotation continuity, cross-path byte/loss parity, and rejection of rogue
//! announcements without disturbing healthy edges.  No AOT artifacts needed
//! (host codec venue).

use c3sl::config::TransportKind;
use c3sl::coordinator::{run_multi_edge, MultiEdgeSpec, MultiRunOutput};
use c3sl::hdc::keyring::KeyRing;
use c3sl::tensor::{Labels, Tensor};
use c3sl::transport::sim::LinkModel;
use c3sl::transport::tcp::Tcp;
use c3sl::transport::{Msg, Transport};

fn spec(edges: usize, transport: TransportKind, addr: &str) -> MultiEdgeSpec {
    MultiEdgeSpec {
        edges,
        steps: 6,
        r: 2,
        d: 256,
        batch: 8,
        seed: 5,
        workers: 2,
        transport,
        tcp_addr: addr.into(),
        ..MultiEdgeSpec::default()
    }
}

fn reactor_spec(edges: usize, transport: TransportKind, addr: &str) -> MultiEdgeSpec {
    MultiEdgeSpec { reactor: true, ..spec(edges, transport, addr) }
}

fn check_accounting_steps(out: &MultiRunOutput, edges: usize, steps: u64) {
    assert_eq!(out.cloud.per_client.len(), edges);
    assert_eq!(out.edges.len(), edges);
    for c in &out.cloud.per_client {
        assert_eq!(c.steps, steps, "client {} steps", c.client);
        assert!(c.rx_bytes > 0 && c.tx_bytes > 0);
        // per step: Features + TrainLabels up, Gradients + StepStats down,
        // plus the handshake and Shutdown; the sharded handshake is three
        // messages (ShardHello up, ShardChallenge down, KeyShard up) where
        // the shared one is a single KeySeed
        let sharded = u64::from(c.shard.is_some());
        assert_eq!(c.rx_msgs, steps * 2 + 2 + sharded, "client {} rx msgs", c.client);
        assert_eq!(c.tx_msgs, steps * 2 + sharded, "client {} tx msgs", c.client);
    }
    // the aggregate must be exactly the sum of the per-client halves
    let edge_tx: u64 = out.edges.iter().map(|e| e.tx_bytes).sum();
    let edge_rx: u64 = out.edges.iter().map(|e| e.rx_bytes).sum();
    assert_eq!(out.cloud.total_rx(), edge_tx, "cloud rx == sum of edge uplinks");
    assert_eq!(out.cloud.total_tx(), edge_rx, "cloud tx == sum of edge downlinks");
    assert_eq!(out.cloud.total_steps(), steps * edges as u64);
    // and training must make progress through the lossy codec on every edge
    for (i, e) in out.edges.iter().enumerate() {
        assert!(
            e.last_loss < e.first_loss,
            "edge {i}: probe loss did not decrease ({} -> {})",
            e.first_loss,
            e.last_loss
        );
        assert!(e.first_loss.is_finite() && e.last_loss.is_finite());
    }
}

fn check_accounting(out: &MultiRunOutput, edges: usize) {
    check_accounting_steps(out, edges, 6);
}

#[test]
fn two_inproc_edges_train_concurrently() {
    let out = run_multi_edge(&spec(2, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 2);
    // identical edges (different seeds) see byte-identical frame sizes:
    // same geometry → same serialized bytes per client
    let tx0 = out.cloud.per_client[0].rx_bytes;
    for c in &out.cloud.per_client {
        assert_eq!(c.rx_bytes, tx0, "uniform geometry → uniform per-client bytes");
    }
}

#[test]
fn four_inproc_edges_with_link_model() {
    let mut s = spec(4, TransportKind::InProc, "");
    s.link = Some(LinkModel::wifi());
    let out = run_multi_edge(&s).unwrap();
    check_accounting(&out, 4);
}

#[test]
fn two_tcp_edges_train_concurrently() {
    let out = run_multi_edge(&spec(2, TransportKind::Tcp, "127.0.0.1:39413")).unwrap();
    check_accounting(&out, 2);
}

#[test]
fn three_tcp_edges_aggregate_accounting() {
    let out = run_multi_edge(&spec(3, TransportKind::Tcp, "127.0.0.1:39414")).unwrap();
    check_accounting(&out, 3);
}

#[test]
fn single_edge_multi_path_still_works() {
    // edges=1 must behave exactly like a 1-client pool
    let out = run_multi_edge(&spec(1, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 1);
}

#[test]
fn rejects_bad_geometry() {
    let mut s = spec(2, TransportKind::InProc, "");
    s.batch = 7; // not divisible by r=2
    assert!(run_multi_edge(&s).is_err());
    let mut s = spec(2, TransportKind::InProc, "");
    s.edges = 0;
    assert!(run_multi_edge(&s).is_err());
}

// ---------------------------------------------------------------------------
// Reactor serving path: the same contract through one I/O thread
// ---------------------------------------------------------------------------

#[test]
fn reactor_inproc_edges_train_concurrently() {
    let out = run_multi_edge(&reactor_spec(4, TransportKind::InProc, "")).unwrap();
    check_accounting(&out, 4);
}

#[test]
fn reactor_tcp_edges_train_concurrently() {
    let out = run_multi_edge(&reactor_spec(3, TransportKind::Tcp, "127.0.0.1:39415")).unwrap();
    check_accounting(&out, 3);
}

#[test]
fn reactor_matches_thread_per_client_traffic() {
    // Identical geometry through both serving styles must put identical
    // bytes on the wire — scheduling is not allowed to change the protocol.
    let threads = run_multi_edge(&spec(2, TransportKind::InProc, "")).unwrap();
    let reactor = run_multi_edge(&reactor_spec(2, TransportKind::InProc, "")).unwrap();
    assert_eq!(threads.cloud.total_rx(), reactor.cloud.total_rx());
    assert_eq!(threads.cloud.total_tx(), reactor.cloud.total_tx());
    assert_eq!(threads.cloud.total_steps(), reactor.cloud.total_steps());
}

#[test]
fn reactor_scales_to_256_inproc_edges() {
    // The ROADMAP scale axis: 256 concurrent edges against ONE reactor I/O
    // thread (+4 codec workers), with exact per-client byte accounting and a
    // decreasing probe objective on every edge.  Small geometry keeps this
    // inside the smoke budget.
    let out = run_multi_edge(&MultiEdgeSpec {
        edges: 256,
        steps: 2,
        r: 2,
        d: 64,
        batch: 4,
        seed: 11,
        workers: 4,
        transport: TransportKind::InProc,
        reactor: true,
        ..MultiEdgeSpec::default()
    })
    .unwrap();
    check_accounting_steps(&out, 256, 2);
}

#[test]
fn reactor_survives_slow_and_pipelining_client() {
    // One misbehaving client exercises the backpressure machinery: it
    // pipelines several steps up-front without reading a single reply, then
    // stalls, then drains.  Its parsed-job queue exceeds max_pending_jobs
    // (hold kicks in) and its replies pile into the bounded outbox.  The
    // well-behaved lockstep edges must train to completion regardless, and
    // every byte must still be accounted for exactly.
    let addr = "127.0.0.1:39416";
    let steps = 4u64;
    let mut s = reactor_spec(3, TransportKind::Tcp, addr);
    s.steps = steps;
    s.poll.max_outbox_frames = 2; // small bound → backpressure actually engages
    s.poll.max_pending_jobs = 2;

    // The driver runs the 3 normal edges; the rogue client speaks the wire
    // protocol by hand on its own connection.  It runs MORE steps than the
    // lockstep edges so its byte counts are unique — the report-matching
    // assertion below identifies it unambiguously.
    let rogue_steps = steps + 2;
    let key_seed = s.seed ^ 0xC3_C3_C3_C3u64;
    let rogue = std::thread::spawn(move || {
        let mut tp = Tcp::connect(addr).unwrap();
        tp.send(&Msg::KeySeed { seed: key_seed }).unwrap();
        // pipeline all steps without reading anything back
        for step in 0..rogue_steps {
            let z = Tensor::zeros(&[4, 256]); // (G=4, D) carriers, R=2 → B=8
            tp.send(&Msg::Features { step, tensor: z }).unwrap();
            tp.send(&Msg::TrainLabels { step, labels: Labels(vec![0; 8]) }).unwrap();
        }
        // stall: replies must wait in the cloud's bounded outbox
        std::thread::sleep(std::time::Duration::from_millis(150));
        for step in 0..rogue_steps {
            match tp.recv().unwrap() {
                Msg::Gradients { step: gstep, .. } => assert_eq!(gstep, step),
                other => panic!("rogue expected Gradients, got {other:?}"),
            }
            match tp.recv().unwrap() {
                Msg::StepStats { step: sstep, .. } => assert_eq!(sstep, step),
                other => panic!("rogue expected StepStats, got {other:?}"),
            }
        }
        tp.send(&Msg::Shutdown).unwrap();
        tp.stats()
    });

    // serve 4 connections (3 lockstep edges + the rogue) on one reactor
    let (cloud, edges) = run_multi_edge_with_extra(&s, addr, steps);
    let rogue_stats = rogue.join().unwrap();

    // normal edges all trained to completion
    assert_eq!(edges.len(), 3);
    for (i, e) in edges.iter().enumerate() {
        assert_eq!(e.steps, steps);
        assert!(
            e.last_loss < e.first_loss,
            "edge {i}: loss did not decrease under a stalling neighbour"
        );
    }
    // the rogue was served every step, and its bytes balance exactly; its
    // distinct step count makes the byte-count match unique among clients
    let matches: Vec<_> = cloud
        .per_client
        .iter()
        .filter(|c| c.rx_bytes == rogue_stats.tx() && c.tx_bytes == rogue_stats.rx())
        .collect();
    assert_eq!(matches.len(), 1, "exactly one report mirrors the rogue's accounting");
    assert_eq!(matches[0].steps, rogue_steps);
    // aggregate: cloud rx == all uplinks (3 drivers + rogue)
    let edge_tx: u64 = edges.iter().map(|e| e.tx_bytes).sum::<u64>() + rogue_stats.tx();
    assert_eq!(cloud.total_rx(), edge_tx);
}

/// Drive a reactor cloud expecting `spec.edges + 1` connections while this
/// function spawns only `spec.edges` lockstep edges — the extra slot is for
/// the test's hand-rolled client racing on the same listener.
fn run_multi_edge_with_extra(
    spec: &MultiEdgeSpec,
    addr: &str,
    steps: u64,
) -> (c3sl::coordinator::MultiStats, Vec<c3sl::coordinator::EdgeReport>) {
    use c3sl::coordinator::multi;
    use c3sl::coordinator::{CloudCodec, EdgeCodec, RunCodec};
    use c3sl::transport::reactor::{NbTcp, ReactorConn};

    let key_seed = spec.seed ^ 0xC3_C3_C3_C3u64;
    let cloud_codec = RunCodec::host(key_seed, spec.r, spec.d, spec.workers);
    let edge_codec = RunCodec::host(key_seed, spec.r, spec.d, spec.workers);
    let n = spec.edges + 1;
    let listener = Tcp::bind(addr).unwrap();
    let poll = spec.poll;
    let workers = spec.workers;
    std::thread::scope(|sc| {
        let cloud_codec = &cloud_codec;
        let cloud = sc.spawn(move || {
            let streams =
                Tcp::accept_streams(&listener, n, std::time::Duration::from_secs(30)).unwrap();
            let conns: Vec<Box<dyn ReactorConn>> = streams
                .into_iter()
                .map(|s| Box::new(NbTcp::from_stream(s).unwrap()) as Box<dyn ReactorConn>)
                .collect();
            multi::serve_clients_reactor(CloudCodec::Shared(cloud_codec), conns, workers, poll)
                .unwrap()
        });
        let mut handles = Vec::new();
        for i in 0..spec.edges {
            let codec = &edge_codec;
            handles.push(sc.spawn(move || {
                let mut tp = Tcp::connect(addr).unwrap();
                multi::run_edge(
                    EdgeCodec::Shared { codec, key_seed },
                    &mut tp,
                    steps,
                    spec.seed.wrapping_add(i as u64),
                    spec.batch,
                    spec.d,
                )
                .unwrap()
            }));
        }
        let edges: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (cloud.join().unwrap(), edges)
    })
}

// ---------------------------------------------------------------------------
// Per-client key sharding: Msg::KeyShard handshake, rotation, conformance
// ---------------------------------------------------------------------------

fn sharded_spec(edges: usize, transport: TransportKind, addr: &str) -> MultiEdgeSpec {
    MultiEdgeSpec { key_sharding: true, ..spec(edges, transport, addr) }
}

#[test]
fn sharded_inproc_edges_train_both_styles() {
    // No rotation → per-client keys are fixed for the run, so the standard
    // accounting checks (incl. per-edge loss decrease) hold exactly.
    let threads = run_multi_edge(&sharded_spec(3, TransportKind::InProc, "")).unwrap();
    check_accounting(&threads, 3);
    let mut rspec = sharded_spec(3, TransportKind::InProc, "");
    rspec.reactor = true;
    let reactor = run_multi_edge(&rspec).unwrap();
    check_accounting(&reactor, 3);
    // in-proc client order is spawn order, so shard ids line up exactly
    for out in [&threads, &reactor] {
        for (i, c) in out.cloud.per_client.iter().enumerate() {
            assert_eq!(c.shard, Some(i as u64), "client {i} shard id");
        }
    }
    // per-client shards carry different key material but identical frame
    // *sizes* (same geometry), so per-client bytes stay uniform
    let rx0 = threads.cloud.per_client[0].rx_bytes;
    for c in &threads.cloud.per_client {
        assert_eq!(c.rx_bytes, rx0, "uniform geometry → uniform per-client bytes");
    }
}

#[test]
fn sharded_tcp_edges_train() {
    let out = run_multi_edge(&sharded_spec(2, TransportKind::Tcp, "127.0.0.1:39419")).unwrap();
    check_accounting(&out, 2);
    // accept order is arbitrary over TCP: shard ids form a set, not a
    // sequence — each edge claimed exactly one distinct shard
    let mut shards: Vec<u64> =
        out.cloud.per_client.iter().map(|c| c.shard.unwrap()).collect();
    shards.sort_unstable();
    assert_eq!(shards, vec![0, 1]);
}

#[test]
fn sharded_reactor_matches_thread_per_client_bytes_and_losses() {
    // Same seeds through both serve paths, WITH rotation active, must put
    // byte-identical LinkStats and reply frames on every link — scheduling
    // is not allowed to change which keys any step is served with.
    let mut threads = sharded_spec(3, TransportKind::InProc, "");
    threads.rotation_steps = 2;
    let mut reactor = threads.clone();
    reactor.reactor = true;
    let a = run_multi_edge(&threads).unwrap();
    let b = run_multi_edge(&reactor).unwrap();
    assert_eq!(a.cloud.total_steps(), b.cloud.total_steps());
    assert_eq!(a.cloud.total_rx(), b.cloud.total_rx());
    assert_eq!(a.cloud.total_tx(), b.cloud.total_tx());
    for (ca, cb) in a.cloud.per_client.iter().zip(&b.cloud.per_client) {
        assert_eq!(ca.client, cb.client);
        assert_eq!(ca.shard, cb.shard);
        assert_eq!(ca.steps, cb.steps);
        assert_eq!(ca.rx_bytes, cb.rx_bytes, "client {} uplink bytes", ca.client);
        assert_eq!(ca.tx_bytes, cb.tx_bytes, "client {} downlink bytes", ca.client);
        assert_eq!(ca.rx_msgs, cb.rx_msgs);
        assert_eq!(ca.tx_msgs, cb.tx_msgs);
        assert_eq!(
            ca.last_loss.to_bits(),
            cb.last_loss.to_bits(),
            "client {} loss must be bit-identical across serve paths",
            ca.client
        );
    }
    for (i, (ea, eb)) in a.edges.iter().zip(&b.edges).enumerate() {
        assert_eq!(ea.tx_bytes, eb.tx_bytes, "edge {i} uplink");
        assert_eq!(ea.rx_bytes, eb.rx_bytes, "edge {i} downlink");
        assert_eq!(ea.first_loss.to_bits(), eb.first_loss.to_bits(), "edge {i}");
        assert_eq!(ea.last_loss.to_bits(), eb.last_loss.to_bits(), "edge {i}");
    }
}

#[test]
fn packed_backend_serve_paths_agree_under_rotation() {
    // The packed-kernel serve contract: with `fft_backend = packed` on every
    // endpoint and key rotation active, BOTH serving styles must still put
    // byte-identical traffic and bit-identical losses on every link (the
    // packed kernels are deterministic — scheduling may not change which
    // keys or kernels any step is served with).
    let mut threads = sharded_spec(3, TransportKind::InProc, "");
    threads.rotation_steps = 2;
    threads.fft_backend = c3sl::hdc::FftBackend::Packed;
    let mut reactor = threads.clone();
    reactor.reactor = true;
    let a = run_multi_edge(&threads).unwrap();
    let b = run_multi_edge(&reactor).unwrap();
    // NB: no per-edge loss-decrease assertion here — first/last losses sit
    // in different key epochs (rotation), so the robust checks are exact
    // accounting and cross-path equality, as in the reference-backend
    // rotation parity test above
    for out in [&a, &b] {
        assert_eq!(out.cloud.per_client.len(), 3);
        for c in &out.cloud.per_client {
            assert_eq!(c.steps, 6, "client {} lost a step", c.client);
            assert_eq!(c.rx_msgs, 6 * 2 + 3, "client {} rx msgs", c.client);
            assert_eq!(c.tx_msgs, 6 * 2 + 1, "client {} tx msgs", c.client);
        }
        let edge_tx: u64 = out.edges.iter().map(|e| e.tx_bytes).sum();
        assert_eq!(out.cloud.total_rx(), edge_tx);
        for (i, e) in out.edges.iter().enumerate() {
            assert!(e.first_loss.is_finite() && e.last_loss.is_finite(), "edge {i}");
        }
    }
    assert_eq!(a.cloud.total_rx(), b.cloud.total_rx());
    assert_eq!(a.cloud.total_tx(), b.cloud.total_tx());
    for (ca, cb) in a.cloud.per_client.iter().zip(&b.cloud.per_client) {
        assert_eq!(ca.client, cb.client);
        assert_eq!(ca.shard, cb.shard);
        assert_eq!(ca.rx_bytes, cb.rx_bytes, "client {} uplink bytes", ca.client);
        assert_eq!(ca.tx_bytes, cb.tx_bytes, "client {} downlink bytes", ca.client);
        assert_eq!(
            ca.last_loss.to_bits(),
            cb.last_loss.to_bits(),
            "client {} packed loss must be bit-identical across serve paths",
            ca.client
        );
    }
    // and the packed run lands within tolerance of the reference run: the
    // same scenario on the reference kernels reports ~equal (not
    // bit-identical) probe losses — the tolerance-parity story end to end
    // through the serve stack
    let mut reference = threads.clone();
    reference.fft_backend = c3sl::hdc::FftBackend::Reference;
    let r = run_multi_edge(&reference).unwrap();
    assert_eq!(r.cloud.total_rx(), a.cloud.total_rx(), "frame sizes must not change");
    for (cp, cr) in a.cloud.per_client.iter().zip(&r.cloud.per_client) {
        let (lp, lr) = (cp.last_loss as f64, cr.last_loss as f64);
        assert!(
            (lp - lr).abs() <= 1e-6 + 1e-4 * lp.abs().max(lr.abs()),
            "client {}: packed loss {lp} drifted from reference {lr}",
            cp.client
        );
    }
}

/// Drive a sharded reactor cloud serving 3 healthy edges plus one rogue
/// connection whose `Msg::KeyShard` announcement is invalid.  The rogue
/// receives the cloud's challenge like everyone else and `make_rogue` builds
/// its announcement from the (ring, nonce) pair.  The rogue must be rejected
/// and closed; every healthy edge must train to completion; the rejection
/// surfaces only in the aggregate serve error afterwards (the
/// fault-isolation contract from the broken-client test, extended to the
/// handshake).
fn sharded_rogue_case(addr: &'static str, make_rogue: fn(KeyRing, u64) -> Msg, expect: &str) {
    use c3sl::coordinator::multi;
    use c3sl::coordinator::{CloudCodec, EdgeCodec, ShardGate};
    use c3sl::hdc::FftBackend;
    use c3sl::transport::reactor::{NbTcp, ReactorConfig, ReactorConn};

    let edges = 3usize;
    let steps = 4u64;
    let ring = KeyRing::new(0x51AD, 2, 128, 0);
    let n = edges + 1;
    let gate = ShardGate::new(ring, n);
    let listener = Tcp::bind(addr).unwrap();
    let (serve_result, reports) = std::thread::scope(|sc| {
        let gate = &gate;
        let cloud = sc.spawn(move || {
            let streams =
                Tcp::accept_streams(&listener, n, std::time::Duration::from_secs(30)).unwrap();
            let conns: Vec<Box<dyn ReactorConn>> = streams
                .into_iter()
                .map(|s| Box::new(NbTcp::from_stream(s).unwrap()) as Box<dyn ReactorConn>)
                .collect();
            multi::serve_clients_reactor(
                CloudCodec::Sharded(gate),
                conns,
                2,
                ReactorConfig::default(),
            )
        });
        let rogue = sc.spawn(move || {
            let mut tp = Tcp::connect(addr).unwrap();
            // hello first, like every sharded edge; the cloud answers with
            // this connection's challenge
            tp.send(&Msg::ShardHello).unwrap();
            let nonce = match tp.recv().unwrap() {
                Msg::ShardChallenge { nonce } => nonce,
                other => panic!("rogue expected ShardChallenge, got {other:?}"),
            };
            tp.send(&make_rogue(ring, nonce)).unwrap();
            // rejected AND closed: the next read observes the hangup
            assert!(
                tp.recv().is_err(),
                "rogue connection should be closed by the cloud"
            );
        });
        let mut handles = Vec::new();
        for i in 0..edges {
            handles.push(sc.spawn(move || {
                let mut tp = Tcp::connect(addr).unwrap();
                multi::run_edge(
                    EdgeCodec::Sharded {
                        shard: ring.edge_shard(i as u64),
                        workers: 1,
                        fft: FftBackend::default(),
                    },
                    &mut tp,
                    steps,
                    i as u64,
                    8,
                    128,
                )
                .unwrap()
            }));
        }
        let reports: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        rogue.join().unwrap();
        (cloud.join().unwrap(), reports)
    });
    let err = serve_result.expect_err("rogue handshake must surface in the aggregate error");
    assert!(err.to_string().contains(expect), "{err}");
    // every healthy edge trained to completion, undisturbed (fixed keys →
    // deterministic per-step loss decrease)
    assert_eq!(reports.len(), edges);
    for (i, e) in reports.iter().enumerate() {
        assert_eq!(e.steps, steps, "edge {i} lost steps to the rogue");
        assert!(
            e.last_loss < e.first_loss,
            "edge {i}: probe loss did not decrease next to a rejected rogue"
        );
    }
}

#[test]
fn sharded_reactor_rejects_wrong_shard_id_without_disturbing_edges() {
    sharded_rogue_case(
        "127.0.0.1:39417",
        |ring, nonce| {
            Msg::KeyShard { client_id: 99, epoch: 0, proof: ring.shard_proof(99, 0, nonce) }
        },
        "out of range",
    );
}

#[test]
fn sharded_reactor_rejects_stale_epoch_without_disturbing_edges() {
    sharded_rogue_case(
        "127.0.0.1:39418",
        |ring, nonce| {
            Msg::KeyShard { client_id: 3, epoch: 7, proof: ring.shard_proof(3, 7, nonce) }
        },
        "stale key epoch",
    );
}

#[test]
fn sharded_reactor_rejects_replayed_proof_without_disturbing_edges() {
    // The adversarial replay leg, end to end over TCP: the rogue holds a
    // proof that was valid for an EARLIER challenge (simulated by answering
    // a different nonce than the one this connection was issued).  The
    // nonce-bound PRF makes it worthless: rejected, closed, healthy edges
    // untouched.
    sharded_rogue_case(
        "127.0.0.1:39420",
        |ring, nonce| {
            Msg::KeyShard {
                client_id: 3,
                epoch: 0,
                proof: ring.shard_proof(3, 0, nonce.wrapping_add(1)),
            }
        },
        "proof mismatch",
    );
}

#[test]
fn key_shard_smoke_64_edge_reactor_rotation() {
    // The ISSUE acceptance scenario (and the CI `key-shard-smoke` job): 64
    // sharded edges against one reactor cloud, rotating keys every 4 steps
    // of an 8-step run — the epoch boundary must lose no training step.
    let steps = 8u64;
    let edges = 64usize;
    let out = run_multi_edge(&MultiEdgeSpec {
        edges,
        steps,
        r: 2,
        d: 256,
        batch: 8,
        seed: 17,
        workers: 4,
        transport: TransportKind::InProc,
        reactor: true,
        key_sharding: true,
        rotation_steps: 4,
        ..MultiEdgeSpec::default()
    })
    .unwrap();
    assert_eq!(out.cloud.per_client.len(), edges);
    assert_eq!(out.edges.len(), edges);
    // rotation continuity: every client served every step, every message
    // accounted for, both halves of every link byte-balanced
    for c in &out.cloud.per_client {
        assert_eq!(
            c.steps, steps,
            "client {} lost a step across the epoch boundary",
            c.client
        );
        // hello + claim + per-step uplinks + shutdown; challenge + replies
        assert_eq!(c.rx_msgs, steps * 2 + 3, "client {} rx msgs", c.client);
        assert_eq!(c.tx_msgs, steps * 2 + 1, "client {} tx msgs", c.client);
    }
    let edge_tx: u64 = out.edges.iter().map(|e| e.tx_bytes).sum();
    let edge_rx: u64 = out.edges.iter().map(|e| e.rx_bytes).sum();
    assert_eq!(out.cloud.total_rx(), edge_tx);
    assert_eq!(out.cloud.total_tx(), edge_rx);
    assert_eq!(out.cloud.total_steps(), steps * edges as u64);
    // every edge claimed its own shard, exactly once
    let mut shards: Vec<u64> = out
        .cloud
        .per_client
        .iter()
        .map(|c| c.shard.expect("sharded run reports shard ids"))
        .collect();
    shards.sort_unstable();
    assert_eq!(shards, (0..edges as u64).collect::<Vec<_>>());
    // training stays healthy through the rotation: every loss finite, and
    // the fleet-average probe loss decreases.  (first/last are measured
    // under *different* key draws per edge, so the robust cross-epoch
    // signal is the aggregate, not each individual edge.)
    let (mut first_sum, mut last_sum) = (0f64, 0f64);
    for (i, e) in out.edges.iter().enumerate() {
        assert_eq!(e.steps, steps);
        assert!(e.first_loss.is_finite() && e.last_loss.is_finite(), "edge {i}");
        first_sum += e.first_loss as f64;
        last_sum += e.last_loss as f64;
    }
    assert!(
        last_sum < first_sum,
        "aggregate probe loss did not decrease across the rotation: \
         {first_sum} -> {last_sum}"
    );
}

#[test]
fn compression_shows_on_the_wire() {
    // R=4 halves-of-halves the uplink feature bytes vs R=1-equivalent:
    // features are (B/R, D) instead of (B, D).
    let mut s4 = spec(2, TransportKind::InProc, "");
    s4.r = 4;
    s4.batch = 8;
    let out4 = run_multi_edge(&s4).unwrap();
    let mut s1 = spec(2, TransportKind::InProc, "");
    s1.r = 1;
    s1.batch = 8;
    let out1 = run_multi_edge(&s1).unwrap();
    let up4 = out4.cloud.total_rx() as f64;
    let up1 = out1.cloud.total_rx() as f64;
    assert!(
        up1 / up4 > 3.0,
        "R=4 should cut uplink ~4x: {up1} vs {up4}"
    );
}
