//! Bench: codec hot-path microbenchmarks — the perf-pass instrument AND the
//! CI benchmark-regression gate.
//!
//!   cargo bench --bench codec_hotpath                       # report only
//!   cargo bench --bench codec_hotpath -- \
//!       --json BENCH_codec_hotpath.json \
//!       --gate BENCH_baseline.json                          # CI bench-gate
//!
//! Sweeps the codec venues:
//!   host/direct       — paper-faithful O(D²) loops (seed allocating path)
//!   host/fft          — seed allocating convolution-theorem path (encode_ref:
//!                       3+ fresh Vec<C64> per group, reference-kernel FFT)
//!   host/fft-scratch  — the zero-allocation engine: caller-owned C3Scratch,
//!                       table-driven branchless FFT kernel (bit-identical to
//!                       host/fft — the property tests prove it)
//!   host/fft-packed   — the packed half-spectrum engine: real transforms
//!                       through one N/2-point FFT each, half-size key
//!                       spectra, decode inverses paired two-rows-per-
//!                       transform (tolerance-equal to the reference — see
//!                       the hdc packed parity tests).  Pinned to the
//!                       forced-scalar kernel set so its trajectory keeps
//!                       measuring the pre-SIMD packed loops
//!   host/fft-simd     — the same packed engine through the runtime-detected
//!                       SIMD kernel set (AVX2+FMA / NEON; equals the scalar
//!                       set on hosts with neither — see fft::kernels)
//!   host/fft-parallel — the scratch engine fanned out group-parallel across
//!                       scoped worker threads
//!   artifact          — AOT Pallas kernels through PJRT (includes runtime
//!                       dispatch + literal marshalling), when artifacts exist
//! across D ∈ {512..4096} at B=32, and reports per-batch time + effective
//! throughput.  Results and the optimization log live in EXPERIMENTS.md §Perf.
//!
//! `--json PATH` writes the machine-readable result (venue × D → encode/
//! decode rows-per-second + bytes per step) for the repo-root
//! `BENCH_codec_hotpath.json` trajectory.  `--gate BASELINE` compares the
//! fresh numbers against a committed baseline and exits non-zero when any
//! venue regresses more than the tolerance (default 15%, env
//! `C3SL_BENCH_GATE_TOL`), or when the packed engine fails its acceptance
//! floor: ≥ 1.3x decode rows/s over host/fft-scratch at D = 2048 — or when
//! the SIMD kernel set fails its own floor: ≥ 2x decode rows/s over the
//! forced-scalar host/fft-packed venue at D = 2048 (armed only once the
//! committed baseline carries non-zero host/fft-simd cells AND a vector ISA
//! was actually detected, so scalar-only hosts warn instead of fail).  Baseline
//! entries whose value is 0 (or a baseline with `"calibrated": false`) skip
//! the absolute comparison, and an uncalibrated baseline also downgrades
//! the packed floor to a loud warning — no threshold blocks merges before
//! it has been measured once on the runner class (committing a calibrated
//! baseline arms everything).  Quick mode (`C3SL_BENCH_QUICK=1`) trims
//! iteration counts for
//! CI; rows/s are taken from each measurement's fastest iteration to damp
//! scheduler noise.

use std::collections::BTreeMap;

use c3sl::fft::kernels::{Isa, Kernels};
use c3sl::hdc::{Backend, C3Scratch, FftBackend, KeySet, C3};
use c3sl::runtime::{CodecRuntime, Engine};
use c3sl::tensor::Tensor;
use c3sl::util::json::Json;
use c3sl::util::rng::Rng;
use c3sl::util::timer::{bench, fmt_secs, BenchStats};

/// One venue × D measurement destined for the JSON artifact.
struct Sample {
    venue: &'static str,
    d: usize,
    /// Feature rows encoded per second (B / fastest encode pass).
    encode_rows_per_s: f64,
    /// Feature rows decoded per second (B / fastest decode pass).
    decode_rows_per_s: f64,
    /// Uncompressed feature bytes moved through the codec per step (B·D·4).
    bytes_per_step: usize,
}

fn row(venue: &str, d: usize, enc: &BenchStats, dec: &BenchStats, bytes: f64) {
    println!(
        "{:<18} {:>6} | {:>12} {:>12} | {:>14.1}",
        venue,
        d,
        fmt_secs(enc.mean_s),
        fmt_secs(dec.mean_s),
        bytes / (enc.mean_s + dec.mean_s) / 1e6,
    );
}

fn record(
    samples: &mut Vec<Sample>,
    venue: &'static str,
    d: usize,
    b: usize,
    enc: &BenchStats,
    dec: &BenchStats,
) {
    row(venue, d, enc, dec, (b * d * 4) as f64);
    samples.push(Sample {
        venue,
        d,
        encode_rows_per_s: b as f64 / enc.min_s.max(1e-12),
        decode_rows_per_s: b as f64 / dec.min_s.max(1e-12),
        bytes_per_step: b * d * 4,
    });
}

fn sample<'a>(samples: &'a [Sample], venue: &str, d: usize) -> Option<&'a Sample> {
    samples.iter().find(|s| s.venue == venue && s.d == d)
}

fn samples_to_json(samples: &[Sample], b: usize, r: usize, quick: bool) -> Json {
    let mut venues: BTreeMap<String, Json> = BTreeMap::new();
    for s in samples {
        let entry = venues
            .entry(s.venue.to_string())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        if let Json::Obj(m) = entry {
            m.insert(
                s.d.to_string(),
                Json::obj(vec![
                    ("encode_rows_per_s", Json::num(s.encode_rows_per_s)),
                    ("decode_rows_per_s", Json::num(s.decode_rows_per_s)),
                    ("bytes_per_step", Json::num(s.bytes_per_step as f64)),
                ]),
            );
        }
    }
    Json::obj(vec![
        ("bench", Json::str("codec_hotpath")),
        ("b", Json::num(b as f64)),
        ("r", Json::num(r as f64)),
        ("quick", Json::Bool(quick)),
        // "usable as an armed baseline" — deliberately NEVER emitted true:
        // copying a fresh result over BENCH_baseline.json must not silently
        // arm the 15% absolute gates on one runner's quick-mode numbers;
        // flipping this to true is the maintainer's explicit, reviewed call
        // (see the note inside BENCH_baseline.json)
        ("calibrated", Json::Bool(false)),
        ("venues", Json::Obj(venues)),
    ])
}

/// Compare fresh samples against a committed baseline.  Returns the list of
/// human-readable gate failures (empty = pass).
fn gate_failures(samples: &[Sample], baseline: &Json, tol: f64) -> Vec<String> {
    let mut failures = Vec::new();
    let calibrated = c3sl::util::bench::calibrated(baseline);
    if !calibrated {
        println!(
            "(gate: baseline is uncalibrated — absolute throughput checks skipped; \
             refresh it from a fresh BENCH_codec_hotpath.json)"
        );
    }
    let Some(venues) = baseline.get("venues").and_then(|v| v.as_obj()) else {
        failures.push("baseline has no \"venues\" object".into());
        return failures;
    };
    for (venue, per_d) in venues {
        // `reactor/*` venues are owned by benches/reactor_scale.rs (which
        // gates them itself); this bench neither measures nor judges them
        if venue.starts_with("reactor/") {
            continue;
        }
        let Some(per_d) = per_d.as_obj() else { continue };
        for (dstr, entry) in per_d {
            let Ok(d) = dstr.parse::<usize>() else { continue };
            let Some(fresh) = sample(samples, venue, d) else {
                failures.push(format!("baseline venue {venue} D={d} was not measured"));
                continue;
            };
            for (key, fresh_v) in [
                ("encode_rows_per_s", fresh.encode_rows_per_s),
                ("decode_rows_per_s", fresh.decode_rows_per_s),
            ] {
                let old = entry.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
                if !calibrated || old <= 0.0 {
                    continue; // no recorded trajectory for this cell yet
                }
                let floor = old * (1.0 - tol);
                if fresh_v < floor {
                    failures.push(format!(
                        "{venue} D={d} {key} regressed {:.1}%: {fresh_v:.0} rows/s vs \
                         baseline {old:.0} (floor {floor:.0} at {:.0}% tolerance)",
                        100.0 * (1.0 - fresh_v / old),
                        tol * 100.0,
                    ));
                }
            }
        }
    }
    failures
}

fn main() {
    // argv after `--`: [--json PATH] [--gate BASELINE]
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let flag = |name: &str| -> Option<String> {
        argv.iter()
            .position(|a| a == name)
            .and_then(|i| argv.get(i + 1))
            .cloned()
    };
    let json_path = flag("--json");
    let gate_path = flag("--gate");
    // tolerance + calibration policy is shared with the reactor gate
    // (util::bench) so the two bench gates cannot silently diverge
    let gate_tol = c3sl::util::bench::gate_tolerance();

    let quick = std::env::var("C3SL_BENCH_QUICK").is_ok();
    let iters = if quick { 5 } else { 10 };
    let b = 32usize;
    let r = 4usize;
    let par_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(2, 8);
    let simd_isa = Kernels::detect().isa();
    println!(
        "# codec hot path: encode+decode per batch (B={b}, R={r}, {iters} iters, \
         parallel workers={par_workers}, simd={})\n",
        simd_isa.name()
    );
    println!(
        "{:<18} {:>6} | {:>12} {:>12} | {:>14}",
        "venue", "D", "encode", "decode", "batch MB/s"
    );

    let mut samples: Vec<Sample> = Vec::new();
    let mut rng = Rng::new(9);
    for d in [512usize, 1024, 2048, 4096] {
        let mut zdata = vec![0.0f32; b * d];
        rng.fill_normal(&mut zdata, 0.0, 1.0);
        let z = Tensor::from_vec(&[b, d], zdata);
        let g = b / r;

        for backend in [Backend::Direct, Backend::Fft] {
            let keys = KeySet::generate(&mut rng, r, d);
            let c3 = C3::new(keys, backend);
            let it = if backend == Backend::Direct && d >= 2048 { 2 } else { iters };
            let enc = bench(1, it, || c3.encode_ref(&z));
            let s = c3.encode_ref(&z);
            let dec = bench(1, it, || c3.decode_ref(&s));
            let venue = if backend == Backend::Direct { "host/direct" } else { "host/fft" };
            record(&mut samples, venue, d, b, &enc, &dec);
        }

        // scratch venue: zero allocations in steady state
        let keys = KeySet::generate(&mut rng, r, d);
        let c3 = C3::new(keys.clone(), Backend::Fft);
        let mut scratch = C3Scratch::new(d);
        let mut out_e = vec![0.0f32; g * d];
        let mut out_d = vec![0.0f32; b * d];
        let enc = bench(1, iters, || c3.encode_into(&z, &mut out_e, &mut scratch));
        let s = c3.encode(&z);
        let dec = bench(1, iters, || c3.decode_into(&s, &mut out_d, &mut scratch));
        record(&mut samples, "host/fft-scratch", d, b, &enc, &dec);

        // packed venue: half-spectrum kernels on the same scratch engine,
        // pinned to the forced-scalar kernel set so this trajectory keeps
        // measuring the pre-SIMD packed loops (the SIMD delta gets its own
        // venue below instead of silently inflating this one)
        let c3p =
            C3::with_kernels(keys.clone(), Backend::Fft, FftBackend::Packed, 1, Kernels::scalar());
        let enc = bench(1, iters, || c3p.encode_into(&z, &mut out_e, &mut scratch));
        let sp = c3p.encode(&z);
        let dec = bench(1, iters, || c3p.decode_into(&sp, &mut out_d, &mut scratch));
        record(&mut samples, "host/fft-packed", d, b, &enc, &dec);

        // simd venue: the same packed engine through the runtime-detected
        // kernel set (equals host/fft-packed on hosts with no vector ISA)
        let c3s = C3::with_backends(keys.clone(), Backend::Fft, FftBackend::Packed, 1);
        let enc = bench(1, iters, || c3s.encode_into(&z, &mut out_e, &mut scratch));
        let ss = c3s.encode(&z);
        let dec = bench(1, iters, || c3s.decode_into(&ss, &mut out_d, &mut scratch));
        record(&mut samples, "host/fft-simd", d, b, &enc, &dec);

        // parallel venue: groups fanned out across scoped worker threads
        let c3w = C3::with_workers(keys, Backend::Fft, par_workers);
        let enc = bench(1, iters, || c3w.par_encode_into(&z, &mut out_e, par_workers));
        let dec = bench(1, iters, || c3w.par_decode_into(&s, &mut out_d, par_workers));
        record(&mut samples, "host/fft-parallel", d, b, &enc, &dec);
    }

    // Artifact venue at the tiny model's real geometry (D=1024, B=32, R=4).
    let dir = "artifacts/vggt_b32/codec_c3_r4";
    if std::path::Path::new(dir).join("manifest.json").exists() {
        match Engine::cpu() {
            Ok(engine) => {
                let mut codec = CodecRuntime::load(&engine, dir).expect("codec artifacts");
                codec.init_keys(1).expect("keys");
                let d = codec.d();
                let mut zdata = vec![0.0f32; b * d];
                rng.fill_normal(&mut zdata, 0.0, 1.0);
                let z = Tensor::from_vec(&[b, d], zdata);
                let enc = bench(1, iters, || codec.encode(&z).unwrap());
                let s = codec.encode(&z).unwrap();
                let dec = bench(1, iters, || codec.decode(&s).unwrap());
                row("artifact", d, &enc, &dec, (b * d * 4) as f64);
            }
            Err(e) => println!("(artifact venue skipped — {e})"),
        }
    } else {
        println!("(artifact venue skipped — run `make artifacts`)");
    }

    // Acceptance summary: the packed engine must beat the scratch engine on
    // decode rows/s at the paper's D=2048 geometry by ≥ 1.3x.
    let packed_ok = match (
        sample(&samples, "host/fft-packed", 2048),
        sample(&samples, "host/fft-scratch", 2048),
    ) {
        (Some(p), Some(s)) => {
            let dec_x = p.decode_rows_per_s / s.decode_rows_per_s.max(1e-12);
            let enc_x = p.encode_rows_per_s / s.encode_rows_per_s.max(1e-12);
            println!(
                "\nspeedup @D=2048: fft-packed {dec_x:.2}x decode rows/s, {enc_x:.2}x \
                 encode rows/s over fft-scratch (floor: 1.30x decode)"
            );
            dec_x >= 1.3
        }
        _ => false,
    };

    // SIMD acceptance: the detected kernel set must beat the forced-scalar
    // packed venue on decode rows/s at D=2048 by ≥ 2x — but only where a
    // vector ISA actually exists; on scalar-only hosts the two venues are
    // the same code and the ratio is ~1x by construction.
    let simd_ok = match (
        sample(&samples, "host/fft-simd", 2048),
        sample(&samples, "host/fft-packed", 2048),
    ) {
        (Some(v), Some(p)) => {
            let dec_x = v.decode_rows_per_s / p.decode_rows_per_s.max(1e-12);
            let enc_x = v.encode_rows_per_s / p.encode_rows_per_s.max(1e-12);
            println!(
                "speedup @D=2048: fft-simd ({}) {dec_x:.2}x decode rows/s, {enc_x:.2}x \
                 encode rows/s over forced-scalar fft-packed (floor: 2.00x decode \
                 where a vector ISA is detected)",
                simd_isa.name()
            );
            dec_x >= 2.0
        }
        _ => false,
    };

    println!("\nreading: fft wins past D≈512; the scratch engine removes every per-group");
    println!("allocation (bit-identical to host/fft), and the packed engine halves the");
    println!("butterfly work per row — N/2-point forward transforms, half-size key");
    println!("spectra, decode inverses paired two-rows-per-transform (tolerance-equal;");
    println!("see the packed parity tests in hdc).  fft-simd runs the same packed");
    println!("engine through the runtime-detected kernel set (AVX2+FMA / NEON) — the");
    println!("pointwise bind/unbind multiplies and butterfly inner loops vectorized,");
    println!("scalar bit-identical fallback everywhere else.  The artifact venue pays PJRT");
    println!("dispatch + interpret-mode Pallas gather cost — acceptable off the edge");
    println!("hot path, hence the coordinator defaults the HOST venue for decode.");

    if let Some(path) = &json_path {
        let json = samples_to_json(&samples, b, r, quick);
        std::fs::write(path, json.to_string() + "\n").expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    if let Some(path) = &gate_path {
        let text = std::fs::read_to_string(path).expect("reading bench baseline");
        let baseline = c3sl::util::json::parse(&text).expect("parsing bench baseline");
        let calibrated = c3sl::util::bench::calibrated(&baseline);
        let mut failures = gate_failures(&samples, &baseline, gate_tol);
        if !packed_ok {
            let msg = "host/fft-packed decode rows/s below the 1.3x floor over \
                       host/fft-scratch at D=2048";
            if calibrated {
                failures.push(msg.into());
            } else {
                // a threshold that has never been measured on this hardware
                // class must not block unrelated work: warn loudly until a
                // calibrated baseline (which arms all throughput checks,
                // this floor included) is committed
                println!("bench-gate WARNING (uncalibrated baseline, not fatal): {msg}");
            }
        }
        if !simd_ok {
            // the 2x SIMD floor arms only when (a) the committed baseline's
            // host/fft-simd cells have been measured at least once (non-zero
            // decode cell at D=2048), (b) the baseline is calibrated, and
            // (c) this host actually detected a vector ISA — otherwise warn
            // loudly instead of blocking merges on hardware that cannot pass
            let baseline_simd_measured = baseline
                .get("venues")
                .and_then(|v| v.get("host/fft-simd"))
                .and_then(|v| v.get("2048"))
                .and_then(|v| v.get("decode_rows_per_s"))
                .and_then(|v| v.as_f64())
                .is_some_and(|v| v > 0.0);
            let msg = format!(
                "host/fft-simd decode rows/s below the 2x floor over forced-scalar \
                 host/fft-packed at D=2048 (detected isa: {})",
                simd_isa.name()
            );
            if calibrated && baseline_simd_measured && simd_isa != Isa::Scalar {
                failures.push(msg);
            } else {
                println!(
                    "bench-gate WARNING (simd floor unarmed — calibrated={calibrated} \
                     baseline_simd_measured={baseline_simd_measured} isa={}, not \
                     fatal): {msg}",
                    simd_isa.name()
                );
            }
        }
        if failures.is_empty() {
            println!("bench-gate: PASS ({} venue cells checked)", samples.len());
        } else {
            eprintln!("bench-gate: FAIL");
            for f in &failures {
                eprintln!("  - {f}");
            }
            std::process::exit(1);
        }
    }
}
