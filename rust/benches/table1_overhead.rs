//! Bench: measured codec runtime at the paper's exact operating points — the
//! wall-clock realization of Table 1's FLOPs column.
//!
//!   cargo bench --bench table1_overhead
//!
//! Measures encode+decode time per batch for the host C3 codec (direct and
//! FFT backends) at (B=64, D=2048) and (B=64, D=4096), R ∈ {2,4,8,16}, and
//! reports effective GFLOP/s against the paper's 2BD² direct-convolution
//! FLOP count.  (The AOT/Pallas venue is exercised in codec_hotpath.)

use c3sl::flops::{c3sl_cost, CutSpec};
use c3sl::hdc::{Backend, KeySet, C3};
use c3sl::tensor::Tensor;
use c3sl::util::rng::Rng;
use c3sl::util::timer::{bench, fmt_secs};

fn main() {
    let quick = std::env::var("C3SL_BENCH_QUICK").is_ok();
    let iters = if quick { 2 } else { 5 };
    println!("# Codec overhead at the paper's operating points ({iters} iters)\n");

    for (label, spec) in [
        ("VGG-16 cut: B=64 D=2048", CutSpec::vgg16_cifar10()),
        ("ResNet-50 cut: B=64 D=4096", CutSpec::resnet50_cifar100()),
    ] {
        println!("== {label}");
        println!(
            "{:>4} {:>8} | {:>12} {:>12} {:>14} | {:>12}",
            "R", "backend", "encode", "decode", "2BD² GFLOP/s", "paper GF"
        );
        let d = spec.d();
        let b = spec.b;
        let mut rng = Rng::new(42);
        let mut zdata = vec![0.0f32; b * d];
        rng.fill_normal(&mut zdata, 0.0, 1.0);
        let z = Tensor::from_vec(&[b, d], zdata);

        for r in [2usize, 4, 8, 16] {
            let flops = c3sl_cost(&spec, r).flops as f64;
            for backend in [Backend::Direct, Backend::Fft] {
                // Direct at D=4096 is slow; keep iters small there.
                let it = if backend == Backend::Direct && d >= 4096 {
                    1.max(iters / 4)
                } else {
                    iters
                };
                let keys = KeySet::generate(&mut rng, r, d);
                let c3 = C3::new(keys, backend);
                let enc = bench(1, it, || c3.encode(&z));
                let s = c3.encode(&z);
                let dec = bench(1, it, || c3.decode(&s));
                let gflops = flops / (enc.mean_s + dec.mean_s) / 1e9;
                println!(
                    "{:>4} {:>8} | {:>12} {:>12} {:>14.2} | {:>12.2}",
                    r,
                    format!("{backend:?}"),
                    fmt_secs(enc.mean_s),
                    fmt_secs(dec.mean_s),
                    gflops,
                    flops / 1e9,
                );
            }
        }
        println!();
    }
    println!("note: the paper counts 2BD² (direct form); the FFT backend does the same");
    println!("      math in O(BD log D), so its \"effective\" GFLOP/s exceeds the hardware");
    println!("      peak — that gap IS the algorithmic speedup of the convolution theorem.");
}
