//! Typed experiment configuration.
//!
//! A config file (TOML subset, see `toml.rs`) fully describes one training
//! run: model artifacts, compression scheme, optimizer, dataset, transport,
//! and link model.  `ExperimentConfig::load` validates everything up front
//! so the coordinator never hits a half-configured state.

pub mod cli;
pub mod toml;

use crate::fft::kernels::Isa;
use crate::hdc::FftBackend;
use crate::transport::readiness::ReadinessBackend;
use crate::transport::sim::LinkModel;
use toml::{Doc, Value};

/// Which compression scheme the run trains with (`[scheme] kind`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// Vanilla SL: identity codec.
    Vanilla,
    /// C3-SL batch-wise codec with ratio R.
    C3 {
        /// Compression ratio: features folded per carrier.
        r: usize,
    },
    /// BottleNet++ (codec lives inside the model artifacts).
    BottleNetPP {
        /// Compression ratio of the bottleneck encoder/decoder pair.
        r: usize,
    },
}

impl SchemeKind {
    /// Stable name used in output paths and run summaries
    /// (e.g. `"c3-r4"`).
    pub fn name(&self) -> String {
        match self {
            SchemeKind::Vanilla => "vanilla".into(),
            SchemeKind::C3 { r } => format!("c3-r{r}"),
            SchemeKind::BottleNetPP { r } => format!("bnpp-r{r}"),
        }
    }

    /// The scheme's compression ratio R (1 for vanilla).
    pub fn ratio(&self) -> usize {
        match self {
            SchemeKind::Vanilla => 1,
            SchemeKind::C3 { r } | SchemeKind::BottleNetPP { r } => *r,
        }
    }
}

/// Which link substrate connects edge and cloud (`[transport] kind`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels carrying serialized frames (one process,
    /// two actors; byte accounting still measures real serialized bytes).
    InProc,
    /// TCP sockets (separate processes or the multi-edge localhost venue).
    Tcp,
}

/// C3 codec execution venue (`[scheme] venue`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecVenue {
    /// rust-native hdc implementation (FFT or direct).
    Host,
    /// AOT artifacts (the Pallas kernels) through PJRT.
    Artifact,
}

/// Edge retry/backoff and cloud-side session deadlines (`[resilience]`).
///
/// Millisecond knobs use 0 = disabled.  The retry path (multi-edge TCP venue
/// only) turns a mid-stream disconnect into backoff → reconnect →
/// `Msg::Resume` instead of a failed run; the cloud-side deadlines reap
/// stalled clients (connected but never handshaking, or gone quiet
/// mid-session) so their accept slot and shard claim come back.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResilienceConfig {
    /// Enable edge-side reconnect + session resumption (`retry = true`).
    /// Requires `scheme.key_sharding` and the TCP transport: resumption
    /// re-proves shard possession over every fresh connection.
    pub retry: bool,
    /// Consecutive failed attempts tolerated before an edge gives up
    /// (progress resets the counter).
    pub retry_max_attempts: u32,
    /// First backoff sleep in milliseconds; doubles per consecutive failure.
    pub retry_base_ms: u64,
    /// Backoff ceiling in milliseconds.
    pub retry_max_ms: u64,
    /// Jitter fraction in `[0, 1]`: each sleep is scaled by a factor drawn
    /// uniformly from `[1-j, 1+j]` (deterministic, seeded from the run seed).
    pub retry_jitter: f64,
    /// Bound on each TCP connect attempt, in milliseconds (0 = a generous
    /// built-in bound).
    pub connect_timeout_ms: u64,
    /// Edge-side read/write deadline on the session socket, in milliseconds
    /// (0 = none): a cloud gone quiet past this is retried as a dead link.
    pub io_timeout_ms: u64,
    /// Cloud-side deadline for a connected client to complete its handshake,
    /// in milliseconds (0 = none): never-handshaking clients are reaped and
    /// their accept slot reused.
    pub handshake_timeout_ms: u64,
    /// Cloud-side idle deadline between data frames of an admitted session,
    /// in milliseconds (0 = none): a stalled edge is reaped and its shard
    /// claim released for resumption.
    pub idle_timeout_ms: u64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            retry: false,
            retry_max_attempts: 5,
            retry_base_ms: 100,
            retry_max_ms: 5_000,
            retry_jitter: 0.2,
            connect_timeout_ms: 5_000,
            io_timeout_ms: 30_000,
            handshake_timeout_ms: 10_000,
            idle_timeout_ms: 60_000,
        }
    }
}

impl ResilienceConfig {
    /// `handshake_timeout_ms` as an `Option<Duration>` (0 = none).
    pub fn handshake_deadline(&self) -> Option<std::time::Duration> {
        (self.handshake_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(self.handshake_timeout_ms))
    }

    /// `idle_timeout_ms` as an `Option<Duration>` (0 = none).
    pub fn idle_deadline(&self) -> Option<std::time::Duration> {
        (self.idle_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(self.idle_timeout_ms))
    }
}

/// Everything one training run needs, fully validated
/// ([`ExperimentConfig::validate`]) before any actor starts.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Run name, used in output file names and summaries.
    pub name: String,
    /// Artifact directory key, e.g. "vggt_b32" (see python/compile/model.py).
    pub model_key: String,
    /// Root directory holding the AOT model/codec artifacts.
    pub artifacts_root: String,
    /// Compression scheme to train with.
    pub scheme: SchemeKind,
    /// Where the C3 codec math runs (host engine or AOT artifacts).
    pub codec_venue: CodecVenue,
    /// Worker threads for group-parallel host codec encode/decode.
    pub codec_workers: usize,
    /// FFT kernel family for the host codec: `"packed"` (half-spectrum real
    /// transforms — the default; faster, tolerance-equal, safe fallbacks at
    /// degenerate D) or `"reference"` (full-spectrum, bit-identical to the
    /// seed kernels).
    pub fft_backend: FftBackend,
    /// Pinned SIMD kernel set for the packed host codec (`[scheme] simd`):
    /// `"scalar"`, `"avx2"` or `"neon"`.  `None` (the default) auto-detects
    /// the widest available ISA at engine build, same as the `C3SL_SIMD`
    /// environment knob; pinning an ISA the host lacks fails loudly.
    pub simd: Option<Isa>,
    /// Derive a per-client key shard for every edge (multi-edge scenarios)
    /// instead of one global key set, so a compromised edge cannot decode
    /// any other edge's uplink.
    pub key_sharding: bool,
    /// Rotate every key shard to a fresh epoch each N training steps
    /// (0 = never; requires `key_sharding`).
    pub rotation_steps: u64,
    /// Link substrate between edge and cloud.
    pub transport: TransportKind,
    /// Listen/connect address for the TCP transport.
    pub tcp_addr: String,
    /// Concurrent edge clients the cloud accepts (multi-edge scenarios).
    pub num_edges: usize,
    /// Serve multi-edge clients from the nonblocking reactor (one I/O
    /// thread + a codec worker pool) instead of thread-per-client.
    pub reactor: bool,
    /// Reactor readiness backend: `"epoll"` (event-driven, Linux default)
    /// or `"sweep"` (portable timed poll sweep).
    pub reactor_backend: ReadinessBackend,
    /// Reactor idle poll backoff in microseconds (sweep backend only; the
    /// epoll backend blocks in `epoll_wait` instead).
    pub reactor_poll_us: u64,
    /// Reactor per-client outbox bound in frames (read backpressure).
    pub reactor_outbox: usize,
    /// Optional virtual link cost model (latency + bandwidth) applied on
    /// the edge side for communication-cost accounting.
    pub link: Option<LinkModel>,
    /// Bind address for the plaintext ops control plane (`[ops] addr`):
    /// `/metrics`, `/healthz`, `POST /drain` served off the reactor's own
    /// readiness loop.  Requires `transport.reactor = true`; `None` disables
    /// the endpoint.
    pub ops_addr: Option<String>,
    /// Edge retry/backoff + cloud deadline knobs (`[resilience]`).
    pub resilience: ResilienceConfig,

    // training
    /// Training steps to run.
    pub steps: usize,
    /// Learning rate (paper §4.1 default).
    pub lr: f32,
    /// Base seed: keys, data order and init all derive from it.
    pub seed: u64,
    /// Enable train-time data augmentation.
    pub augment: bool,
    /// Evaluate every N training steps.
    pub eval_every: usize,
    /// Batches per evaluation pass.
    pub eval_batches: usize,

    // data
    /// Dataset root directory (CIFAR binaries, or synth fallback).
    pub data_root: String,
    /// Synthetic-dataset training examples when no real data is present.
    pub synth_train: usize,
    /// Synthetic-dataset test examples when no real data is present.
    pub synth_test: usize,

    // output
    /// Directory run records (CSV curves) are written to.
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            model_key: "vggt_b32".into(),
            artifacts_root: "artifacts".into(),
            scheme: SchemeKind::C3 { r: 4 },
            codec_venue: CodecVenue::Artifact,
            codec_workers: 1,
            // the packed half-spectrum kernels are the experiment-level
            // default (bench-gate trajectory, ROADMAP follow-up from the
            // packed-FFT PR); `reference` remains available as the
            // bit-identical seed-kernel family
            fft_backend: FftBackend::Packed,
            simd: None,
            key_sharding: false,
            rotation_steps: 0,
            transport: TransportKind::InProc,
            tcp_addr: "127.0.0.1:7070".into(),
            num_edges: 1,
            reactor: false,
            reactor_backend: ReadinessBackend::platform_default(),
            reactor_poll_us: 100,
            reactor_outbox: 8,
            link: None,
            ops_addr: None,
            resilience: ResilienceConfig::default(),
            steps: 200,
            lr: 1e-4, // paper §4.1
            seed: 0,
            augment: false,
            eval_every: 50,
            eval_batches: 4,
            data_root: "data".into(),
            synth_train: 4096,
            synth_test: 1024,
            out_dir: "runs".into(),
        }
    }
}

/// Anything that can go wrong loading or validating a config.
#[derive(Debug)]
pub enum ConfigError {
    /// The file is not valid (subset-)TOML.
    Toml(toml::TomlError),
    /// The file could not be read.
    Io(std::io::Error),
    /// The file parsed but a value is out of range / the wrong type / an
    /// inconsistent combination.
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Toml(e) => write!(f, "toml: {e}"),
            ConfigError::Io(e) => write!(f, "io: {e}"),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Toml(e) => Some(e),
            ConfigError::Io(e) => Some(e),
            ConfigError::Invalid(_) => None,
        }
    }
}

impl From<toml::TomlError> for ConfigError {
    fn from(e: toml::TomlError) -> Self {
        ConfigError::Toml(e)
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl From<ConfigError> for crate::util::error::C3Error {
    fn from(e: ConfigError) -> Self {
        Self::msg(e.to_string())
    }
}

fn get<'a>(doc: &'a Doc, section: &str, key: &str) -> Option<&'a Value> {
    doc.get(section).and_then(|s| s.get(key))
}

impl ExperimentConfig {
    /// Parse a config from TOML text, filling unspecified keys from the
    /// defaults and validating the result.
    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        let doc = toml::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        let inv = |m: String| ConfigError::Invalid(m);

        if let Some(v) = get(&doc, "", "name") {
            cfg.name = v.as_str().ok_or_else(|| inv("name must be string".into()))?.into();
        }
        if let Some(v) = get(&doc, "model", "key") {
            cfg.model_key = v.as_str().ok_or_else(|| inv("model.key".into()))?.into();
        }
        if let Some(v) = get(&doc, "model", "artifacts_root") {
            cfg.artifacts_root = v.as_str().ok_or_else(|| inv("model.artifacts_root".into()))?.into();
        }
        if let Some(v) = get(&doc, "scheme", "kind") {
            let r = get(&doc, "scheme", "r").and_then(|v| v.as_i64()).unwrap_or(4) as usize;
            cfg.scheme = match v.as_str() {
                Some("vanilla") => SchemeKind::Vanilla,
                Some("c3") => SchemeKind::C3 { r },
                Some("bnpp") | Some("bottlenetpp") => SchemeKind::BottleNetPP { r },
                other => return Err(inv(format!("scheme.kind: {other:?}"))),
            };
        }
        if let Some(v) = get(&doc, "scheme", "venue") {
            cfg.codec_venue = match v.as_str() {
                Some("host") => CodecVenue::Host,
                Some("artifact") => CodecVenue::Artifact,
                other => return Err(inv(format!("scheme.venue: {other:?}"))),
            };
        }
        if let Some(v) = get(&doc, "scheme", "workers") {
            let w = v.as_i64().ok_or_else(|| inv("scheme.workers".into()))?;
            if w < 1 {
                return Err(inv(format!("scheme.workers must be >= 1, got {w}")));
            }
            cfg.codec_workers = w as usize;
        }
        if let Some(v) = get(&doc, "scheme", "fft_backend") {
            let s = v.as_str().ok_or_else(|| inv("scheme.fft_backend".into()))?;
            cfg.fft_backend = FftBackend::parse(s).ok_or_else(|| {
                inv(format!(
                    "scheme.fft_backend must be \"packed\" or \"reference\", got {s:?}"
                ))
            })?;
        }
        if let Some(v) = get(&doc, "scheme", "simd") {
            let s = v.as_str().ok_or_else(|| inv("scheme.simd".into()))?;
            cfg.simd = Some(Isa::parse(s).ok_or_else(|| {
                inv(format!(
                    "scheme.simd must be \"scalar\", \"avx2\" or \"neon\", got {s:?}"
                ))
            })?);
        }
        if let Some(v) = get(&doc, "scheme", "key_sharding") {
            cfg.key_sharding = v.as_bool().ok_or_else(|| inv("scheme.key_sharding".into()))?;
        }
        if let Some(v) = get(&doc, "scheme", "rotation_steps") {
            let n = v.as_i64().ok_or_else(|| inv("scheme.rotation_steps".into()))?;
            if n < 0 {
                return Err(inv(format!("scheme.rotation_steps must be >= 0, got {n}")));
            }
            cfg.rotation_steps = n as u64;
        }
        if let Some(v) = get(&doc, "transport", "edges") {
            let n = v.as_i64().ok_or_else(|| inv("transport.edges".into()))?;
            if n < 1 {
                return Err(inv(format!("transport.edges must be >= 1, got {n}")));
            }
            cfg.num_edges = n as usize;
        }
        if let Some(v) = get(&doc, "transport", "kind") {
            cfg.transport = match v.as_str() {
                Some("inproc") => TransportKind::InProc,
                Some("tcp") => TransportKind::Tcp,
                other => return Err(inv(format!("transport.kind: {other:?}"))),
            };
        }
        if let Some(v) = get(&doc, "transport", "addr") {
            cfg.tcp_addr = v.as_str().ok_or_else(|| inv("transport.addr".into()))?.into();
        }
        if let Some(v) = get(&doc, "transport", "reactor") {
            cfg.reactor = v.as_bool().ok_or_else(|| inv("transport.reactor".into()))?;
        }
        if let Some(v) = get(&doc, "transport", "backend") {
            let s = v.as_str().ok_or_else(|| inv("transport.backend".into()))?;
            cfg.reactor_backend = ReadinessBackend::parse(s).ok_or_else(|| {
                inv(format!(
                    "transport.backend must be \"epoll\" or \"sweep\", got {s:?}"
                ))
            })?;
        }
        if let Some(v) = get(&doc, "transport", "poll_us") {
            let us = v.as_i64().ok_or_else(|| inv("transport.poll_us".into()))?;
            if us < 0 {
                return Err(inv(format!("transport.poll_us must be >= 0, got {us}")));
            }
            cfg.reactor_poll_us = us as u64;
        }
        if let Some(v) = get(&doc, "transport", "outbox_frames") {
            let fr = v.as_i64().ok_or_else(|| inv("transport.outbox_frames".into()))?;
            if fr < 1 {
                return Err(inv(format!("transport.outbox_frames must be >= 1, got {fr}")));
            }
            cfg.reactor_outbox = fr as usize;
        }
        if let Some(v) = get(&doc, "ops", "addr") {
            cfg.ops_addr = Some(v.as_str().ok_or_else(|| inv("ops.addr".into()))?.into());
        }
        if let Some(v) = get(&doc, "resilience", "retry") {
            cfg.resilience.retry =
                v.as_bool().ok_or_else(|| inv("resilience.retry".into()))?;
        }
        if let Some(v) = get(&doc, "resilience", "retry_max_attempts") {
            let n = v.as_i64().ok_or_else(|| inv("resilience.retry_max_attempts".into()))?;
            if n < 1 {
                return Err(inv(format!(
                    "resilience.retry_max_attempts must be >= 1, got {n}"
                )));
            }
            cfg.resilience.retry_max_attempts = n as u32;
        }
        for (key, field) in [
            ("retry_base_ms", &mut cfg.resilience.retry_base_ms as *mut u64),
            ("retry_max_ms", &mut cfg.resilience.retry_max_ms as *mut u64),
            ("connect_timeout_ms", &mut cfg.resilience.connect_timeout_ms as *mut u64),
            ("io_timeout_ms", &mut cfg.resilience.io_timeout_ms as *mut u64),
            ("handshake_timeout_ms", &mut cfg.resilience.handshake_timeout_ms as *mut u64),
            ("idle_timeout_ms", &mut cfg.resilience.idle_timeout_ms as *mut u64),
        ] {
            if let Some(v) = get(&doc, "resilience", key) {
                let ms = v.as_i64().ok_or_else(|| inv(format!("resilience.{key}")))?;
                if ms < 0 {
                    return Err(inv(format!("resilience.{key} must be >= 0, got {ms}")));
                }
                // SAFETY: each pointer was taken from a distinct live field
                // of `cfg` just above, `cfg` outlives the loop, and no other
                // reference to those fields exists while we write.
                unsafe { *field = ms as u64 };
            }
        }
        if let Some(v) = get(&doc, "resilience", "retry_jitter") {
            let j = v.as_f64().ok_or_else(|| inv("resilience.retry_jitter".into()))?;
            if !(0.0..=1.0).contains(&j) {
                return Err(inv(format!(
                    "resilience.retry_jitter must be in [0, 1], got {j}"
                )));
            }
            cfg.resilience.retry_jitter = j;
        }
        if let (Some(lat), Some(bw)) = (
            get(&doc, "link", "latency_ms").and_then(|v| v.as_f64()),
            get(&doc, "link", "bandwidth_mbps").and_then(|v| v.as_f64()),
        ) {
            cfg.link = Some(LinkModel::new(lat / 1e3, bw * 1e6 / 8.0));
        }
        for (key, field) in [
            ("steps", &mut cfg.steps as *mut usize),
            ("eval_every", &mut cfg.eval_every as *mut usize),
            ("eval_batches", &mut cfg.eval_batches as *mut usize),
        ] {
            if let Some(v) = get(&doc, "train", key) {
                let val = v.as_i64().ok_or_else(|| inv(format!("train.{key}")))? as usize;
                // SAFETY: each pointer was taken from a distinct live field
                // of `cfg` just above, `cfg` outlives the loop, and no other
                // reference to those fields exists while we write.
                unsafe { *field = val };
            }
        }
        if let Some(v) = get(&doc, "train", "lr") {
            cfg.lr = v.as_f64().ok_or_else(|| inv("train.lr".into()))? as f32;
        }
        if let Some(v) = get(&doc, "train", "seed") {
            cfg.seed = v.as_i64().ok_or_else(|| inv("train.seed".into()))? as u64;
        }
        if let Some(v) = get(&doc, "train", "augment") {
            cfg.augment = v.as_bool().ok_or_else(|| inv("train.augment".into()))?;
        }
        if let Some(v) = get(&doc, "data", "root") {
            cfg.data_root = v.as_str().ok_or_else(|| inv("data.root".into()))?.into();
        }
        if let Some(v) = get(&doc, "data", "synth_train") {
            cfg.synth_train = v.as_i64().ok_or_else(|| inv("data.synth_train".into()))? as usize;
        }
        if let Some(v) = get(&doc, "data", "synth_test") {
            cfg.synth_test = v.as_i64().ok_or_else(|| inv("data.synth_test".into()))? as usize;
        }
        if let Some(v) = get(&doc, "out", "dir") {
            cfg.out_dir = v.as_str().ok_or_else(|| inv("out.dir".into()))?.into();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load and validate a config file ([`ExperimentConfig::from_toml_str`]).
    pub fn load(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }

    /// Cross-field validation: ranges, required combinations, and
    /// platform-dependent knobs — everything that would otherwise surface
    /// mid-run as a hang or a confusing downstream error.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let r = self.scheme.ratio();
        if r == 0 || (r & (r - 1)) != 0 && r % 2 != 0 {
            return Err(ConfigError::Invalid(format!("ratio {r} must be even")));
        }
        if self.steps == 0 {
            return Err(ConfigError::Invalid("steps must be > 0".into()));
        }
        if self.lr <= 0.0 {
            return Err(ConfigError::Invalid("lr must be > 0".into()));
        }
        if self.codec_workers == 0 {
            return Err(ConfigError::Invalid("scheme.workers must be >= 1".into()));
        }
        if self.num_edges == 0 {
            return Err(ConfigError::Invalid("transport.edges must be >= 1".into()));
        }
        if self.reactor_outbox == 0 {
            return Err(ConfigError::Invalid(
                "transport.outbox_frames must be >= 1".into(),
            ));
        }
        if !self.reactor_backend.supported() {
            return Err(ConfigError::Invalid(format!(
                "transport.backend = \"{}\" is not supported on this platform \
                 (use \"sweep\")",
                self.reactor_backend.name()
            )));
        }
        if let Some(addr) = &self.ops_addr {
            if addr.parse::<std::net::SocketAddr>().is_err() {
                return Err(ConfigError::Invalid(format!(
                    "ops.addr must be a host:port socket address, got {addr:?}"
                )));
            }
            if !self.reactor {
                return Err(ConfigError::Invalid(
                    "ops.addr requires transport.reactor = true — the ops \
                     control plane is served from the reactor's readiness loop"
                        .into(),
                ));
            }
        }
        if let Some(isa) = self.simd {
            if !isa.available() {
                return Err(ConfigError::Invalid(format!(
                    "scheme.simd = \"{}\" is not available on this host \
                     (use \"scalar\", or drop the knob to auto-detect)",
                    isa.name()
                )));
            }
        }
        if self.resilience.retry_base_ms == 0 {
            return Err(ConfigError::Invalid(
                "resilience.retry_base_ms must be >= 1".into(),
            ));
        }
        if self.resilience.retry_max_ms < self.resilience.retry_base_ms {
            return Err(ConfigError::Invalid(format!(
                "resilience.retry_max_ms ({}) must be >= retry_base_ms ({})",
                self.resilience.retry_max_ms, self.resilience.retry_base_ms
            )));
        }
        if self.resilience.retry {
            if !self.key_sharding {
                return Err(ConfigError::Invalid(
                    "resilience.retry requires scheme.key_sharding = true — \
                     session resumption re-proves shard possession over every \
                     fresh connection"
                        .into(),
                ));
            }
            if self.transport != TransportKind::Tcp {
                return Err(ConfigError::Invalid(
                    "resilience.retry requires transport.kind = \"tcp\" — an \
                     in-proc channel cannot be redialed"
                        .into(),
                ));
            }
        }
        if self.rotation_steps > 0 && !self.key_sharding {
            return Err(ConfigError::Invalid(
                "scheme.rotation_steps requires scheme.key_sharding = true".into(),
            ));
        }
        if matches!(self.scheme, SchemeKind::BottleNetPP { .. })
            && self.codec_venue == CodecVenue::Host
        {
            return Err(ConfigError::Invalid(
                "BottleNet++ has no host codec — its codec lives in the model artifacts".into(),
            ));
        }
        Ok(())
    }

    /// Artifact directory for the model.
    pub fn model_dir(&self) -> String {
        match self.scheme {
            SchemeKind::BottleNetPP { r } => {
                format!("{}/{}_bnpp_r{}", self.artifacts_root, self.model_key, r)
            }
            _ => format!("{}/{}", self.artifacts_root, self.model_key),
        }
    }

    /// Codec artifact directory (C3 only).
    pub fn codec_dir(&self) -> Option<String> {
        match self.scheme {
            SchemeKind::C3 { r } => {
                Some(format!("{}/{}/codec_c3_r{}", self.artifacts_root, self.model_key, r))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        name = "tiny-c3-r4"
        [model]
        key = "vggt_b32"
        artifacts_root = "artifacts"
        [scheme]
        kind = "c3"
        r = 4
        venue = "artifact"
        [transport]
        kind = "inproc"
        [train]
        steps = 100
        lr = 0.0001
        seed = 7
        [link]
        latency_ms = 2.0
        bandwidth_mbps = 50.0
    "#;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.name, "tiny-c3-r4");
        assert_eq!(cfg.scheme, SchemeKind::C3 { r: 4 });
        assert_eq!(cfg.steps, 100);
        assert_eq!(cfg.seed, 7);
        assert!(cfg.link.is_some());
        assert_eq!(cfg.codec_dir().unwrap(), "artifacts/vggt_b32/codec_c3_r4");
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let cfg = ExperimentConfig::from_toml_str("name = \"x\"\n").unwrap();
        assert_eq!(cfg.lr, 1e-4);
        assert_eq!(cfg.transport, TransportKind::InProc);
    }

    #[test]
    fn bnpp_model_dir_is_suffixed() {
        let cfg = ExperimentConfig::from_toml_str(
            "[scheme]\nkind = \"bnpp\"\nr = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.model_dir(), "artifacts/vggt_b32_bnpp_r8");
        assert!(cfg.codec_dir().is_none());
    }

    #[test]
    fn parses_workers_and_edges() {
        let cfg = ExperimentConfig::from_toml_str(
            "[scheme]\nkind = \"c3\"\nworkers = 4\n[transport]\nedges = 3\n",
        )
        .unwrap();
        assert_eq!(cfg.codec_workers, 4);
        assert_eq!(cfg.num_edges, 3);
        // defaults are serial single-edge
        let d = ExperimentConfig::default();
        assert_eq!(d.codec_workers, 1);
        assert_eq!(d.num_edges, 1);
    }

    #[test]
    fn rejects_zero_or_negative_workers_or_edges() {
        assert!(ExperimentConfig::from_toml_str("[scheme]\nworkers = 0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[transport]\nedges = 0\n").is_err());
        // negative values must not wrap through the i64 → usize cast
        assert!(ExperimentConfig::from_toml_str("[scheme]\nworkers = -1\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[transport]\nedges = -3\n").is_err());
    }

    #[test]
    fn parses_reactor_knobs() {
        let cfg = ExperimentConfig::from_toml_str(
            "[transport]\nreactor = true\npoll_us = 250\noutbox_frames = 16\n",
        )
        .unwrap();
        assert!(cfg.reactor);
        assert_eq!(cfg.reactor_poll_us, 250);
        assert_eq!(cfg.reactor_outbox, 16);
        // defaults: thread-per-client serving
        let d = ExperimentConfig::default();
        assert!(!d.reactor);
        assert_eq!(d.reactor_outbox, 8);
        // bounds
        assert!(ExperimentConfig::from_toml_str("[transport]\noutbox_frames = 0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[transport]\npoll_us = -5\n").is_err());
    }

    #[test]
    fn parses_readiness_backend_knob() {
        // the portable value parses everywhere
        let cfg = ExperimentConfig::from_toml_str("[transport]\nbackend = \"sweep\"\n").unwrap();
        assert_eq!(cfg.reactor_backend, ReadinessBackend::Sweep);
        // the default is the platform default (epoll on Linux)
        assert_eq!(
            ExperimentConfig::default().reactor_backend,
            ReadinessBackend::platform_default()
        );
        // explicit epoll: accepted exactly where it can actually run,
        // rejected loudly (not silently downgraded) elsewhere
        let r = ExperimentConfig::from_toml_str("[transport]\nbackend = \"epoll\"\n");
        if ReadinessBackend::Epoll.supported() {
            assert_eq!(r.unwrap().reactor_backend, ReadinessBackend::Epoll);
        } else {
            assert!(r.is_err());
        }
        // unknown values are rejected loudly, never silently defaulted
        assert!(ExperimentConfig::from_toml_str("[transport]\nbackend = \"magic\"\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[transport]\nbackend = 3\n").is_err());
    }

    #[test]
    fn parses_key_sharding_knobs() {
        let cfg = ExperimentConfig::from_toml_str(
            "[scheme]\nkind = \"c3\"\nkey_sharding = true\nrotation_steps = 50\n",
        )
        .unwrap();
        assert!(cfg.key_sharding);
        assert_eq!(cfg.rotation_steps, 50);
        // defaults: one global key set, never rotated
        let d = ExperimentConfig::default();
        assert!(!d.key_sharding);
        assert_eq!(d.rotation_steps, 0);
        // rotation without sharding is rejected (there is nothing to rotate)
        assert!(ExperimentConfig::from_toml_str("[scheme]\nrotation_steps = 10\n").is_err());
        // negative cadence must not wrap through the i64 → u64 cast
        assert!(ExperimentConfig::from_toml_str(
            "[scheme]\nkey_sharding = true\nrotation_steps = -5\n"
        )
        .is_err());
        // sharding with rotation disabled is fine
        assert!(ExperimentConfig::from_toml_str("[scheme]\nkey_sharding = true\n").is_ok());
    }

    #[test]
    fn parses_ops_addr_knob() {
        let cfg = ExperimentConfig::from_toml_str(
            "[transport]\nreactor = true\n[ops]\naddr = \"127.0.0.1:9100\"\n",
        )
        .unwrap();
        assert_eq!(cfg.ops_addr.as_deref(), Some("127.0.0.1:9100"));
        // default: no ops endpoint
        assert!(ExperimentConfig::default().ops_addr.is_none());
        // the ops plane rides the reactor loop — blocking serving has none
        assert!(ExperimentConfig::from_toml_str("[ops]\naddr = \"127.0.0.1:9100\"\n").is_err());
        // unparseable socket addresses are rejected loudly at load time
        assert!(ExperimentConfig::from_toml_str(
            "[transport]\nreactor = true\n[ops]\naddr = \"not-an-addr\"\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str(
            "[transport]\nreactor = true\n[ops]\naddr = 9100\n"
        )
        .is_err());
    }

    #[test]
    fn parses_resilience_knobs() {
        let cfg = ExperimentConfig::from_toml_str(
            "[scheme]\nkey_sharding = true\n[transport]\nkind = \"tcp\"\n\
             [resilience]\nretry = true\nretry_max_attempts = 8\n\
             retry_base_ms = 50\nretry_max_ms = 2000\nretry_jitter = 0.1\n\
             connect_timeout_ms = 1000\nio_timeout_ms = 4000\n\
             handshake_timeout_ms = 500\nidle_timeout_ms = 9000\n",
        )
        .unwrap();
        assert!(cfg.resilience.retry);
        assert_eq!(cfg.resilience.retry_max_attempts, 8);
        assert_eq!(cfg.resilience.retry_base_ms, 50);
        assert_eq!(cfg.resilience.retry_max_ms, 2000);
        assert_eq!(cfg.resilience.retry_jitter, 0.1);
        assert_eq!(cfg.resilience.connect_timeout_ms, 1000);
        assert_eq!(cfg.resilience.io_timeout_ms, 4000);
        assert_eq!(
            cfg.resilience.handshake_deadline(),
            Some(std::time::Duration::from_millis(500))
        );
        assert_eq!(
            cfg.resilience.idle_deadline(),
            Some(std::time::Duration::from_millis(9000))
        );
        // defaults: retry off, generous deadlines
        let d = ExperimentConfig::default();
        assert!(!d.resilience.retry);
        assert_eq!(d.resilience.retry_max_attempts, 5);
        // 0 disables a deadline
        let cfg = ExperimentConfig::from_toml_str(
            "[resilience]\nhandshake_timeout_ms = 0\nidle_timeout_ms = 0\n",
        )
        .unwrap();
        assert!(cfg.resilience.handshake_deadline().is_none());
        assert!(cfg.resilience.idle_deadline().is_none());
    }

    #[test]
    fn rejects_incoherent_resilience_knobs() {
        // retry without key sharding: nothing to re-prove on resume
        assert!(ExperimentConfig::from_toml_str(
            "[transport]\nkind = \"tcp\"\n[resilience]\nretry = true\n"
        )
        .is_err());
        // retry over in-proc channels: nothing to redial
        assert!(ExperimentConfig::from_toml_str(
            "[scheme]\nkey_sharding = true\n[resilience]\nretry = true\n"
        )
        .is_err());
        // range checks, including negative values that must not wrap
        assert!(ExperimentConfig::from_toml_str(
            "[resilience]\nretry_max_attempts = 0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str("[resilience]\nretry_base_ms = 0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[resilience]\nio_timeout_ms = -1\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[resilience]\nretry_jitter = 1.5\n").is_err());
        assert!(
            ExperimentConfig::from_toml_str("[resilience]\nretry_jitter = -0.1\n").is_err()
        );
        // max below base would silently clamp the whole schedule
        assert!(ExperimentConfig::from_toml_str(
            "[resilience]\nretry_base_ms = 500\nretry_max_ms = 100\n"
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_scheme() {
        assert!(ExperimentConfig::from_toml_str("[scheme]\nkind = \"magic\"\n").is_err());
    }

    #[test]
    fn parses_fft_backend_knob() {
        let cfg = ExperimentConfig::from_toml_str(
            "[scheme]\nkind = \"c3\"\nfft_backend = \"packed\"\n",
        )
        .unwrap();
        assert_eq!(cfg.fft_backend, FftBackend::Packed);
        let cfg =
            ExperimentConfig::from_toml_str("[scheme]\nfft_backend = \"reference\"\n").unwrap();
        assert_eq!(cfg.fft_backend, FftBackend::Reference);
        // default: the packed half-spectrum kernels (flipped from
        // `reference` once the bench-gate trajectory recorded the win)
        assert_eq!(ExperimentConfig::default().fft_backend, FftBackend::Packed);
        // unknown values are rejected loudly, never silently defaulted
        assert!(
            ExperimentConfig::from_toml_str("[scheme]\nfft_backend = \"magic\"\n").is_err()
        );
        assert!(ExperimentConfig::from_toml_str("[scheme]\nfft_backend = 3\n").is_err());
    }

    #[test]
    fn parses_simd_knob() {
        // scalar is available on every host
        let cfg = ExperimentConfig::from_toml_str("[scheme]\nsimd = \"scalar\"\n").unwrap();
        assert_eq!(cfg.simd, Some(Isa::Scalar));
        // default: auto-detect at engine build
        assert!(ExperimentConfig::default().simd.is_none());
        // explicit vector ISAs: accepted exactly where they can actually
        // run, rejected loudly (not silently downgraded) elsewhere
        for isa in [Isa::Avx2, Isa::Neon] {
            let r = ExperimentConfig::from_toml_str(&format!(
                "[scheme]\nsimd = \"{}\"\n",
                isa.name()
            ));
            if isa.available() {
                assert_eq!(r.unwrap().simd, Some(isa));
            } else {
                assert!(r.is_err());
            }
        }
        // unknown values are rejected loudly, never silently defaulted
        assert!(ExperimentConfig::from_toml_str("[scheme]\nsimd = \"magic\"\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[scheme]\nsimd = 3\n").is_err());
    }

    #[test]
    fn rejects_zero_steps() {
        assert!(ExperimentConfig::from_toml_str("[train]\nsteps = 0\n").is_err());
    }

    #[test]
    fn rejects_bnpp_host_venue() {
        let r = ExperimentConfig::from_toml_str(
            "[scheme]\nkind = \"bnpp\"\nr = 4\nvenue = \"host\"\n",
        );
        assert!(r.is_err());
    }
}
