# Split-learning step functions — the pure-JAX functions that become HLO
# artifacts.  Every function here takes/returns FLAT tuples of arrays so the
# rust runtime can hold parameters as an opaque ordered Vec<Literal> and pass
# them positionally (manifest.json records the order/shapes/dtypes).
#
# Gradient path (paper Algorithm 1, realized distributed — DESIGN.md §1):
#   edge:  z = f_theta(x)                      [edge_fwd]
#   edge:  s = E(z, K)                         [c3_encode]       → uplink
#   cloud: ẑ = D(s, K)                         [c3_decode]
#   cloud: loss, dL/dθ_cloud, dL/dẑ            [cloud_step]
#   cloud: gs = E(dL/dẑ, K)                    [c3_encode]       → downlink
#   edge:  gz = D(gs, K)                       [c3_decode]
#   edge:  dL/dθ_edge = VJP_{f_theta}(x, gz)   [edge_bwd]
# Because decode = encodeᵀ, the distributed gz equals the single-process
# autograd gradient exactly (verified in tests/test_split.py).

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from . import nn
from .kernels import circconv, ref


# ---------------------------------------------------------------------------
# Flat-params plumbing
# ---------------------------------------------------------------------------

def flatten_spec(params):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return leaves, treedef


def make_init(net: nn.Layer, in_shape):
    """(seed u32[2]) → flat param leaves."""

    def init_fn(seed):
        params, _ = net.init(jax.random.wrap_key_data(seed, impl="threefry2x32"), in_shape)
        return tuple(jax.tree_util.tree_leaves(params))

    return init_fn


def _unflatten(treedef, leaves):
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def xent_and_ncorrect(logits: jnp.ndarray, y: jnp.ndarray):
    """Mean cross-entropy and number of correct predictions (both f32)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    ncorrect = (logits.argmax(axis=-1) == y).sum().astype(jnp.float32)
    return loss, ncorrect


# ---------------------------------------------------------------------------
# Edge / cloud step functions (flat in, flat out)
# ---------------------------------------------------------------------------

def make_edge_fwd(edge: nn.Layer, treedef, n_leaves: int):
    def edge_fwd(*args):
        params = _unflatten(treedef, args[:n_leaves])
        x = args[n_leaves]
        return (edge.apply(params, x),)

    return edge_fwd


def make_edge_bwd(edge: nn.Layer, treedef, n_leaves: int):
    def edge_bwd(*args):
        params = _unflatten(treedef, args[:n_leaves])
        x, gz = args[n_leaves], args[n_leaves + 1]
        _, vjp = jax.vjp(lambda p: edge.apply(p, x), params)
        (gparams,) = vjp(gz)
        return tuple(jax.tree_util.tree_leaves(gparams))

    return edge_bwd


def make_cloud_step(cloud: nn.Layer, treedef, n_leaves: int):
    def cloud_step(*args):
        params = _unflatten(treedef, args[:n_leaves])
        zhat, y = args[n_leaves], args[n_leaves + 1]

        def loss_fn(p, zz):
            logits = cloud.apply(p, zz)
            loss, nc = xent_and_ncorrect(logits, y)
            return loss, nc

        (loss, nc), grads = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
            params, zhat)
        gparams, gz = grads
        return (loss, nc) + tuple(jax.tree_util.tree_leaves(gparams)) + (gz,)

    return cloud_step


def make_cloud_eval(cloud: nn.Layer, treedef, n_leaves: int):
    def cloud_eval(*args):
        params = _unflatten(treedef, args[:n_leaves])
        zhat, y = args[n_leaves], args[n_leaves + 1]
        logits = cloud.apply(params, zhat)
        loss, nc = xent_and_ncorrect(logits, y)
        return (loss, nc)

    return cloud_eval


# ---------------------------------------------------------------------------
# Adam (Kingma & Ba) — the paper's optimizer, lr 1e-4 (traced as an arg)
# ---------------------------------------------------------------------------

def make_adam(n_leaves: int, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    """args = params(N) + grads(N) + m(N) + v(N) + (step, lr) → params', m', v'."""

    def adam(*args):
        p = args[0:n_leaves]
        g = args[n_leaves:2 * n_leaves]
        m = args[2 * n_leaves:3 * n_leaves]
        v = args[3 * n_leaves:4 * n_leaves]
        step, lr = args[4 * n_leaves], args[4 * n_leaves + 1]
        t = step + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        new_p, new_m, new_v = [], [], []
        for pi, gi, mi, vi in zip(p, g, m, v):
            mi = b1 * mi + (1.0 - b1) * gi
            vi = b2 * vi + (1.0 - b2) * gi * gi
            mhat = mi / bc1
            vhat = vi / bc2
            new_p.append(pi - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_p) + tuple(new_m) + tuple(new_v)

    return adam


# ---------------------------------------------------------------------------
# Codec functions (C3: fixed keys; kernel selectable pallas|fft)
# ---------------------------------------------------------------------------

def make_gen_keys(r: int, d: int):
    def gen_keys(seed):
        return (ref.generate_keys(
            jax.random.wrap_key_data(seed, impl="threefry2x32"), r, d),)

    return gen_keys


def make_c3_encode(b: int, r: int, d: int, kernel: str = "pallas"):
    """(z[B,D], keys[R,D]) → s[G,D]; groups are consecutive batch rows."""
    g = b // r

    def encode(z, keys):
        zg = z.reshape(g, r, d)
        if kernel == "pallas":
            return (circconv.c3_encode(zg, keys),)
        return (ref.encode_ref(zg, keys),)

    return encode


def make_c3_decode(b: int, r: int, d: int, kernel: str = "pallas"):
    """(s[G,D], keys[R,D]) → ẑ[B,D] (groups unpacked back to batch order)."""
    g = b // r

    def decode(s, keys):
        if kernel == "pallas":
            zh = circconv.c3_decode(s, keys)
        else:
            zh = ref.decode_ref(s, keys)
        return (zh.reshape(b, d),)

    return decode


# ---------------------------------------------------------------------------
# Single-process oracle (for tests): full C3-SL step == paper Algorithm 1
# ---------------------------------------------------------------------------

def singleprocess_c3_step(edge: nn.Layer, cloud: nn.Layer, edge_params,
                          cloud_params, keys, x, y, r: int):
    """Paper Algorithm 1 in one jax.grad — the ground truth the distributed
    pipeline must match bit-for-bit (up to fp reassociation)."""

    def loss_fn(ep, cp):
        z = edge.apply(ep, x)                      # (B, D)
        b, d = z.shape
        zg = z.reshape(b // r, r, d)
        s = ref.encode_ref(zg, keys)
        zh = ref.decode_ref(s, keys).reshape(b, d)
        logits = cloud.apply(cp, zh)
        loss, nc = xent_and_ncorrect(logits, y)
        return loss, nc

    (loss, nc), grads = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
        edge_params, cloud_params)
    return loss, nc, grads[0], grads[1]
