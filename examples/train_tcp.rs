//! Two-process split learning over TCP — demonstrates that the edge and
//! cloud really are independent actors speaking the wire protocol.
//!
//! This example forks the cloud into a second OS process (re-executing this
//! binary with `--role cloud`), trains a few steps over localhost TCP and
//! reports the traffic.
//!
//!   cargo run --release --example train_tcp

use c3sl::util::error::Result;

use c3sl::config::{CodecVenue, ExperimentConfig, SchemeKind, TransportKind};
use c3sl::coordinator::{CloudWorker, EdgeWorker};
use c3sl::data::open_dataset;
use c3sl::runtime::Engine;
use c3sl::transport::tcp::Tcp;
use c3sl::transport::Transport;

fn cfg() -> ExperimentConfig {
    ExperimentConfig {
        name: "train_tcp".into(),
        model_key: "vggt_b32".into(),
        scheme: SchemeKind::C3 { r: 4 },
        codec_venue: CodecVenue::Artifact,
        transport: TransportKind::Tcp,
        tcp_addr: "127.0.0.1:39717".into(),
        steps: 10,
        lr: 1e-3,
        seed: 3,
        eval_every: 10,
        eval_batches: 2,
        synth_train: 256,
        synth_test: 64,
        ..Default::default()
    }
}

fn run_cloud() -> Result<()> {
    let c = cfg();
    let engine = Engine::cpu()?;
    let mut cloud = CloudWorker::new(&engine, &c)?;
    let mut tp: Box<dyn Transport> = Box::new(Tcp::listen(&c.tcp_addr)?);
    cloud.run(tp.as_mut())?;
    eprintln!("[cloud-proc] done; mean cloud step {:.4}s", cloud.step_latency.mean());
    Ok(())
}

fn main() -> Result<()> {
    if !std::path::Path::new("artifacts/vggt_b32/manifest.json").exists() {
        println!("SKIP train_tcp: artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    if std::env::args().any(|a| a == "cloud") {
        return run_cloud();
    }

    // Fork the cloud as a separate OS process.
    let exe = std::env::current_exe()?;
    let mut child = std::process::Command::new(exe)
        .arg("--role")
        .arg("cloud")
        .spawn()?;

    let c = cfg();
    let engine = Engine::cpu()?;
    let mut edge = EdgeWorker::new(&engine, &c)?;
    let manifest = c3sl::runtime::ModelManifest::load(c.model_dir())?;
    let train = open_dataset(&c.data_root, manifest.classes, manifest.image, true, 256);
    let test = open_dataset(&c.data_root, manifest.classes, manifest.image, false, 64);

    let mut tp: Box<dyn Transport> = Box::new(Tcp::connect(&c.tcp_addr)?);
    let rec = edge.run(tp.as_mut(), train.as_ref(), test.as_ref(), &c)?;
    let status = child.wait()?;
    c3sl::ensure!(status.success(), "cloud process failed");

    println!("[edge-proc] {}", rec.summary());
    println!(
        "[edge-proc] tcp traffic: tx={}B rx={}B over {} steps",
        tp.stats().tx(),
        tp.stats().rx(),
        c.steps
    );
    println!("train_tcp OK — two OS processes, real sockets, compressed both ways");
    Ok(())
}
