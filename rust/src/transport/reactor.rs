//! Nonblocking reactor transport: one thread multiplexes N edge connections.
//!
//! The thread-per-client cloud ([`crate::coordinator::multi::serve_clients`])
//! burns one OS thread (stack, scheduler slot, context switches) per edge,
//! which caps concurrent edges at the dozens.  This module provides the
//! substrate for a reactor-driven cloud that scales to thousands of edges:
//!
//! * [`ReactorConn`] — a connection that can be *polled*: pull at most one
//!   complete length-prefixed wire frame without blocking, and push queued
//!   reply frames as far as the peer will accept them without blocking.
//! * [`NbTcp`] — a nonblocking TCP connection with explicit partial-read /
//!   partial-write state machines for the `[len u32 LE][frame]` framing
//!   (`std`-only: `TcpStream::set_nonblocking`, no external crates).
//! * [`NbInProc`] — the in-process equivalent over mpsc channels (frames
//!   arrive whole, so the state machine degenerates to `try_recv`), used by
//!   tests and the in-proc multi-edge venue.  Carries an eventfd *doorbell*
//!   on Linux so channel-backed connections are epoll-pollable like sockets.
//! * [`Reactor`] — the event pump, with two interchangeable readiness
//!   backends ([`crate::transport::readiness`], knob:
//!   [`ReactorConfig::backend`]):
//!
//!   * **`epoll`** (Linux default) — event-driven: every connection's fd is
//!     registered with per-connection *interest* (read-interest whenever the
//!     client may be read; write-interest only while its outbox has parked
//!     bytes, re-armed on partial writes) and the pump blocks in
//!     `epoll_wait` until the OS reports readiness.  Zero CPU at idle, no
//!     matter the fan-in, and a worker-pool eventfd waker delivers finished
//!     compute to the pump immediately.
//!   * **`sweep`** (portable fallback) — the original fair round-robin
//!     sweep over all open connections with a timed idle backoff.
//!
//!   Both backends flush outboxes first, then pull newly completed frames,
//!   decode them to [`Msg`] events, and apply backpressure by *not reading*
//!   from a client whose outbox is backed up past
//!   [`ReactorConfig::max_outbox_frames`].  Byte-for-byte, the two backends
//!   are indistinguishable on the wire (the conformance tests assert it).
//!
//! The reactor owns I/O only.  Compute (codec decode/step/encode) belongs on
//! a worker pool — see `coordinator::multi::serve_clients_reactor`, which
//! feeds jobs from the reactor's ready events to `scheme.workers` codec
//! threads and queues the resulting reply frames back through [`Reactor`].
//!
//! Byte accounting matches the blocking transports exactly: [`NbTcp`] counts
//! the 4-byte length prefix like [`super::tcp::Tcp`]; [`NbInProc`] counts raw
//! frame bytes like [`super::InProc`] — so a reactor cloud and its blocking
//! edges agree byte-for-byte in the multi-edge accounting tests.

use std::collections::VecDeque;
use std::io::{IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;

use super::readiness::{RawFd, ReadinessBackend, WakeHandle};
#[cfg(target_os = "linux")]
use super::readiness::{Epoll, Interest, Ready, WAKER_TOKEN};
use super::{check_frame_len, LinkStats, Msg, TransportError};
use crate::transport::wire;

/// Outcome of one nonblocking receive attempt on a [`ReactorConn`].
#[derive(Debug)]
pub enum PollIn {
    /// A complete wire frame arrived.
    Frame(Vec<u8>),
    /// No complete frame is available right now (reading would block).
    Idle,
    /// The peer closed the connection cleanly (EOF at a frame boundary).
    Closed,
}

/// A connection a [`Reactor`] can multiplex: nonblocking frame I/O with an
/// internal outbox for partially written replies.
pub trait ReactorConn: Send {
    /// Try to pull one complete wire frame without blocking.  Partial reads
    /// are buffered internally; the peer-announced length prefix is validated
    /// with [`check_frame_len`] *before* any allocation.
    fn poll_recv(&mut self) -> Result<PollIn, TransportError>;

    /// Queue a wire frame (as produced by [`wire::encode`]) for transmission.
    /// Never blocks; bytes move on the next [`ReactorConn::poll_send`].
    fn queue_frame(&mut self, frame: Vec<u8>);

    /// Push queued bytes toward the peer without blocking.  Returns `true`
    /// when the outbox fully drained, `false` if the peer would block.
    fn poll_send(&mut self) -> Result<bool, TransportError>;

    /// Frames queued but not yet fully handed to the peer.
    fn pending_out(&self) -> usize;

    /// Shared byte counters for this connection (this endpoint's half).
    fn stats(&self) -> Arc<LinkStats>;

    /// The OS-pollable readiness handle for this connection, if it has one:
    /// the socket fd for [`NbTcp`], the eventfd doorbell for [`NbInProc`]
    /// (Linux).  `None` means the connection cannot participate in an
    /// event-driven backend — a reactor holding such a connection falls
    /// back to the portable sweep for the whole session.
    fn readiness_fd(&self) -> Option<RawFd> {
        None
    }
}

// ---------------------------------------------------------------------------
// Nonblocking TCP connection
// ---------------------------------------------------------------------------

/// Read-side state machine position for [`NbTcp`].
enum ReadState {
    /// Accumulating the 4-byte length prefix.
    Len,
    /// Accumulating the frame body (length already validated).
    Body,
}

/// One queued reply: the 4-byte length prefix kept separate from the frame
/// so queueing never copies the frame body (the workers hand over owned
/// frames; the I/O thread only writes them, gather-style).
struct OutFrame {
    prefix: [u8; 4],
    frame: Vec<u8>,
}

impl OutFrame {
    fn total(&self) -> usize {
        4 + self.frame.len()
    }
}

/// A nonblocking TCP connection speaking the `[len u32 LE][frame]` framing,
/// resumable at any byte boundary: partial prefixes, partial bodies and
/// partial writes all park state and return to the reactor instead of
/// blocking the thread.
pub struct NbTcp {
    stream: TcpStream,
    stats: Arc<LinkStats>,
    rstate: ReadState,
    lenbuf: [u8; 4],
    len_have: usize,
    body: Vec<u8>,
    body_have: usize,
    outbox: VecDeque<OutFrame>,
    /// Bytes of `outbox.front()` (prefix + frame) already written.
    out_off: usize,
}

impl NbTcp {
    /// Wrap an accepted stream in nonblocking mode (the reactor's accept path
    /// hands over raw streams from [`super::tcp::Tcp::accept_streams`]).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<Self> {
        stream.set_nodelay(true)?;
        stream.set_nonblocking(true)?;
        Ok(NbTcp {
            stream,
            stats: Arc::new(LinkStats::default()),
            rstate: ReadState::Len,
            lenbuf: [0; 4],
            len_have: 0,
            body: Vec::new(),
            body_have: 0,
            outbox: VecDeque::new(),
            out_off: 0,
        })
    }
}

impl ReactorConn for NbTcp {
    fn poll_recv(&mut self) -> Result<PollIn, TransportError> {
        loop {
            match self.rstate {
                ReadState::Len => {
                    while self.len_have < 4 {
                        match self.stream.read(&mut self.lenbuf[self.len_have..]) {
                            Ok(0) => {
                                if self.len_have == 0 {
                                    return Ok(PollIn::Closed);
                                }
                                return Err(std::io::Error::new(
                                    std::io::ErrorKind::UnexpectedEof,
                                    "EOF inside a length prefix",
                                )
                                .into());
                            }
                            Ok(n) => self.len_have += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(PollIn::Idle)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    let len = u32::from_le_bytes(self.lenbuf) as usize;
                    // Validate the peer-controlled length BEFORE allocating.
                    check_frame_len(len)?;
                    self.body = vec![0u8; len];
                    self.body_have = 0;
                    self.rstate = ReadState::Body;
                }
                ReadState::Body => {
                    while self.body_have < self.body.len() {
                        match self.stream.read(&mut self.body[self.body_have..]) {
                            Ok(0) => {
                                return Err(std::io::Error::new(
                                    std::io::ErrorKind::UnexpectedEof,
                                    "EOF inside a frame body",
                                )
                                .into())
                            }
                            Ok(n) => self.body_have += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(PollIn::Idle)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    let frame = std::mem::take(&mut self.body);
                    self.rstate = ReadState::Len;
                    self.len_have = 0;
                    self.stats
                        .rx_bytes
                        .fetch_add(4 + frame.len() as u64, Ordering::Relaxed);
                    self.stats.rx_msgs.fetch_add(1, Ordering::Relaxed);
                    return Ok(PollIn::Frame(frame));
                }
            }
        }
    }

    fn queue_frame(&mut self, frame: Vec<u8>) {
        // zero-copy queueing: the frame Vec moves in untouched, the prefix
        // rides alongside and both are written gather-style in poll_send
        self.outbox.push_back(OutFrame {
            prefix: (frame.len() as u32).to_le_bytes(),
            frame,
        });
    }

    fn poll_send(&mut self) -> Result<bool, TransportError> {
        loop {
            let Some(front) = self.outbox.front() else {
                return Ok(true);
            };
            // one writev over the unwritten tail of [prefix][frame]
            let wrote = if self.out_off < 4 {
                self.stream.write_vectored(&[
                    IoSlice::new(&front.prefix[self.out_off..]),
                    IoSlice::new(&front.frame),
                ])
            } else {
                self.stream.write(&front.frame[self.out_off - 4..])
            };
            match wrote {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "peer accepted zero bytes",
                    )
                    .into())
                }
                Ok(n) => {
                    self.out_off += n;
                    if self.out_off == front.total() {
                        // `front` came off this queue above, so pop_front
                        // cannot miss — but the I/O thread must never
                        // panic, so an (impossible) empty queue is a no-op
                        if let Some(done) = self.outbox.pop_front() {
                            self.out_off = 0;
                            // prefix + frame bytes, matching Tcp::send accounting
                            self.stats
                                .tx_bytes
                                .fetch_add(done.total() as u64, Ordering::Relaxed);
                            self.stats.tx_msgs.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn pending_out(&self) -> usize {
        self.outbox.len()
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }

    fn readiness_fd(&self) -> Option<RawFd> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            Some(self.stream.as_raw_fd())
        }
        #[cfg(not(unix))]
        {
            None
        }
    }
}

// ---------------------------------------------------------------------------
// Nonblocking in-process connection
// ---------------------------------------------------------------------------

/// In-process [`ReactorConn`] over mpsc channels, pairing with a blocking
/// [`super::InProc`] edge endpoint (see [`super::inproc_reactor_pair`]).
/// Frames arrive whole, so `poll_recv` is a `try_recv`; sends never block
/// (the channel is unbounded), so backpressure shows up only as outbox depth.
///
/// On Linux the pair shares an eventfd *doorbell*: the edge rings it after
/// every channel send (and on drop), so the epoll backend can wait on this
/// connection exactly like a socket.  The doorbell is cleared only when the
/// channel is observed empty, then re-checked — a frame that lands between
/// the check and the clear is picked up immediately, and one that lands
/// after re-rings the level trigger, so no frame is ever stranded behind a
/// cleared bell.
pub struct NbInProc {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    stats: Arc<LinkStats>,
    outbox: VecDeque<Vec<u8>>,
    bell: WakeHandle,
}

impl NbInProc {
    /// Build from raw channel halves plus the doorbell the sending side
    /// rings (used by [`super::inproc_reactor_pair`]; pass
    /// [`WakeHandle::none`] for a sweep-only connection).
    pub fn new(tx: Sender<Vec<u8>>, rx: Receiver<Vec<u8>>, bell: WakeHandle) -> Self {
        NbInProc {
            tx,
            rx,
            stats: Arc::new(LinkStats::default()),
            outbox: VecDeque::new(),
            bell,
        }
    }

    /// Account and wrap one received frame.
    fn accept_frame(&self, frame: Vec<u8>) -> Result<PollIn, TransportError> {
        check_frame_len(frame.len())?;
        self.stats.rx_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.stats.rx_msgs.fetch_add(1, Ordering::Relaxed);
        Ok(PollIn::Frame(frame))
    }
}

impl ReactorConn for NbInProc {
    fn poll_recv(&mut self) -> Result<PollIn, TransportError> {
        match self.rx.try_recv() {
            Ok(frame) => self.accept_frame(frame),
            Err(TryRecvError::Empty) => {
                // Clear the doorbell only on an observed-empty channel, then
                // re-check: the sender's send→ring order guarantees a frame
                // enqueued after this second look re-rings the bell.
                self.bell.clear();
                match self.rx.try_recv() {
                    Ok(frame) => self.accept_frame(frame),
                    Err(TryRecvError::Empty) => Ok(PollIn::Idle),
                    Err(TryRecvError::Disconnected) => Ok(PollIn::Closed),
                }
            }
            Err(TryRecvError::Disconnected) => Ok(PollIn::Closed),
        }
    }

    fn queue_frame(&mut self, frame: Vec<u8>) {
        self.outbox.push_back(frame);
    }

    fn poll_send(&mut self) -> Result<bool, TransportError> {
        while let Some(frame) = self.outbox.pop_front() {
            let n = frame.len() as u64;
            if self.tx.send(frame).is_err() {
                return Err(TransportError::Closed);
            }
            self.stats.tx_bytes.fetch_add(n, Ordering::Relaxed);
            self.stats.tx_msgs.fetch_add(1, Ordering::Relaxed);
        }
        Ok(true)
    }

    fn pending_out(&self) -> usize {
        self.outbox.len()
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }

    fn readiness_fd(&self) -> Option<RawFd> {
        self.bell.raw_fd()
    }
}

// ---------------------------------------------------------------------------
// The reactor: event pump over N connections, sweep or epoll driven
// ---------------------------------------------------------------------------

/// Tunables for the reactor loop (config: `[transport] backend/poll_us/...`).
#[derive(Clone, Copy, Debug)]
pub struct ReactorConfig {
    /// Readiness discovery: event-driven `epoll` (Linux default) or the
    /// portable `sweep` fallback (`[transport] backend`,
    /// `--reactor-backend`).  A reactor that cannot realize `epoll` (non-fd
    /// connection, descriptor exhaustion) silently degrades to `sweep` —
    /// [`Reactor::backend`] reports what actually runs.
    pub backend: ReadinessBackend,
    /// Idle backoff sleep in microseconds when a full sweep makes no
    /// progress — the sweep backend's stand-in for blocking in `epoll_wait`
    /// (the epoll backend blocks instead and ignores this).
    pub poll_sleep_us: u64,
    /// Per-client outbox bound, in frames: once a client's outbox reaches
    /// this depth the reactor stops *reading* from it until replies drain —
    /// a slow consumer stalls only itself, never the pump.
    pub max_outbox_frames: usize,
    /// Fairness cap: at most this many frames are pulled from one client per
    /// sweep (or per epoll readiness report), so one chatty edge cannot
    /// starve the round-robin.
    pub max_frames_per_sweep: usize,
    /// Per-client bound on parsed-but-undispatched compute jobs; above it
    /// the serving loop holds reads from that client (pipelined clients get
    /// genuine TCP backpressure instead of unbounded queueing).
    pub max_pending_jobs: usize,
}

impl ReactorConfig {
    /// Copy with every count bound clamped to ≥ 1.  A zero bound would
    /// silently stop all reads (or permanently hold every client) and hang
    /// whatever drives the pump, so every consumer normalizes through this
    /// one place.
    pub fn clamped(self) -> Self {
        ReactorConfig {
            backend: self.backend,
            poll_sleep_us: self.poll_sleep_us,
            max_outbox_frames: self.max_outbox_frames.max(1),
            max_frames_per_sweep: self.max_frames_per_sweep.max(1),
            max_pending_jobs: self.max_pending_jobs.max(1),
        }
    }
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            backend: ReadinessBackend::platform_default(),
            poll_sleep_us: 100,
            max_outbox_frames: 8,
            max_frames_per_sweep: 4,
            max_pending_jobs: 4,
        }
    }
}

/// What one reactor sweep observed on one client.
#[derive(Debug)]
pub enum Event {
    /// A decoded protocol message arrived from `client`.
    Msg {
        /// Connection index (accept order).
        client: usize,
        /// The decoded message.
        msg: Msg,
    },
    /// `client` closed its connection cleanly (EOF at a frame boundary).
    Closed {
        /// Connection index (accept order).
        client: usize,
    },
    /// `client`'s connection failed; the reactor has already closed it.
    Error {
        /// Connection index (accept order).
        client: usize,
        /// The transport-level failure.
        error: TransportError,
    },
    /// The data-plane accept listener ([`Reactor::serve_accept`]) admitted
    /// a new connection; `client` is its freshly assigned slot.  Always
    /// surfaced before any [`Event::Msg`] from that slot, so the serving
    /// loop can grow its per-client state first.
    Accepted {
        /// The new connection's slot (continues accept order).
        client: usize,
    },
}

/// I/O-side observability for one reactor serve, surfaced by
/// `coordinator::multi::MultiStats` and the scale bench: which readiness
/// backend actually ran, how often the pump woke, and how much CPU the I/O
/// thread burned (where the thread CPU clock exists).  The epoll backend's
/// whole point is that `wakeups` tracks *events*, not time: a mostly-idle
/// fleet wakes it orders of magnitude less often than the sweep's timed
/// polling.
#[derive(Clone, Copy, Debug)]
pub struct ReactorIoStats {
    /// The readiness backend the reactor actually ran
    /// (after any fallback — see [`ReactorConfig::backend`]).
    pub backend: ReadinessBackend,
    /// Pump wakeups: `epoll_wait` returns (epoll) or poll sweeps (sweep).
    pub wakeups: u64,
    /// CPU seconds the serving (I/O) thread consumed, when measurable.
    pub io_cpu_seconds: Option<f64>,
}

// ---------------------------------------------------------------------------
// The ops control plane: a plaintext HTTP listener served off the reactor's
// own readiness pass — one more pollable fd, no extra I/O thread.
// ---------------------------------------------------------------------------

/// Registration token reserved for the ops listener fd (one below
/// [`WAKER_TOKEN`]; never a valid connection index).
const OPS_LISTENER_TOKEN: u64 = u64::MAX - 1;

/// Registration token reserved for the data-plane accept listener
/// ([`Reactor::serve_accept`]); like the ops tokens it only wakes the
/// wait — the unconditional accept pump after each pass does the service.
const DATA_LISTENER_TOKEN: u64 = u64::MAX - 2;

/// Ops connection tokens are `OPS_CONN_BASE + slot` — a namespace far above
/// any plausible client index, so the epoll dispatch can tell the two apart
/// with one comparison.
const OPS_CONN_BASE: u64 = 1 << 62;

/// Concurrent ops connections served.  Excess accepts are dropped on the
/// floor (scrapers retry); the ops plane must never be the reactor's memory
/// or fd amplifier.
const MAX_OPS_CONNS: usize = 32;

/// Request-head cap: an ops request is one short line plus a few headers.
/// Anything larger gets `431` and the connection closed, so a misdirected
/// upload cannot balloon the pump's memory.
const MAX_OPS_REQUEST_BYTES: usize = 8 * 1024;

/// One HTTP request parsed off an ops connection, surfaced by
/// [`Reactor::take_ops_requests`].  The serving loop interprets the path and
/// answers via [`Reactor::ops_respond`] with the same `conn` handle.
#[derive(Debug)]
pub struct OpsRequest {
    /// Ops connection handle (valid until responded or the peer hangs up).
    pub conn: usize,
    /// Request method (`GET`, `POST`, ...), verbatim.
    pub method: String,
    /// Request path (`/metrics`, `/healthz`, `/drain`, ...), verbatim.
    pub path: String,
}

/// One accepted ops connection: a tiny nonblocking HTTP/1.0 state machine —
/// read until the blank line, surface the request, write one response, close.
struct OpsConn {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    out_off: usize,
    /// Head fully received (request surfaced or canned error queued): the
    /// pump stops reading — any body bytes are ignored, the response closes
    /// the connection.
    head_done: bool,
    /// Whether the fd is currently registered with the epoll backend.
    registered: bool,
}

/// The ops listener plus its accepted connections.
struct OpsState {
    listener: TcpListener,
    local: Option<SocketAddr>,
    conns: Vec<Option<OpsConn>>,
}

/// The data-plane accept listener ([`Reactor::serve_accept`]).
struct AcceptState {
    listener: TcpListener,
    local: Option<SocketAddr>,
}

struct Slot {
    link: Option<Box<dyn ReactorConn>>,
    stats: Arc<LinkStats>,
    hold: bool,
}

impl Slot {
    /// Frames parked in this connection's outbox (0 once closed).
    fn pending(&self) -> usize {
        self.link.as_ref().map_or(0, |l| l.pending_out())
    }

    /// THE read gate: a client may be read iff open, not held, and its
    /// outbox is under the backpressure bound.  Both backends' service
    /// paths AND the epoll interest arming evaluate exactly this one
    /// definition — epoll correctness depends on armed interest staying in
    /// lockstep with the service gate, so the invariant must never be
    /// restated anywhere else.
    fn wants_read(&self, cfg: &ReactorConfig) -> bool {
        self.link.is_some() && !self.hold && self.pending() < cfg.max_outbox_frames
    }
}

/// Per-connection epoll registration state: the fd and the interest it is
/// currently armed with (`None` = deregistered, e.g. a held client with an
/// empty outbox, which must not wake the pump even via the always-reported
/// error/hangup events).
#[cfg(target_os = "linux")]
struct EpollReg {
    fd: RawFd,
    armed: Option<Interest>,
}

/// The epoll backend's working state.
#[cfg(target_os = "linux")]
struct EpollState {
    ep: Epoll,
    /// The worker-pool waker, registered under [`WAKER_TOKEN`].
    waker: WakeHandle,
    /// Indexed by connection; `None` once permanently deregistered (closed).
    reg: Vec<Option<EpollReg>>,
    /// Connections whose interest must be recomputed before the next wait
    /// (outbox changed, hold toggled, closed) — deduplicated via `is_dirty`.
    dirty: Vec<usize>,
    is_dirty: Vec<bool>,
    /// Reused readiness buffer.
    ready: Vec<Ready>,
    /// `epoll_wait` returns so far (the bench's wakeups/sec numerator;
    /// failed waits are not counted).
    wakeups: u64,
    /// Consecutive `epoll_wait` failures; at
    /// [`MAX_WAIT_FAILURES`] the reactor degrades to the sweep backend
    /// instead of spinning hot on a broken wait.
    wait_failures: u32,
}

/// Consecutive `epoll_wait` failures tolerated (each bounded by a 1 ms
/// backoff) before the reactor permanently degrades to the sweep backend.
/// `epoll_wait` cannot fail on a valid epfd in normal operation — this
/// guards pathological environments (a seccomp profile denying the
/// syscall at runtime, an invalidated epfd) where silently retrying would
/// otherwise become a 100% CPU busy-spin with no events and no error.
#[cfg(target_os = "linux")]
const MAX_WAIT_FAILURES: u32 = 3;

#[cfg(target_os = "linux")]
impl EpollState {
    fn mark_dirty(&mut self, ci: usize) {
        if ci < self.is_dirty.len() && !self.is_dirty[ci] {
            self.is_dirty[ci] = true;
            self.dirty.push(ci);
        }
    }
}

/// Which readiness machinery this reactor instance runs.
enum BackendImpl {
    Sweep,
    #[cfg(target_os = "linux")]
    Epoll(EpollState),
}

/// The event pump: owns all client connections and multiplexes them from a
/// single thread.  Each [`Reactor::poll`] performs one discovery pass —
/// a fair round-robin sweep (sweep backend) or an `epoll_wait` dispatch
/// (epoll backend); callers interleave passes with their own work
/// (dispatching compute, collecting results).  When neither side made
/// progress, an epoll-backed caller simply blocks in the next
/// [`Reactor::poll_wait`]; a sweep-backed caller parks via
/// [`Reactor::idle_sleep`] / its own completion-channel timeout.
pub struct Reactor {
    conns: Vec<Slot>,
    cfg: ReactorConfig,
    rr: usize,
    /// Sweep passes so far (the sweep backend's wakeup counter).
    sweeps: u64,
    backend: BackendImpl,
    /// The ops control plane, once [`Reactor::serve_ops`] installed it.
    ops: Option<OpsState>,
    /// Requests parsed off ops connections, awaiting
    /// [`Reactor::take_ops_requests`].
    ops_requests: Vec<OpsRequest>,
    /// The data-plane accept listener, once [`Reactor::serve_accept`]
    /// installed it.
    accept: Option<AcceptState>,
}

impl Reactor {
    /// Take ownership of `links` (index = client id, accept order).  The
    /// count bounds are normalized via [`ReactorConfig::clamped`].  With
    /// [`ReactorConfig::backend`] = `epoll`, every connection's
    /// [`ReactorConn::readiness_fd`] is registered up front; if the backend
    /// cannot be realized (unsupported platform, an fd-less connection,
    /// descriptor exhaustion) the reactor degrades to the sweep —
    /// [`Reactor::backend`] reports the outcome.
    pub fn new(links: Vec<Box<dyn ReactorConn>>, cfg: ReactorConfig) -> Self {
        let cfg = cfg.clamped();
        let conns: Vec<Slot> = links
            .into_iter()
            .map(|link| Slot { stats: link.stats(), link: Some(link), hold: false })
            .collect();
        let backend = build_backend(&conns, cfg.backend);
        Reactor {
            conns,
            cfg,
            rr: 0,
            sweeps: 0,
            backend,
            ops: None,
            ops_requests: Vec::new(),
            accept: None,
        }
    }

    /// Install the data-plane accept listener: new edge connections are
    /// admitted on every pump pass (under the epoll backend the listener
    /// also registers as a wakeup source), wrapped in [`NbTcp`], appended
    /// as fresh slots, and surfaced as [`Event::Accepted`].  This is what
    /// lets a serving session outlive any single connection — the
    /// reconnect-and-resume path accepts mid-serve instead of locking the
    /// fleet at construction.
    pub fn serve_accept(&mut self, listener: TcpListener) -> std::io::Result<()> {
        listener.set_nonblocking(true)?;
        let local = listener.local_addr().ok();
        #[cfg(target_os = "linux")]
        if let BackendImpl::Epoll(st) = &mut self.backend {
            use std::os::unix::io::AsRawFd;
            // best-effort: an unregistered listener is still accepted from
            // on every pump pass, just without event-driven latency
            let _ = st.ep.add(
                listener.as_raw_fd(),
                DATA_LISTENER_TOKEN,
                Interest { read: true, write: false },
            );
        }
        self.accept = Some(AcceptState { listener, local });
        Ok(())
    }

    /// The bound address of the data accept listener, if one is installed.
    pub fn accept_local_addr(&self) -> Option<SocketAddr> {
        self.accept.as_ref().and_then(|a| a.local)
    }

    /// Append a connection as a fresh slot mid-serve, returning its index.
    /// Under the epoll backend the new fd registers immediately; if it
    /// cannot (no fd, registration failure) the whole reactor degrades to
    /// the sweep backend rather than stranding one unserviceable slot.
    pub fn add_conn(&mut self, link: Box<dyn ReactorConn>) -> usize {
        let ci = self.conns.len();
        self.conns.push(Slot { stats: link.stats(), link: Some(link), hold: false });
        #[cfg(target_os = "linux")]
        if let BackendImpl::Epoll(st) = &mut self.backend {
            let fd = self.conns[ci].link.as_ref().and_then(|l| l.readiness_fd());
            let interest = Interest { read: true, write: false };
            match fd {
                Some(fd) if st.ep.add(fd, ci as u64, interest).is_ok() => {
                    st.reg.push(Some(EpollReg { fd, armed: Some(interest) }));
                    st.is_dirty.push(false);
                }
                _ => {
                    // an unarmable connection would never be serviced:
                    // degrade the whole pump to the sweep, which needs no
                    // registrations (matching the poll_wait failure path)
                    self.backend = BackendImpl::Sweep;
                }
            }
        }
        ci
    }

    /// Tunables this reactor runs with.
    pub fn config(&self) -> ReactorConfig {
        self.cfg
    }

    /// The readiness backend actually in use (after any fallback).
    pub fn backend(&self) -> ReadinessBackend {
        match &self.backend {
            BackendImpl::Sweep => ReadinessBackend::Sweep,
            #[cfg(target_os = "linux")]
            BackendImpl::Epoll(_) => ReadinessBackend::Epoll,
        }
    }

    /// Total connections this reactor was built with (open or closed).
    pub fn client_count(&self) -> usize {
        self.conns.len()
    }

    /// Pump wakeups so far: `epoll_wait` returns (epoll backend) or poll
    /// sweeps (sweep backend).  The scale bench divides by wall time to
    /// report wakeups/sec per backend.
    pub fn wakeups(&self) -> u64 {
        match &self.backend {
            BackendImpl::Sweep => self.sweeps,
            #[cfg(target_os = "linux")]
            BackendImpl::Epoll(st) => st.wakeups,
        }
    }

    /// A cross-thread handle that wakes the pump out of `epoll_wait`
    /// (worker pools ring it after publishing finished compute).  Unarmed —
    /// a no-op — on the sweep backend, whose callers park on their
    /// completion channel instead and need no wakeup.
    pub fn waker(&self) -> WakeHandle {
        match &self.backend {
            BackendImpl::Sweep => WakeHandle::none(),
            #[cfg(target_os = "linux")]
            BackendImpl::Epoll(st) => st.waker.clone(),
        }
    }

    /// Install the ops control-plane listener: plaintext HTTP served off
    /// this reactor's own readiness pass — the listener is just one more
    /// pollable fd, no extra thread, no async runtime.  Under the epoll
    /// backend the listener (and each accepted connection) registers as a
    /// wakeup source; the sweep backend polls them on every pass like
    /// everything else.  Parsed requests surface via
    /// [`Reactor::take_ops_requests`]; the serving loop answers each with
    /// [`Reactor::ops_respond`].
    pub fn serve_ops(&mut self, listener: TcpListener) -> std::io::Result<()> {
        listener.set_nonblocking(true)?;
        let local = listener.local_addr().ok();
        self.ops = Some(OpsState { listener, local, conns: Vec::new() });
        #[cfg(target_os = "linux")]
        if let (BackendImpl::Epoll(st), Some(ops)) = (&mut self.backend, self.ops.as_ref()) {
            use std::os::unix::io::AsRawFd;
            // best-effort: an unregistered listener is still accepted from
            // on every pump pass, just without event-driven latency
            let _ = st.ep.add(
                ops.listener.as_raw_fd(),
                OPS_LISTENER_TOKEN,
                Interest { read: true, write: false },
            );
        }
        Ok(())
    }

    /// The bound address of the ops listener, if one is installed (callers
    /// bind port 0 and discover the real port here).
    pub fn ops_local_addr(&self) -> Option<SocketAddr> {
        self.ops.as_ref().and_then(|o| o.local)
    }

    /// Drain the ops requests parsed since the last call.
    pub fn take_ops_requests(&mut self) -> Vec<OpsRequest> {
        std::mem::take(&mut self.ops_requests)
    }

    /// Answer one surfaced [`OpsRequest`]: a complete `HTTP/1.0` response
    /// is assembled, flushed as far as the peer accepts without blocking,
    /// and any remainder drains on subsequent passes; the connection closes
    /// once the response is fully written.  A vanished connection (the peer
    /// hung up first) or a double answer is a no-op.
    pub fn ops_respond(
        &mut self,
        conn: usize,
        status: u16,
        reason: &str,
        content_type: &str,
        body: &[u8],
    ) {
        let Some(ops) = self.ops.as_mut() else {
            return;
        };
        match ops.conns.get_mut(conn) {
            Some(Some(c)) if c.outbuf.is_empty() => {
                c.outbuf = http_response(status, reason, content_type, body);
                c.out_off = 0;
            }
            _ => return,
        }
        ops_flush_conn(&mut self.backend, ops, conn);
    }

    /// Live-retune the per-client outbox bound (the SIGHUP reload path).
    /// The value is clamped to ≥ 1 exactly like [`ReactorConfig::clamped`],
    /// and every connection's readiness interest is refreshed so the epoll
    /// backend re-evaluates its read gate under the new bound.
    pub fn set_max_outbox_frames(&mut self, frames: usize) {
        let frames = frames.max(1);
        if self.cfg.max_outbox_frames == frames {
            return;
        }
        self.cfg.max_outbox_frames = frames;
        for ci in 0..self.conns.len() {
            self.touch(ci);
        }
    }

    /// Live-retune the sweep backend's idle backoff (the SIGHUP reload
    /// path).  The epoll backend blocks in `epoll_wait` and ignores this.
    pub fn set_poll_sleep_us(&mut self, us: u64) {
        self.cfg.poll_sleep_us = us;
    }

    /// Mark one connection's readiness interest stale (epoll backend); the
    /// next poll re-arms it before waiting.
    fn touch(&mut self, _ci: usize) {
        #[cfg(target_os = "linux")]
        if let BackendImpl::Epoll(st) = &mut self.backend {
            st.mark_dirty(_ci);
        }
    }

    /// One discovery pass without blocking: flush outboxes, pull up to
    /// [`ReactorConfig::max_frames_per_sweep`] frames per ready client
    /// (skipping held or backlogged clients), decoding each into an
    /// [`Event`].  Connection failures surface as [`Event::Error`] and close
    /// the connection; they never abort the pass for other clients.
    /// Returns `true` if any byte moved or any event was produced.
    pub fn poll(&mut self, events: &mut Vec<Event>) -> bool {
        self.poll_wait(events, 0)
    }

    /// Like [`Reactor::poll`], but the epoll backend may block up to
    /// `timeout_ms` waiting for readiness (0 = return immediately) — the
    /// serving loop passes its idle budget here instead of sleeping.  The
    /// sweep backend cannot block on sockets, so it ignores the timeout and
    /// performs one immediate sweep (its caller parks on the completion
    /// channel, see `coordinator::multi`).
    pub fn poll_wait(&mut self, events: &mut Vec<Event>, timeout_ms: i32) -> bool {
        let _ = &timeout_ms;
        let mut progress = false;
        let mut discovered = false;
        let _ = &mut discovered;
        #[cfg(target_os = "linux")]
        {
            let outcome = match &mut self.backend {
                BackendImpl::Epoll(st) => {
                    Some(poll_epoll(&mut self.conns, &self.cfg, st, events, timeout_ms))
                }
                BackendImpl::Sweep => None,
            };
            match outcome {
                Some(Some(p)) => {
                    progress = p;
                    discovered = true;
                }
                Some(None) => {
                    // epoll_wait is persistently failing: degrade to the
                    // sweep backend (which needs no registrations) instead
                    // of spinning hot on a broken wait.  Dropping the epoll
                    // state closes the epfd; armed doorbells keep ringing
                    // into the void, which is harmless.
                    self.backend = BackendImpl::Sweep;
                }
                None => {}
            }
        }
        if !discovered {
            self.sweeps += 1;
            progress |= poll_sweep(&mut self.conns, &self.cfg, &mut self.rr, events);
        }
        // ops control plane: accepted and served off this very same pass —
        // the listener is one more readiness source, not another thread
        if let Some(ops) = self.ops.as_mut() {
            progress |= pump_ops(&mut self.backend, ops, &mut self.ops_requests);
        }
        // data-plane accept: each pending connection becomes a fresh slot
        // and surfaces as Event::Accepted — always ahead of any Event::Msg
        // from that slot, which only its NEXT pass can produce
        while self.accept.is_some() {
            let accepted = match self.accept.as_ref() {
                Some(acc) => acc.listener.accept(),
                None => break,
            };
            match accepted {
                Ok((stream, _peer)) => {
                    progress = true;
                    // an unwrappable stream is dropped; the edge retries
                    if let Ok(conn) = NbTcp::from_stream(stream) {
                        let ci = self.add_conn(Box::new(conn));
                        events.push(Event::Accepted { client: ci });
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break, // transient accept failure: retried next pass
            }
        }
        progress
    }

    /// Queue a wire frame for `client` (dropped silently if already closed —
    /// the caller learns about closure via [`Event::Closed`]/[`Event::Error`]).
    pub fn queue_frame(&mut self, client: usize, frame: Vec<u8>) {
        let queued = match self.conns[client].link.as_mut() {
            Some(link) => {
                link.queue_frame(frame);
                true
            }
            None => false,
        };
        if queued {
            self.touch(client);
        }
    }

    /// Pause (`true`) or resume (`false`) reading from `client` — the
    /// serving loop's lever for job-queue backpressure.
    pub fn set_hold(&mut self, client: usize, hold: bool) {
        if self.conns[client].hold == hold {
            return;
        }
        self.conns[client].hold = hold;
        self.touch(client);
    }

    /// Frames queued to `client` that have not fully reached the peer.
    pub fn outbox_len(&self, client: usize) -> usize {
        self.conns[client].link.as_ref().map_or(0, |l| l.pending_out())
    }

    /// Whether `client`'s connection is still open.
    pub fn is_open(&self, client: usize) -> bool {
        self.conns[client].link.is_some()
    }

    /// Open connection count.
    pub fn open_count(&self) -> usize {
        self.conns.iter().filter(|s| s.link.is_some()).count()
    }

    /// Byte counters for `client` (valid after close too).
    pub fn stats(&self, client: usize) -> Arc<LinkStats> {
        self.conns[client].stats.clone()
    }

    /// Close `client`'s connection (drops the socket / channel halves; the
    /// epoll backend deregisters the fd before the next wait).
    pub fn close(&mut self, client: usize) {
        self.conns[client].link = None;
        self.touch(client);
    }

    /// Park the thread briefly after a no-progress sweep — the sweep
    /// backend's idle backoff (the epoll backend blocks in
    /// [`Reactor::poll_wait`] instead and never needs this).
    pub fn idle_sleep(&self) {
        std::thread::sleep(std::time::Duration::from_micros(self.cfg.poll_sleep_us.max(1)));
    }
}

/// Construct the requested readiness backend, degrading to the sweep when
/// it cannot be realized on this platform / connection set.
fn build_backend(conns: &[Slot], want: ReadinessBackend) -> BackendImpl {
    if want != ReadinessBackend::Epoll {
        return BackendImpl::Sweep;
    }
    #[cfg(target_os = "linux")]
    {
        let Ok(ep) = Epoll::new() else {
            return BackendImpl::Sweep;
        };
        let waker = WakeHandle::armed();
        let Some(wfd) = waker.raw_fd() else {
            return BackendImpl::Sweep;
        };
        if ep.add(wfd, WAKER_TOKEN, Interest { read: true, write: false }).is_err() {
            return BackendImpl::Sweep;
        }
        let mut reg = Vec::with_capacity(conns.len());
        for (ci, slot) in conns.iter().enumerate() {
            let Some(link) = slot.link.as_ref() else {
                reg.push(None);
                continue;
            };
            // every connection must be OS-pollable, or the whole reactor
            // falls back: a half-evented pump would strand the fd-less conns
            let Some(fd) = link.readiness_fd() else {
                return BackendImpl::Sweep;
            };
            let interest = Interest { read: true, write: false };
            if ep.add(fd, ci as u64, interest).is_err() {
                return BackendImpl::Sweep;
            }
            reg.push(Some(EpollReg { fd, armed: Some(interest) }));
        }
        BackendImpl::Epoll(EpollState {
            ep,
            waker,
            reg,
            dirty: Vec::new(),
            is_dirty: vec![false; conns.len()],
            ready: Vec::new(),
            wakeups: 0,
            wait_failures: 0,
        })
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = conns;
        BackendImpl::Sweep
    }
}

/// Flush one slot's outbox (writes first: draining replies is what unblocks
/// everyone).  A write failure closes the slot and pushes [`Event::Error`].
/// Returns `true` when the outbox fully drained or the slot failed.
fn flush_slot(slot: &mut Slot, ci: usize, events: &mut Vec<Event>) -> bool {
    let Some(link) = slot.link.as_mut() else {
        return false;
    };
    if link.pending_out() == 0 {
        return false;
    }
    match link.poll_send() {
        Ok(true) => true,
        Ok(false) => false,
        Err(error) => {
            slot.link = None;
            events.push(Event::Error { client: ci, error });
            true
        }
    }
}

/// Pull up to `max_frames` complete frames from one slot, decoding each
/// into an [`Event::Msg`].  Close/decode/transport failures close the slot
/// and push the matching event.  Returns `true` on any progress.
fn read_slot(slot: &mut Slot, ci: usize, max_frames: usize, events: &mut Vec<Event>) -> bool {
    let mut progress = false;
    for _ in 0..max_frames {
        let Some(link) = slot.link.as_mut() else {
            break;
        };
        match link.poll_recv() {
            Ok(PollIn::Frame(frame)) => {
                progress = true;
                match wire::decode(&frame) {
                    Ok(msg) => events.push(Event::Msg { client: ci, msg }),
                    Err(e) => {
                        slot.link = None;
                        events.push(Event::Error { client: ci, error: e.into() });
                        break;
                    }
                }
            }
            Ok(PollIn::Idle) => break,
            Ok(PollIn::Closed) => {
                progress = true;
                slot.link = None;
                events.push(Event::Closed { client: ci });
                break;
            }
            Err(error) => {
                progress = true;
                slot.link = None;
                events.push(Event::Error { client: ci, error });
                break;
            }
        }
    }
    progress
}

/// One fair round-robin sweep over every open connection — the portable
/// readiness backend.
fn poll_sweep(
    conns: &mut [Slot],
    cfg: &ReactorConfig,
    rr: &mut usize,
    events: &mut Vec<Event>,
) -> bool {
    let n = conns.len();
    let mut progress = false;
    let start = *rr;
    *rr = (start + 1) % n.max(1);
    for off in 0..n {
        let ci = (start + off) % n;
        let slot = &mut conns[ci];
        if slot.link.is_none() {
            continue;
        }

        // 1) writes first: draining replies is what unblocks everyone
        progress |= flush_slot(slot, ci, events);

        // 2) reads, gated by backpressure: a client whose outbox is backed
        //    up (or that the caller put on hold) is not read.
        if slot.wants_read(cfg) {
            progress |= read_slot(slot, ci, cfg.max_frames_per_sweep, events);
        }
    }
    progress
}

/// Recompute and (re-)arm one connection's epoll interest:
///
/// * read-interest whenever the client may be read (open, not held, outbox
///   under [`ReactorConfig::max_outbox_frames`]);
/// * write-interest only while the outbox has parked bytes;
/// * **no** interest → the fd is *deregistered* (a held, drained client
///   must not wake the pump, not even via the always-reported
///   error/hangup events), and re-added when interest returns;
/// * a closed slot is deregistered permanently (the fd may outlive the
///   close on shared-doorbell in-proc connections, so auto-removal on fd
///   close cannot be relied on).
///
/// An `epoll_ctl` failure fails that connection only (like any transport
/// error).  Returns `true` when an event was pushed.
#[cfg(target_os = "linux")]
fn update_interest(
    conns: &mut [Slot],
    cfg: &ReactorConfig,
    st: &mut EpollState,
    ci: usize,
    events: &mut Vec<Event>,
) -> bool {
    let (fd, was_armed) = match st.reg[ci].as_ref() {
        Some(reg) => (reg.fd, reg.armed),
        None => return false,
    };
    if conns[ci].link.is_none() {
        // closed: deregister permanently (the fd may outlive the close on
        // shared-doorbell in-proc connections)
        if was_armed.is_some() {
            st.ep.del(fd);
        }
        st.reg[ci] = None;
        return false;
    }
    let desired = Interest {
        // the ONE read-gate definition (Slot::wants_read) keeps arming in
        // lockstep with both backends' service paths
        read: conns[ci].wants_read(cfg),
        write: conns[ci].pending() > 0,
    };
    if desired.is_none() {
        if was_armed.is_some() {
            st.ep.del(fd);
            if let Some(reg) = st.reg[ci].as_mut() {
                reg.armed = None;
            }
        }
        return false;
    }
    if was_armed == Some(desired) {
        return false;
    }
    let armed = if was_armed.is_some() {
        st.ep.modify(fd, ci as u64, desired)
    } else {
        st.ep.add(fd, ci as u64, desired)
    };
    match armed {
        Ok(()) => {
            if let Some(reg) = st.reg[ci].as_mut() {
                reg.armed = Some(desired);
            }
            false
        }
        Err(e) => {
            // an unarmable connection would never be serviced again: fail
            // it now, loudly, instead of letting it hang silently
            st.ep.del(fd);
            st.reg[ci] = None;
            conns[ci].link = None;
            events.push(Event::Error { client: ci, error: TransportError::Io(e) });
            true
        }
    }
}

/// One event-driven discovery pass: re-arm stale interest, block in
/// `epoll_wait` up to `timeout_ms`, then service exactly the connections
/// the OS reported ready (writes first, then gated reads, then re-arm).
/// Returns `Some(progress)`, or `None` when `epoll_wait` has failed
/// [`MAX_WAIT_FAILURES`] times in a row and the caller must degrade the
/// reactor to the sweep backend.
#[cfg(target_os = "linux")]
fn poll_epoll(
    conns: &mut [Slot],
    cfg: &ReactorConfig,
    st: &mut EpollState,
    events: &mut Vec<Event>,
    timeout_ms: i32,
) -> Option<bool> {
    let mut progress = false;

    // 0) apply deferred interest updates so the wait reflects current state
    while let Some(ci) = st.dirty.pop() {
        st.is_dirty[ci] = false;
        progress |= update_interest(conns, cfg, st, ci, events);
    }

    // 1) wait for readiness (level-triggered: nothing consumed is lost)
    let mut ready = std::mem::take(&mut st.ready);
    match st.ep.wait(&mut ready, timeout_ms) {
        Ok(_) => st.wait_failures = 0,
        Err(_) => {
            // cannot happen on a valid epfd; guard pathological
            // environments — a brief backoff bounds any retry spin, and a
            // persistent failure hands the reactor to the sweep backend
            // rather than spinning hot forever with no events
            st.ready = ready;
            st.wait_failures += 1;
            if st.wait_failures >= MAX_WAIT_FAILURES {
                return None;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
            return Some(progress);
        }
    }
    st.wakeups += 1;

    // 2) service exactly what the OS reported
    for r in &ready {
        if r.token == WAKER_TOKEN {
            // worker-pool wakeup: clear the counter; the serving loop
            // drains its completion channel right after this pass (anything
            // enqueued after the clear re-rings the level trigger)
            st.waker.clear();
            continue;
        }
        if r.token >= OPS_CONN_BASE {
            // ops-plane fd (listener or conn): it exists only to wake this
            // wait — the unconditional ops pump right after this pass does
            // the actual accept/read/write service
            continue;
        }
        let ci = r.token as usize;
        if ci >= conns.len() {
            continue;
        }
        {
            let slot = &mut conns[ci];
            if slot.link.is_some() {
                // writes first, exactly like the sweep
                progress |= flush_slot(slot, ci, events);
            }
        }
        {
            let slot = &mut conns[ci];
            if slot.wants_read(cfg) {
                progress |= read_slot(slot, ci, cfg.max_frames_per_sweep, events);
            }
        }
        // 3) re-arm this connection's interest for the next wait
        progress |= update_interest(conns, cfg, st, ci, events);
    }
    st.ready = ready;
    Some(progress)
}

// ---------------------------------------------------------------------------
// Ops control-plane pump (free functions so the backend and the ops state
// can be borrowed disjointly from the Reactor)
// ---------------------------------------------------------------------------

/// Accept pending ops connections and service every open one (reads, head
/// parsing, response flushing).  Runs unconditionally after each discovery
/// pass: under epoll the registered ops fds merely wake the wait early,
/// under sweep this *is* the polling.  Returns `true` on any progress.
fn pump_ops(
    backend: &mut BackendImpl,
    ops: &mut OpsState,
    requests: &mut Vec<OpsRequest>,
) -> bool {
    let mut progress = false;
    loop {
        match ops.listener.accept() {
            Ok((stream, _peer)) => {
                progress = true;
                if stream.set_nonblocking(true).is_err() {
                    continue; // dropped; the scraper retries
                }
                let open = ops.conns.iter().filter(|c| c.is_some()).count();
                if open >= MAX_OPS_CONNS {
                    continue; // at capacity: drop, never amplify
                }
                let conn = OpsConn {
                    stream,
                    inbuf: Vec::new(),
                    outbuf: Vec::new(),
                    out_off: 0,
                    head_done: false,
                    registered: false,
                };
                let oi = match ops.conns.iter().position(|c| c.is_none()) {
                    Some(i) => {
                        ops.conns[i] = Some(conn);
                        i
                    }
                    None => {
                        ops.conns.push(Some(conn));
                        ops.conns.len() - 1
                    }
                };
                ops_arm(backend, ops, oi, true, false);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break, // transient accept failure: retried next pass
        }
    }
    for oi in 0..ops.conns.len() {
        progress |= pump_ops_conn(backend, ops, oi, requests);
    }
    progress
}

/// Service one ops connection: read until the request head completes (then
/// surface it, or queue a canned error for garbage), and flush any queued
/// response bytes.  Returns `true` on any progress.
fn pump_ops_conn(
    backend: &mut BackendImpl,
    ops: &mut OpsState,
    oi: usize,
    requests: &mut Vec<OpsRequest>,
) -> bool {
    enum ReadOut {
        Blocked,
        HeadDone,
        Close,
        More,
    }
    let mut progress = false;
    loop {
        let out = {
            let Some(Some(c)) = ops.conns.get_mut(oi) else {
                return progress;
            };
            if c.head_done {
                ReadOut::HeadDone
            } else {
                let mut buf = [0u8; 1024];
                match c.stream.read(&mut buf) {
                    Ok(0) => ReadOut::Close,
                    Ok(n) => {
                        progress = true;
                        c.inbuf.extend_from_slice(&buf[..n]);
                        if let Some(end) = find_head_end(&c.inbuf) {
                            c.head_done = true;
                            match parse_request_head(&c.inbuf[..end]) {
                                Some((method, path)) => {
                                    requests.push(OpsRequest { conn: oi, method, path });
                                }
                                None => {
                                    c.outbuf = http_response(
                                        400,
                                        "Bad Request",
                                        "text/plain; charset=utf-8",
                                        b"malformed request\n",
                                    );
                                    c.out_off = 0;
                                }
                            }
                            ReadOut::HeadDone
                        } else if c.inbuf.len() > MAX_OPS_REQUEST_BYTES {
                            c.head_done = true;
                            c.outbuf = http_response(
                                431,
                                "Request Header Fields Too Large",
                                "text/plain; charset=utf-8",
                                b"request head too large\n",
                            );
                            c.out_off = 0;
                            ReadOut::HeadDone
                        } else {
                            ReadOut::More
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => ReadOut::Blocked,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => ReadOut::More,
                    Err(_) => ReadOut::Close,
                }
            }
        };
        match out {
            ReadOut::More => {}
            ReadOut::Blocked => break,
            ReadOut::HeadDone => {
                // head is in: stop watching reads — a surfaced request waits
                // on the serving loop for its answer, a canned error flushes
                // below (ops_flush_conn arms write interest if it parks)
                ops_disarm(backend, ops, oi);
                break;
            }
            ReadOut::Close => {
                ops_close_conn(backend, ops, oi);
                return true;
            }
        }
    }
    progress | ops_flush_conn(backend, ops, oi)
}

/// Flush one ops connection's queued response as far as the peer accepts.
/// A fully written response closes the connection (HTTP/1.0 semantics);
/// a partial write arms write interest so the epoll backend wakes when the
/// peer drains.  Returns `true` on any progress.
fn ops_flush_conn(backend: &mut BackendImpl, ops: &mut OpsState, oi: usize) -> bool {
    enum WriteOut {
        Idle,
        Blocked,
        Done,
        Close,
        More,
    }
    let mut progress = false;
    loop {
        let out = {
            let Some(Some(c)) = ops.conns.get_mut(oi) else {
                return progress;
            };
            if c.outbuf.is_empty() {
                WriteOut::Idle
            } else if c.out_off >= c.outbuf.len() {
                WriteOut::Done
            } else {
                match c.stream.write(&c.outbuf[c.out_off..]) {
                    Ok(0) => WriteOut::Close,
                    Ok(n) => {
                        c.out_off += n;
                        progress = true;
                        if c.out_off >= c.outbuf.len() {
                            WriteOut::Done
                        } else {
                            WriteOut::More
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => WriteOut::Blocked,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => WriteOut::More,
                    Err(_) => WriteOut::Close,
                }
            }
        };
        match out {
            WriteOut::More => {}
            WriteOut::Idle => return progress,
            WriteOut::Blocked => {
                ops_arm(backend, ops, oi, false, true);
                return progress;
            }
            WriteOut::Done => {
                // lingering close: discard any unread request bytes first so
                // the close does not RST the connection and risk zapping the
                // response bytes the peer has not yet consumed
                ops_linger_drain(ops, oi);
                ops_close_conn(backend, ops, oi);
                return true;
            }
            WriteOut::Close => {
                ops_close_conn(backend, ops, oi);
                return true;
            }
        }
    }
}

/// Best-effort, bounded read-and-discard of unread request bytes before a
/// normal close (the classic lingering-close move — closing with unread
/// data queued makes TCP reset the connection, which can discard the
/// in-flight response on the peer's side).
fn ops_linger_drain(ops: &mut OpsState, oi: usize) {
    if let Some(Some(c)) = ops.conns.get_mut(oi) {
        let mut scratch = [0u8; 4096];
        for _ in 0..16 {
            match c.stream.read(&mut scratch) {
                Ok(0) => break,
                Ok(_) => {}
                Err(_) => break,
            }
        }
    }
}

/// (Re-)register one ops connection fd with the requested interest (epoll
/// backend; a no-op under sweep).  Best-effort: on `epoll_ctl` failure the
/// unconditional pump still services the connection every pass, just
/// without event-driven latency.
fn ops_arm(_backend: &mut BackendImpl, _ops: &mut OpsState, _oi: usize, _read: bool, _write: bool) {
    #[cfg(target_os = "linux")]
    if let BackendImpl::Epoll(st) = _backend {
        if let Some(Some(c)) = _ops.conns.get_mut(_oi) {
            use std::os::unix::io::AsRawFd;
            let fd = c.stream.as_raw_fd();
            let token = OPS_CONN_BASE + _oi as u64;
            let interest = Interest { read: _read, write: _write };
            c.registered = if c.registered {
                st.ep.modify(fd, token, interest).is_ok()
            } else {
                st.ep.add(fd, token, interest).is_ok()
            };
        }
    }
}

/// Deregister one ops connection fd from the epoll backend (no-op under
/// sweep or when never registered).
fn ops_disarm(_backend: &mut BackendImpl, _ops: &mut OpsState, _oi: usize) {
    #[cfg(target_os = "linux")]
    if let BackendImpl::Epoll(st) = _backend {
        if let Some(Some(c)) = _ops.conns.get_mut(_oi) {
            if c.registered {
                use std::os::unix::io::AsRawFd;
                st.ep.del(c.stream.as_raw_fd());
                c.registered = false;
            }
        }
    }
}

/// Deregister and drop one ops connection (dropping the stream closes the
/// fd; the slot is reused by the next accept).
fn ops_close_conn(backend: &mut BackendImpl, ops: &mut OpsState, oi: usize) {
    ops_disarm(backend, ops, oi);
    if let Some(slot) = ops.conns.get_mut(oi) {
        *slot = None;
    }
}

/// Byte offset just past the `\r\n\r\n` head terminator, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Parse `METHOD PATH ...` off the request line; `None` for garbage (which
/// the pump answers with a canned `400`).
fn parse_request_head(head: &[u8]) -> Option<(String, String)> {
    let text = std::str::from_utf8(head).ok()?;
    let line = text.lines().next()?;
    let mut it = line.split_whitespace();
    let method = it.next()?.to_string();
    let path = it.next()?.to_string();
    Some((method, path))
}

/// Assemble one complete `HTTP/1.0` response with explicit length and
/// `Connection: close` (the ops plane never keeps connections alive).
fn http_response(status: u16, reason: &str, content_type: &str, body: &[u8]) -> Vec<u8> {
    let head = format!(
        "HTTP/1.0 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let mut out = Vec::with_capacity(head.len() + body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::transport::{inproc_reactor_pair, Transport};
    use std::net::TcpListener;

    fn feat(step: u64, n: usize) -> Msg {
        Msg::Features { step, tensor: Tensor::from_vec(&[n], (0..n).map(|i| i as f32).collect()) }
    }

    fn cfg_with(backend: ReadinessBackend) -> ReactorConfig {
        ReactorConfig { backend, ..ReactorConfig::default() }
    }

    /// Backends every roundtrip-style test runs through on this platform.
    fn backends() -> Vec<ReadinessBackend> {
        if ReadinessBackend::Epoll.supported() {
            vec![ReadinessBackend::Sweep, ReadinessBackend::Epoll]
        } else {
            vec![ReadinessBackend::Sweep]
        }
    }

    #[test]
    fn inproc_reactor_roundtrip_all_backends() {
        for backend in backends() {
            let (mut edge, cloud) = inproc_reactor_pair();
            let mut reactor = Reactor::new(vec![Box::new(cloud)], cfg_with(backend));
            assert_eq!(reactor.backend(), backend, "requested backend must engage");
            edge.send(&feat(1, 8)).unwrap();
            let mut events = Vec::new();
            assert!(reactor.poll(&mut events));
            match events.as_slice() {
                [Event::Msg { client: 0, msg }] => assert_eq!(msg, &feat(1, 8)),
                other => panic!("unexpected events {other:?}"),
            }
            // reply path: queue + flush, edge receives
            reactor.queue_frame(0, wire::encode(&Msg::KeySeed { seed: 7 }));
            events.clear();
            reactor.poll(&mut events);
            assert_eq!(reactor.outbox_len(0), 0);
            assert_eq!(edge.recv().unwrap(), Msg::KeySeed { seed: 7 });
            // accounting: both halves agree
            assert_eq!(edge.stats().tx(), reactor.stats(0).rx());
            assert_eq!(edge.stats().rx(), reactor.stats(0).tx());
            assert!(reactor.wakeups() > 0, "discovery passes are counted");
        }
    }

    #[test]
    fn closed_peer_surfaces_as_event_all_backends() {
        for backend in backends() {
            let (edge, cloud) = inproc_reactor_pair();
            let mut reactor = Reactor::new(vec![Box::new(cloud)], cfg_with(backend));
            drop(edge); // drop rings the doorbell, so epoll observes it too
            let mut events = Vec::new();
            reactor.poll(&mut events);
            assert!(
                matches!(events.as_slice(), [Event::Closed { client: 0 }]),
                "{backend:?}: {events:?}"
            );
            assert!(!reactor.is_open(0));
            assert_eq!(reactor.open_count(), 0);
        }
    }

    #[test]
    fn backpressure_pauses_reads_until_outbox_drains() {
        let (mut edge, cloud) = inproc_reactor_pair();
        let cfg = ReactorConfig { max_outbox_frames: 2, ..ReactorConfig::default() };
        let mut reactor = Reactor::new(vec![Box::new(cloud)], cfg);
        // NbInProc::poll_send always drains (channel sends never block), so
        // force a backlog via hold=false but pending frames: queue 3 replies
        // without polling, then confirm the read gate sees the depth.
        for s in 0..3u64 {
            reactor.queue_frame(0, wire::encode(&Msg::KeySeed { seed: s }));
        }
        assert_eq!(reactor.outbox_len(0), 3);
        edge.send(&feat(0, 4)).unwrap();
        let mut events = Vec::new();
        // Poll: writes flush first (in-proc never blocks), after which the
        // read gate reopens and the frame arrives — the TCP case where the
        // flush stalls is exercised end-to-end in tests/multi_edge.rs.
        reactor.poll(&mut events);
        assert_eq!(reactor.outbox_len(0), 0);
        assert!(events.iter().any(|e| matches!(e, Event::Msg { .. })));
        for _ in 0..3 {
            edge.recv().unwrap();
        }
    }

    #[test]
    fn hold_gates_reads_all_backends() {
        for backend in backends() {
            let (mut edge, cloud) = inproc_reactor_pair();
            let mut reactor = Reactor::new(vec![Box::new(cloud)], cfg_with(backend));
            edge.send(&feat(0, 4)).unwrap();
            reactor.set_hold(0, true);
            let mut events = Vec::new();
            reactor.poll(&mut events);
            assert!(events.is_empty(), "{backend:?}: held client must not be read");
            reactor.set_hold(0, false);
            reactor.poll(&mut events);
            assert_eq!(events.len(), 1, "{backend:?}: unheld client delivers");
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_blocks_instead_of_sweeping_when_idle() {
        // The tentpole property, at the unit level: with one idle
        // connection, a blocking poll_wait performs exactly ONE wakeup per
        // call (the wait itself) instead of a timed sweep train, and a
        // doorbell ring cuts the block short.
        let (mut edge, cloud) = inproc_reactor_pair();
        let mut reactor =
            Reactor::new(vec![Box::new(cloud)], cfg_with(ReadinessBackend::Epoll));
        assert_eq!(reactor.backend(), ReadinessBackend::Epoll);
        let mut events = Vec::new();

        // idle: one blocking pass, one wakeup, zero events
        let w0 = reactor.wakeups();
        let t0 = std::time::Instant::now();
        assert!(!reactor.poll_wait(&mut events, 60));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(40), "must block");
        assert_eq!(reactor.wakeups(), w0 + 1, "idle block is a single wakeup");
        assert!(events.is_empty());

        // a frame sent mid-block wakes it early
        let t0 = std::time::Instant::now();
        let send = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            edge.send(&feat(3, 4)).unwrap();
            edge
        });
        assert!(reactor.poll_wait(&mut events, 5_000));
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "doorbell must cut the block short"
        );
        assert_eq!(events.len(), 1);
        let _edge = send.join().unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_waker_wakes_blocking_poll() {
        // The worker-completion path: a waker ring — even one that lands
        // BEFORE the pump blocks — pulls poll_wait out of epoll_wait.
        let (_edge, cloud) = inproc_reactor_pair();
        let mut reactor =
            Reactor::new(vec![Box::new(cloud)], cfg_with(ReadinessBackend::Epoll));
        let waker = reactor.waker();
        assert!(waker.is_armed());
        let mut events = Vec::new();

        // ring happens-before the wait: must not sleep out the timeout
        waker.wake();
        let t0 = std::time::Instant::now();
        reactor.poll_wait(&mut events, 5_000);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(1),
            "pre-block ring must wake the pump (lost-wakeup race)"
        );

        // ring from another thread mid-block
        let w = waker.clone();
        let ringer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            w.wake();
        });
        let t0 = std::time::Instant::now();
        reactor.poll_wait(&mut events, 5_000);
        ringer.join().unwrap();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "mid-block ring must wake the pump"
        );
    }

    #[test]
    fn sweep_waker_is_noop() {
        let (_edge, cloud) = inproc_reactor_pair();
        let reactor = Reactor::new(vec![Box::new(cloud)], cfg_with(ReadinessBackend::Sweep));
        assert_eq!(reactor.backend(), ReadinessBackend::Sweep);
        assert!(!reactor.waker().is_armed());
    }

    #[test]
    fn nbtcp_reassembles_partial_frames() {
        // Feed a frame through the socket one byte at a time: the reactor
        // side must park partial state between polls and still deliver one
        // intact frame (plus correct byte accounting with the prefix).
        let addr = "127.0.0.1:39391";
        let listener = TcpListener::bind(addr).unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = NbTcp::from_stream(stream).unwrap();
        #[cfg(unix)]
        assert!(conn.readiness_fd().is_some(), "a socket is always pollable");

        let msg = feat(3, 16);
        let frame = wire::encode(&msg);
        let mut on_wire = Vec::new();
        on_wire.extend_from_slice(&(frame.len() as u32).to_le_bytes());
        on_wire.extend_from_slice(&frame);

        let mut got = None;
        for (i, byte) in on_wire.iter().enumerate() {
            client.write_all(std::slice::from_ref(byte)).unwrap();
            client.flush().unwrap();
            // give the kernel a moment to make the byte readable
            for _ in 0..200 {
                match conn.poll_recv().unwrap() {
                    PollIn::Frame(f) => {
                        got = Some(f);
                        break;
                    }
                    PollIn::Idle => {
                        if i + 1 < on_wire.len() {
                            break; // more bytes still to send
                        }
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    PollIn::Closed => panic!("unexpected close"),
                }
            }
        }
        let got = got.expect("frame must complete after the last byte");
        assert_eq!(wire::decode(&got).unwrap(), msg);
        assert_eq!(conn.stats().rx(), on_wire.len() as u64);
    }

    #[test]
    fn nbtcp_rejects_zero_and_oversized_prefixes() {
        let addr = "127.0.0.1:39392";
        let listener = TcpListener::bind(addr).unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = NbTcp::from_stream(stream).unwrap();

        client.write_all(&0u32.to_le_bytes()).unwrap();
        client.flush().unwrap();
        let err = loop {
            match conn.poll_recv() {
                Ok(PollIn::Idle) => std::thread::sleep(std::time::Duration::from_millis(1)),
                Ok(other) => panic!("expected error, got {other:?}"),
                Err(e) => break e,
            }
        };
        assert!(matches!(err, TransportError::EmptyFrame), "{err:?}");

        // oversized prefix on a fresh pair
        let addr = "127.0.0.1:39393";
        let listener = TcpListener::bind(addr).unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = NbTcp::from_stream(stream).unwrap();
        client.write_all(&u32::MAX.to_le_bytes()).unwrap();
        client.flush().unwrap();
        let err = loop {
            match conn.poll_recv() {
                Ok(PollIn::Idle) => std::thread::sleep(std::time::Duration::from_millis(1)),
                Ok(other) => panic!("expected error, got {other:?}"),
                Err(e) => break e,
            }
        };
        assert!(matches!(err, TransportError::FrameTooLarge(_)), "{err:?}");
    }

    /// Pump the reactor until `done` reports success (bounded; panics on a
    /// stuck ops plane).
    fn pump_until<F: FnMut(&mut Reactor) -> bool>(reactor: &mut Reactor, mut done: F) {
        let mut events = Vec::new();
        for _ in 0..2_000 {
            reactor.poll_wait(&mut events, 5);
            if done(reactor) {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        panic!("ops pump did not reach the expected state in time");
    }

    #[test]
    fn ops_listener_serves_requests_all_backends() {
        for backend in backends() {
            let (_edge, cloud) = inproc_reactor_pair();
            let mut reactor = Reactor::new(vec![Box::new(cloud)], cfg_with(backend));
            assert_eq!(reactor.backend(), backend);
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            reactor.serve_ops(listener).unwrap();
            let addr = reactor.ops_local_addr().expect("listener bound");

            let mut client = std::net::TcpStream::connect(addr).unwrap();
            client.write_all(b"GET /healthz HTTP/1.0\r\n\r\n").unwrap();

            let mut reqs = Vec::new();
            pump_until(&mut reactor, |r| {
                reqs.extend(r.take_ops_requests());
                !reqs.is_empty()
            });
            assert_eq!(reqs.len(), 1, "{backend:?}");
            assert_eq!(reqs[0].method, "GET", "{backend:?}");
            assert_eq!(reqs[0].path, "/healthz", "{backend:?}");

            reactor.ops_respond(reqs[0].conn, 200, "OK", "text/plain; charset=utf-8", b"ok\n");
            // flush any parked remainder; the conn closes after the response
            let mut events = Vec::new();
            for _ in 0..50 {
                reactor.poll_wait(&mut events, 1);
            }
            client
                .set_read_timeout(Some(std::time::Duration::from_secs(10)))
                .unwrap();
            let mut resp = String::new();
            client.read_to_string(&mut resp).unwrap();
            assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{backend:?}: {resp}");
            assert!(resp.contains("Content-Length: 3\r\n"), "{resp}");
            assert!(resp.ends_with("\r\n\r\nok\n"), "{resp}");
        }
    }

    #[test]
    fn ops_garbage_requests_get_canned_errors() {
        let (_edge, cloud) = inproc_reactor_pair();
        let mut reactor = Reactor::new(vec![Box::new(cloud)], cfg_with(ReadinessBackend::Sweep));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        reactor.serve_ops(listener).unwrap();
        let addr = reactor.ops_local_addr().expect("listener bound");
        let mut events = Vec::new();

        // a head with no path token: canned 400, no request surfaced
        let mut bad = std::net::TcpStream::connect(addr).unwrap();
        bad.write_all(b"nonsense\r\n\r\n").unwrap();
        for _ in 0..100 {
            reactor.poll_wait(&mut events, 1);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(reactor.take_ops_requests().is_empty(), "garbage surfaces no request");
        bad.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
        let mut resp = String::new();
        bad.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 400 "), "{resp}");

        // a head that never terminates within the cap: canned 431
        let mut big = std::net::TcpStream::connect(addr).unwrap();
        big.write_all(&vec![b'a'; MAX_OPS_REQUEST_BYTES + 1024]).unwrap();
        for _ in 0..100 {
            reactor.poll_wait(&mut events, 1);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        big.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
        let mut resp = String::new();
        big.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 431 "), "{resp}");
        assert!(reactor.take_ops_requests().is_empty());
    }

    #[test]
    fn ops_reload_setters_clamp_and_apply() {
        let (_edge, cloud) = inproc_reactor_pair();
        let mut reactor = Reactor::new(vec![Box::new(cloud)], cfg_with(ReadinessBackend::Sweep));
        reactor.set_max_outbox_frames(0); // clamped like ReactorConfig::clamped
        assert_eq!(reactor.config().max_outbox_frames, 1);
        reactor.set_max_outbox_frames(32);
        assert_eq!(reactor.config().max_outbox_frames, 32);
        reactor.set_poll_sleep_us(250);
        assert_eq!(reactor.config().poll_sleep_us, 250);
        // the reactor still pumps normally after a retune
        let mut events = Vec::new();
        reactor.poll(&mut events);
    }
}
