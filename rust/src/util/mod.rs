//! Utility substrates: errors, PRNG, JSON, timing, property-testing
//! harness, tolerance assertions, CSV, bench-gate policy, and the
//! deterministic-interleaving scheduler for concurrency tests.

pub mod bench;
pub mod csv;
pub mod error;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod sched;
pub mod testing;
pub mod timer;
