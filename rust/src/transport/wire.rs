//! Wire format: compact binary framing for `Msg`.
//!
//! Frame layout (little-endian):
//!   [tag u8][body...]
//! Tensors:  [ndim u8][dims u32 × ndim][len u32][f32 × len]
//! Labels:   [len u32][i32 × len]
//!
//! Decoding is fully checked (no panics on malformed input) — fuzzed in the
//! tests below.

use std::fmt;

use crate::tensor::{Labels, Tensor};
use crate::transport::Msg;

/// Decode failure on a single wire frame.
#[derive(Debug)]
pub enum WireError {
    /// Frame ended at this byte offset before the message was complete.
    Truncated(usize),
    /// Unknown message tag byte.
    UnknownTag(u8),
    /// Announced element count exceeds [`MAX_ELEMS`] or contradicts shape.
    TooLarge(u64),
    /// A [`Msg::Sequenced`] envelope enveloping another envelope.  The
    /// sequencing layer stamps exactly one sequence number per data frame;
    /// nesting can only be a corrupt or malicious peer.
    NestedSequence,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated(pos) => write!(f, "truncated frame at byte {pos}"),
            WireError::UnknownTag(tag) => write!(f, "unknown tag {tag}"),
            WireError::TooLarge(n) => write!(f, "tensor too large: {n} elements"),
            WireError::NestedSequence => write!(f, "nested sequenced envelope"),
        }
    }
}

impl std::error::Error for WireError {}

const TAG_FEATURES: u8 = 1;
const TAG_TRAIN_LABELS: u8 = 2;
const TAG_GRADIENTS: u8 = 3;
const TAG_STEP_STATS: u8 = 4;
const TAG_EVAL_FEATURES: u8 = 5;
const TAG_EVAL_STATS: u8 = 6;
const TAG_KEY_SEED: u8 = 7;
const TAG_SHUTDOWN: u8 = 8;
const TAG_KEY_SHARD: u8 = 9;
const TAG_SHARD_CHALLENGE: u8 = 10;
const TAG_SHARD_HELLO: u8 = 11;
const TAG_SEQUENCED: u8 = 12;
const TAG_RESUME: u8 = 13;
const TAG_RESUME_OK: u8 = 14;

/// Hard cap on decoded element counts (guards fuzz/corruption OOM).
pub const MAX_ELEMS: u64 = 1 << 28;

/// Hard cap on a single wire frame, consistent with [`MAX_ELEMS`]: the
/// largest frame `decode` can accept is `EvalFeatures` carrying a
/// MAX_ELEMS-element tensor *and* a MAX_ELEMS-entry labels vector (the
/// decoder caps each independently), 4 bytes per element on both, plus
/// header slack.  Transports must reject any length prefix above this
/// *before* allocating — a corrupt or malicious peer must not be able to
/// force an unbounded allocation.
///
/// The admissible frame-size range is `1 ..= MAX_FRAME_BYTES`: the smallest
/// message (`Shutdown`) encodes to exactly one tag byte, so a zero-length
/// frame can never be produced by `encode` and transports reject a zero
/// length prefix outright (`transport::check_frame_len`).
pub const MAX_FRAME_BYTES: usize = 8 * MAX_ELEMS as usize + 4096;

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

/// Serialize one message to its wire frame (always ≥ 1 byte: the tag).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    match msg {
        Msg::Features { step, tensor } => {
            out.push(TAG_FEATURES);
            put_u64(&mut out, *step);
            put_tensor(&mut out, tensor);
        }
        Msg::TrainLabels { step, labels } => {
            out.push(TAG_TRAIN_LABELS);
            put_u64(&mut out, *step);
            put_labels(&mut out, labels);
        }
        Msg::Gradients { step, tensor } => {
            out.push(TAG_GRADIENTS);
            put_u64(&mut out, *step);
            put_tensor(&mut out, tensor);
        }
        Msg::StepStats { step, loss, ncorrect } => {
            out.push(TAG_STEP_STATS);
            put_u64(&mut out, *step);
            put_f32(&mut out, *loss);
            put_f32(&mut out, *ncorrect);
        }
        Msg::EvalFeatures { step, tensor, labels } => {
            out.push(TAG_EVAL_FEATURES);
            put_u64(&mut out, *step);
            put_tensor(&mut out, tensor);
            put_labels(&mut out, labels);
        }
        Msg::EvalStats { step, loss, ncorrect } => {
            out.push(TAG_EVAL_STATS);
            put_u64(&mut out, *step);
            put_f32(&mut out, *loss);
            put_f32(&mut out, *ncorrect);
        }
        Msg::KeySeed { seed } => {
            out.push(TAG_KEY_SEED);
            put_u64(&mut out, *seed);
        }
        Msg::ShardHello => out.push(TAG_SHARD_HELLO),
        Msg::ShardChallenge { nonce } => {
            out.push(TAG_SHARD_CHALLENGE);
            put_u64(&mut out, *nonce);
        }
        Msg::KeyShard { client_id, epoch, proof } => {
            out.push(TAG_KEY_SHARD);
            put_u64(&mut out, *client_id);
            put_u64(&mut out, *epoch);
            put_u64(&mut out, *proof);
        }
        Msg::Shutdown => out.push(TAG_SHUTDOWN),
        Msg::Sequenced { seq, inner } => {
            debug_assert!(
                !matches!(**inner, Msg::Sequenced { .. }),
                "sequenced envelopes never nest"
            );
            out.push(TAG_SEQUENCED);
            put_u64(&mut out, *seq);
            out.extend_from_slice(&encode(inner));
        }
        Msg::Resume { client_id, epoch, last_acked_step, proof } => {
            out.push(TAG_RESUME);
            put_u64(&mut out, *client_id);
            put_u64(&mut out, *epoch);
            put_u64(&mut out, *last_acked_step);
            put_u64(&mut out, *proof);
        }
        Msg::ResumeOk { resume_step } => {
            out.push(TAG_RESUME_OK);
            put_u64(&mut out, *resume_step);
        }
    }
    out
}

/// Build a `[`[`Msg::Sequenced`]`]` wire frame around an already-encoded
/// inner frame without re-decoding it — the reactor's codec workers hand the
/// serve loop finished frames, and the sequencing layer stamps them on the
/// way out.
pub fn seq_frame(seq: u64, inner: &[u8]) -> Vec<u8> {
    debug_assert_ne!(inner.first(), Some(&TAG_SEQUENCED), "sequenced envelopes never nest");
    let mut out = Vec::with_capacity(9 + inner.len());
    out.push(TAG_SEQUENCED);
    put_u64(&mut out, seq);
    out.extend_from_slice(inner);
    out
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.push(t.ndim() as u8);
    for &d in t.shape() {
        put_u32(out, d as u32);
    }
    put_u32(out, t.len() as u32);
    out.reserve(t.len() * 4);
    for &v in t.data() {
        put_f32(out, v);
    }
}

fn put_labels(out: &mut Vec<u8>, l: &Labels) {
    put_u32(out, l.0.len() as u32);
    for &v in &l.0 {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

// ---------------------------------------------------------------------------
// Decoding (checked)
// ---------------------------------------------------------------------------

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.b.len() {
            return Err(WireError::Truncated(self.pos));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn tensor(&mut self) -> Result<Tensor, WireError> {
        let ndim = self.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        let mut prod: u64 = 1;
        for _ in 0..ndim {
            let d = self.u32()? as usize;
            prod = prod.saturating_mul(d as u64);
            shape.push(d);
        }
        let len = self.u32()? as u64;
        if len != prod || len > MAX_ELEMS {
            return Err(WireError::TooLarge(len));
        }
        let bytes = self.take(len as usize * 4)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Tensor::from_vec(&shape, data))
    }

    fn labels(&mut self) -> Result<Labels, WireError> {
        let len = self.u32()? as u64;
        if len > MAX_ELEMS {
            return Err(WireError::TooLarge(len));
        }
        let bytes = self.take(len as usize * 4)?;
        Ok(Labels(
            bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ))
    }
}

/// Decode one wire frame; fully checked, never panics on malformed input.
pub fn decode(frame: &[u8]) -> Result<Msg, WireError> {
    let mut r = Reader { b: frame, pos: 0 };
    let tag = r.u8()?;
    if tag == TAG_SEQUENCED {
        let seq = r.u64()?;
        let inner_tag = r.u8()?;
        if inner_tag == TAG_SEQUENCED {
            return Err(WireError::NestedSequence);
        }
        let inner = decode_body(&mut r, inner_tag)?;
        return Ok(Msg::Sequenced { seq, inner: Box::new(inner) });
    }
    decode_body(&mut r, tag)
}

/// Decode the body of one non-envelope message after its tag byte.  Shared
/// by the top-level frame path and the single permitted envelope level —
/// deliberately NOT recursive, so nesting depth is bounded by construction.
fn decode_body(r: &mut Reader<'_>, tag: u8) -> Result<Msg, WireError> {
    let msg = match tag {
        TAG_FEATURES => Msg::Features { step: r.u64()?, tensor: r.tensor()? },
        TAG_TRAIN_LABELS => Msg::TrainLabels { step: r.u64()?, labels: r.labels()? },
        TAG_GRADIENTS => Msg::Gradients { step: r.u64()?, tensor: r.tensor()? },
        TAG_STEP_STATS => Msg::StepStats {
            step: r.u64()?,
            loss: r.f32()?,
            ncorrect: r.f32()?,
        },
        TAG_EVAL_FEATURES => Msg::EvalFeatures {
            step: r.u64()?,
            tensor: r.tensor()?,
            labels: r.labels()?,
        },
        TAG_EVAL_STATS => Msg::EvalStats {
            step: r.u64()?,
            loss: r.f32()?,
            ncorrect: r.f32()?,
        },
        TAG_KEY_SEED => Msg::KeySeed { seed: r.u64()? },
        TAG_SHARD_HELLO => Msg::ShardHello,
        TAG_SHARD_CHALLENGE => Msg::ShardChallenge { nonce: r.u64()? },
        TAG_KEY_SHARD => Msg::KeyShard {
            client_id: r.u64()?,
            epoch: r.u64()?,
            proof: r.u64()?,
        },
        TAG_SHUTDOWN => Msg::Shutdown,
        TAG_RESUME => Msg::Resume {
            client_id: r.u64()?,
            epoch: r.u64()?,
            last_acked_step: r.u64()?,
            proof: r.u64()?,
        },
        TAG_RESUME_OK => Msg::ResumeOk { resume_step: r.u64()? },
        t => return Err(WireError::UnknownTag(t)),
    };
    Ok(msg)
}

/// Serialized payload size of a feature/gradient tensor message — the number
/// the communication benches report.
pub fn tensor_msg_bytes(t: &Tensor) -> usize {
    encode(&Msg::Features { step: 0, tensor: t.clone() }).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_tensor_shapes() {
        Prop::new("wire roundtrip", 50).run(|g| {
            let ndim = g.usize_in(1, 3);
            let shape: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 8)).collect();
            let n: usize = shape.iter().product();
            let t = Tensor::from_vec(&shape, g.vec_normal(n, 0.0, 2.0));
            let m = Msg::Features { step: g.usize_in(0, 1000) as u64, tensor: t };
            assert_eq!(decode(&encode(&m)).unwrap(), m);
        });
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut rng = Rng::new(0xF422);
        for _ in 0..2000 {
            let len = rng.below(128);
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            let _ = decode(&bytes); // must not panic
        }
    }

    #[test]
    fn truncation_detected() {
        let m = Msg::Features {
            step: 1,
            tensor: Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]),
        };
        let f = encode(&m);
        for cut in 1..f.len() {
            assert!(decode(&f[..cut]).is_err(), "cut={cut} should fail");
        }
    }

    #[test]
    fn shape_data_mismatch_detected() {
        // craft a frame whose dims product ≠ len
        let m = Msg::Features {
            step: 0,
            tensor: Tensor::from_vec(&[4], vec![0.0; 4]),
        };
        let mut f = encode(&m);
        // dims start at byte 1+8+1 = 10; set dim to 5 while len stays 4
        f[10] = 5;
        assert!(decode(&f).is_err());
    }

    #[test]
    fn frame_size_boundaries() {
        // empty frame: never produced by encode, always rejected by decode
        assert!(decode(&[]).is_err());
        // 1 byte is the smallest frame and round-trips
        let f = encode(&Msg::Shutdown);
        assert_eq!(f.len(), 1);
        assert_eq!(decode(&f).unwrap(), Msg::Shutdown);
        // the cap sits above the largest decodable message (tensor + labels
        // at MAX_ELEMS each, 4 bytes per element) with header slack
        assert!(MAX_FRAME_BYTES as u64 >= 8 * MAX_ELEMS);
    }

    #[test]
    fn key_shard_roundtrip_and_truncation() {
        let m = Msg::KeyShard {
            client_id: 17,
            epoch: 3,
            proof: 0xDEAD_BEEF_CAFE_F00D,
        };
        let f = encode(&m);
        // tag + three u64 fields, nothing more
        assert_eq!(f.len(), 1 + 8 * 3);
        assert_eq!(decode(&f).unwrap(), m);
        for cut in 1..f.len() {
            assert!(decode(&f[..cut]).is_err(), "cut={cut} should fail");
        }
    }

    #[test]
    fn shard_challenge_roundtrip_and_truncation() {
        let m = Msg::ShardChallenge { nonce: 0x0123_4567_89AB_CDEF };
        let f = encode(&m);
        // tag + one u64 nonce, nothing more
        assert_eq!(f.len(), 1 + 8);
        assert_eq!(decode(&f).unwrap(), m);
        for cut in 1..f.len() {
            assert!(decode(&f[..cut]).is_err(), "cut={cut} should fail");
        }
        // the hello is a bare tag, like Shutdown
        let f = encode(&Msg::ShardHello);
        assert_eq!(f.len(), 1);
        assert_eq!(decode(&f).unwrap(), Msg::ShardHello);
    }

    #[test]
    fn sequenced_roundtrip_and_truncation() {
        let inner = Msg::Gradients {
            step: 9,
            tensor: Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]),
        };
        let m = Msg::Sequenced { seq: 41, inner: Box::new(inner.clone()) };
        let f = encode(&m);
        assert_eq!(decode(&f).unwrap(), m);
        for cut in 1..f.len() {
            assert!(decode(&f[..cut]).is_err(), "cut={cut} should fail");
        }
        // the envelope is exactly tag + seq prepended to the inner frame,
        // and seq_frame builds the identical bytes from the encoded inner
        assert_eq!(f.len(), 1 + 8 + encode(&inner).len());
        assert_eq!(seq_frame(41, &encode(&inner)), f);
    }

    #[test]
    fn nested_sequence_rejected() {
        let inner = encode(&Msg::Sequenced { seq: 1, inner: Box::new(Msg::Shutdown) });
        let f = seq_frame(0, &inner); // forged: encode() would assert
        assert!(matches!(decode(&f), Err(WireError::NestedSequence)));
    }

    #[test]
    fn resume_roundtrip_and_truncation() {
        let m = Msg::Resume {
            client_id: 5,
            epoch: 2,
            last_acked_step: 117,
            proof: 0xFACE_0FF5_1DE5_EED5,
        };
        let f = encode(&m);
        // tag + four u64 fields, nothing more
        assert_eq!(f.len(), 1 + 8 * 4);
        assert_eq!(decode(&f).unwrap(), m);
        for cut in 1..f.len() {
            assert!(decode(&f[..cut]).is_err(), "cut={cut} should fail");
        }
        let ok = Msg::ResumeOk { resume_step: 118 };
        let f = encode(&ok);
        assert_eq!(f.len(), 1 + 8);
        assert_eq!(decode(&f).unwrap(), ok);
    }

    #[test]
    fn bytes_accounting_matches_payload() {
        let t = Tensor::zeros(&[8, 32]);
        let n = tensor_msg_bytes(&t);
        // 1 tag + 8 step + 1 ndim + 8 dims + 4 len + 1024 data
        assert_eq!(n, 1 + 8 + 1 + 8 + 4 + 8 * 32 * 4);
    }
}
