//! Crosstalk analysis (our ablation of the paper's Eq. 4): how does the
//! decode error decompose into self-unbinding noise vs crosstalk from the
//! other R−1 bound features, as R and D vary?
//!
//! Validates the quasi-orthogonality argument of §3.1: crosstalk relative
//! energy grows ≈ √(R−1) and shrinks ≈ 1/√D-ish in cosine terms, which is
//! why accuracy stays flat up to R=8 and droops at R=16 in Table 1.
//!
//!   cargo run --release --example crosstalk_analysis
//!
//! Writes runs/crosstalk.csv (columns: d, r, rel_recon_err, rel_crosstalk,
//! mean_cos).

use c3sl::util::error::Result;

use c3sl::hdc::{crosstalk_report, Backend, KeySet, C3};
use c3sl::tensor::Tensor;
use c3sl::util::csv::CsvWriter;
use c3sl::util::rng::Rng;

fn main() -> Result<()> {
    let ds = [256usize, 512, 1024, 2048, 4096, 8192];
    let rs = [1usize, 2, 4, 8, 16, 32, 64];
    let trials = 3;

    let mut w = CsvWriter::create(
        "runs/crosstalk.csv",
        &["d", "r", "rel_recon_err", "rel_crosstalk", "mean_cos"],
    )?;

    println!("Eq. (4) crosstalk decomposition, averaged over {trials} key draws\n");
    println!("{:>6} {:>4} {:>15} {:>15} {:>10}", "D", "R", "recon err", "crosstalk", "cos");
    let mut rng = Rng::new(0xE94);
    for &d in &ds {
        for &r in &rs {
            let (mut e1, mut e2, mut c) = (0.0f64, 0.0f64, 0.0f64);
            for _ in 0..trials {
                let keys = KeySet::generate(&mut rng, r, d);
                let c3 = C3::new(keys, Backend::Auto);
                let mut z = vec![0.0f32; r * d];
                rng.fill_normal(&mut z, 0.0, 1.0);
                let rep = crosstalk_report(&c3, &Tensor::from_vec(&[r, d], z));
                e1 += rep.rel_recon_err as f64;
                e2 += rep.rel_crosstalk as f64;
                c += rep.mean_cos as f64;
            }
            let (e1, e2, c) = (e1 / trials as f64, e2 / trials as f64, c / trials as f64);
            w.row_f64(&[d as f64, r as f64, e1, e2, c])?;
            if d == 2048 || r <= 2 {
                println!("{d:>6} {r:>4} {e1:>15.4} {e2:>15.4} {c:>10.4}");
            }
        }
    }
    w.flush()?;

    // Scaling check: crosstalk ∝ √(R−1) at fixed D.
    println!("\nscaling at D=2048: crosstalk relative energy vs √(R−1)");
    let d = 2048;
    for &r in &[2usize, 4, 8, 16, 32] {
        let keys = KeySet::generate(&mut rng, r, d);
        let c3 = C3::new(keys, Backend::Auto);
        let mut z = vec![0.0f32; r * d];
        rng.fill_normal(&mut z, 0.0, 1.0);
        let rep = crosstalk_report(&c3, &Tensor::from_vec(&[r, d], z));
        println!(
            "  R={r:<3} crosstalk={:.3}  crosstalk/√(R−1)={:.3}",
            rep.rel_crosstalk,
            rep.rel_crosstalk as f64 / ((r - 1) as f64).sqrt()
        );
    }
    println!("\nfull grid → runs/crosstalk.csv");
    Ok(())
}
