//! Crate-local error substrate (no anyhow/thiserror — offline builds must
//! work with zero external crates).
//!
//! `C3Error` is a message-chain error: [`Context::context`] /
//! [`Context::with_context`] prepend a layer exactly like anyhow's, and the
//! [`ensure!`](crate::ensure) / [`bail!`](crate::bail) macros keep call
//! sites terse.  Module-specific errors (`WireError`, `TransportError`,
//! `ConfigError`, ...) implement `Display`/`Error` by hand and convert into
//! `C3Error` so `?` flows through the coordinator and runtime layers.

use std::fmt;

/// The crate-wide error: a rendered message chain.
#[derive(Debug)]
pub struct C3Error {
    msg: String,
}

impl C3Error {
    /// Build an error from any message.
    pub fn msg(m: impl Into<String>) -> Self {
        C3Error { msg: m.into() }
    }
}

impl fmt::Display for C3Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for C3Error {}

impl From<std::io::Error> for C3Error {
    fn from(e: std::io::Error) -> Self {
        C3Error::msg(format!("io: {e}"))
    }
}

/// Crate-wide result alias; the error type defaults to [`C3Error`].
pub type Result<T, E = C3Error> = std::result::Result<T, E>;

/// anyhow-style context: prepend a message layer when propagating errors
/// (or turning an `Option` into an error).
pub trait Context<T> {
    /// Prepend a fixed message layer to the error (evaluated eagerly).
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Prepend a lazily-built message layer (evaluated only on error).
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| C3Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| C3Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| C3Error::msg(msg.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| C3Error::msg(f().to_string()))
    }
}

/// Early-return with a formatted [`C3Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::C3Error::msg(format!($($arg)*)))
    };
}

/// anyhow-style ensure: bail with a formatted message (or the stringified
/// condition) unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        "nope".parse::<u32>().context("parsing the answer")
    }

    #[test]
    fn context_prepends_layers() {
        let e = fails().unwrap_err();
        let msg = e.to_string();
        assert!(msg.starts_with("parsing the answer: "), "{msg}");
    }

    #[test]
    fn option_context_converts() {
        let v: Option<u32> = None;
        let e = Context::context(v, "missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Context::context(Some(7u32), "ok").unwrap(), 7);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 3);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(11).unwrap_err().to_string().contains("x too big"));
        assert!(f(3).unwrap_err().to_string().contains("x != 3"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(f().unwrap_err().to_string().starts_with("io: "));
    }
}
