//! Edge-side resilience: automatic reconnect with exponential backoff and
//! session resumption — faults become recoveries, not failures.
//!
//! [`run_edge_retry`] is the churn-tolerant twin of
//! [`crate::coordinator::multi::run_edge`]: the probe state `z` and the
//! step cursor live *outside* any single connection, so when a link dies
//! mid-stream the edge backs off (exponential, deterministically jittered
//! from [`RetryPolicy::seed`] — a recovery run replays bit-identically
//! under the same seed, exactly like the chaos harness), reconnects through
//! a caller-supplied connect closure, and picks the session back up with
//! `Msg::Resume`:
//!
//! ```text
//!   edge                                cloud
//!    │ ── ShardHello ──────────────────▶ │
//!    │ ◀─ ShardChallenge { nonce } ───── │   fresh nonce, every connection
//!    │ ── Resume { id, epoch,          ─▶ │   gate checks last_acked against
//!    │            last_acked, proof }    │   its observe_step watermark w:
//!    │                                   │   only {w-1, w} is coherent;
//!    │ ◀─ ResumeOk { resume_step } ───── │   nonce burns BEFORE revocation
//!    │ ══ Sequenced data frames ═══════▶ │   counters start fresh at 0
//! ```
//!
//! The proof binds the resume epoch AND the fresh nonce, so a recorded
//! resume replays no better than a recorded claim; a `last_acked_step`
//! staler than `w - 1` is rejected loudly (`stale resume watermark`) —
//! an edge that lost state must not silently rewind the session.  The
//! in-flight step (uplinked but unacknowledged) is simply re-run: the cloud
//! probe step is a pure function of the uplink and the watermark is
//! monotonic, so the replay is idempotent and the loss trajectory matches
//! an unimpaired run bit-for-bit.

use crate::coordinator::multi::{EdgeReport, OpsRegistry};
use crate::hdc::keyring::EdgeShard;
use crate::hdc::FftBackend;
use crate::tensor::{Labels, Tensor};
use crate::transport::seq::Seq;
use crate::transport::{Msg, Transport};
use crate::util::error::{C3Error, Result};
use crate::util::rng::Rng;
use crate::{bail, ensure};

/// Reconnect/backoff knobs for [`run_edge_retry`] (config: `[resilience]`,
/// CLI: `--retry-*` / `--connect-timeout-ms` / `--io-timeout-ms`).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Consecutive failed attempts tolerated before the edge gives up
    /// loudly.  An attempt that makes step progress resets the counter —
    /// bounded retries per fault, not per session.
    pub max_attempts: u32,
    /// First backoff sleep, in milliseconds; doubles per consecutive
    /// failure.
    pub base_backoff_ms: u64,
    /// Backoff ceiling, in milliseconds.
    pub max_backoff_ms: u64,
    /// Jitter fraction `j`: each sleep is scaled by a factor drawn
    /// uniformly from `[1-j, 1+j]` (0 disables jitter).
    pub jitter_frac: f64,
    /// Bound on each TCP connect attempt, in milliseconds (0 = unbounded;
    /// honored by the connect closure, e.g. via
    /// [`crate::transport::tcp::Tcp::connect_within`]).
    pub connect_timeout_ms: u64,
    /// Read deadline on the session transport, in milliseconds (0 =
    /// none).  A cloud that goes quiet past this is treated as a dead link
    /// and retried.
    pub read_timeout_ms: u64,
    /// Write deadline on the session transport, in milliseconds (0 = none).
    pub write_timeout_ms: u64,
    /// Seed for the deterministic jitter stream (replayable recovery runs).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 5,
            base_backoff_ms: 100,
            max_backoff_ms: 5_000,
            jitter_frac: 0.2,
            connect_timeout_ms: 5_000,
            read_timeout_ms: 30_000,
            write_timeout_ms: 30_000,
            seed: 0x0C3_51,
        }
    }
}

impl RetryPolicy {
    /// The sleep before retry number `attempt` (1-based): exponential
    /// doubling from [`RetryPolicy::base_backoff_ms`], capped at
    /// [`RetryPolicy::max_backoff_ms`], scaled by the deterministic jitter
    /// factor drawn from `rng`.
    pub fn backoff_ms(&self, attempt: u32, rng: &mut Rng) -> u64 {
        let exp = attempt.saturating_sub(1).min(32);
        let raw = self
            .base_backoff_ms
            .saturating_mul(1u64 << exp)
            .min(self.max_backoff_ms.max(self.base_backoff_ms));
        // uniform in [0,1): 53 mantissa bits of one PRNG draw — consumed
        // even when jitter is disabled so the replayable stream position
        // does not depend on the knob
        let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let j = self.jitter_frac.clamp(0.0, 1.0);
        let factor = 1.0 - j + 2.0 * j * u;
        ((raw as f64) * factor).round().max(0.0) as u64
    }

    /// [`RetryPolicy::read_timeout_ms`] as an `Option<Duration>` (0 = none).
    pub fn read_deadline(&self) -> Option<std::time::Duration> {
        (self.read_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(self.read_timeout_ms))
    }

    /// [`RetryPolicy::write_timeout_ms`] as an `Option<Duration>` (0 = none).
    pub fn write_deadline(&self) -> Option<std::time::Duration> {
        (self.write_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(self.write_timeout_ms))
    }

    /// [`RetryPolicy::connect_timeout_ms`] as a `Duration` (0 = a generous
    /// bound rather than forever, so a misconfigured knob cannot hang the
    /// connect closure).
    pub fn connect_timeout(&self) -> std::time::Duration {
        std::time::Duration::from_millis(if self.connect_timeout_ms == 0 {
            60_000
        } else {
            self.connect_timeout_ms
        })
    }
}

/// Cross-connection session state: everything that must survive a dropped
/// link for the resumed session to be exact.
struct EdgeSession {
    z: Tensor,
    /// First step not yet acknowledged by the cloud (the resume point).
    next_step: u64,
    end_step: u64,
    batch: usize,
    first_loss: Option<f32>,
    last_loss: f32,
}

/// One sharded training run with automatic reconnect + resume.  `connect`
/// builds a fresh transport per attempt (its argument is the 0-based
/// connection count, so tests can impair specific connections); the first
/// connection claims the shard with `Msg::KeyShard`, every later one
/// resumes it with `Msg::Resume` at the exact step after the last
/// acknowledged one.  The probe state `z` lives here, across connections,
/// so the loss trajectory of a recovered run is bit-identical to an
/// unimpaired one.  `registry` (when given) receives
/// [`OpsRegistry::note_reconnect`] per reconnect and the backoff sleeps.
#[allow(clippy::too_many_arguments)]
pub fn run_edge_retry(
    shard: EdgeShard,
    workers: usize,
    fft: FftBackend,
    mut connect: impl FnMut(u32) -> Result<Box<dyn Transport>>,
    steps: u64,
    data_seed: u64,
    batch: usize,
    d: usize,
    policy: &RetryPolicy,
    registry: Option<&OpsRegistry>,
) -> Result<EdgeReport> {
    ensure!(steps >= 1, "edge needs at least one step");
    let mut rng = Rng::new(data_seed);
    let mut zdata = vec![0.0f32; batch * d];
    rng.fill_normal(&mut zdata, 0.0, 1.0);
    let mut ss = EdgeSession {
        z: Tensor::from_vec(&[batch, d], zdata),
        next_step: 0,
        end_step: steps,
        batch,
        first_loss: None,
        last_loss: 0.0,
    };
    let mut backoff_rng = Rng::new(policy.seed);
    let (mut tx_bytes, mut rx_bytes) = (0u64, 0u64);
    let mut connects = 0u32;
    let mut attempt = 0u32; // consecutive no-progress failures
    loop {
        let fault = match connect(connects) {
            Ok(mut tp) => {
                connects += 1;
                if connects > 1 {
                    if let Some(reg) = registry {
                        reg.note_reconnect();
                    }
                }
                let start_step = ss.next_step;
                let outcome = edge_session(&mut *tp, shard, workers, fft, &mut ss, policy);
                let stats = tp.stats();
                tx_bytes += stats.tx();
                rx_bytes += stats.rx();
                match outcome {
                    Ok(()) => break,
                    Err(e) => {
                        if ss.next_step > start_step {
                            // progress resets the budget: retries are
                            // bounded per fault, not per session
                            attempt = 0;
                        }
                        e
                    }
                }
            }
            Err(e) => e,
        };
        attempt += 1;
        ensure!(
            attempt < policy.max_attempts.max(1),
            "edge shard {}: giving up after {attempt} consecutive failed \
             attempt(s) at step {}: {fault}",
            shard.client_id(),
            ss.next_step,
        );
        let ms = policy.backoff_ms(attempt, &mut backoff_rng);
        if let Some(reg) = registry {
            reg.observe_backoff_ms(ms as f64);
        }
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
    Ok(EdgeReport {
        steps,
        first_loss: ss.first_loss.unwrap_or(0.0),
        last_loss: ss.last_loss,
        tx_bytes,
        rx_bytes,
    })
}

/// One connection's worth of the session: handshake (fresh claim at step 0,
/// `Msg::Resume` otherwise), then sequenced training steps until `end_step`
/// or a transport fault.  Progress is committed into `ss` step by step, so
/// the caller resumes exactly where the fault interrupted.
fn edge_session(
    tp: &mut dyn Transport,
    shard: EdgeShard,
    workers: usize,
    fft: FftBackend,
    ss: &mut EdgeSession,
    policy: &RetryPolicy,
) -> Result<()> {
    if policy.read_timeout_ms > 0 || policy.write_timeout_ms > 0 {
        // best-effort: transports without OS deadlines (in-proc) surface
        // faults as closed channels instead
        let _ = tp.set_deadline(policy.read_deadline(), policy.write_deadline());
    }
    tp.send(&Msg::ShardHello)?;
    let nonce = match tp.recv()? {
        Msg::ShardChallenge { nonce } => nonce,
        other => bail!("edge expected ShardChallenge, got {other:?}"),
    };
    if ss.next_step == 0 {
        let epoch = shard.epoch_of_step(0);
        tp.send(&Msg::KeyShard {
            client_id: shard.client_id(),
            epoch,
            proof: shard.proof(epoch, nonce),
        })?;
    } else {
        let last_acked_step = ss.next_step - 1;
        let epoch = shard.epoch_of_step(ss.next_step);
        tp.send(&Msg::Resume {
            client_id: shard.client_id(),
            epoch,
            last_acked_step,
            proof: shard.proof(epoch, nonce),
        })?;
        match tp.recv()? {
            Msg::ResumeOk { resume_step } => ensure!(
                resume_step == ss.next_step,
                "cloud resumed at step {resume_step}, edge expected {}",
                ss.next_step
            ),
            other => bail!("edge expected ResumeOk, got {other:?}"),
        }
    }
    let mut cc = shard.client_codec_lazy();
    cc.set_workers(workers);
    cc.set_fft_backend(fft);

    // same contraction constant as run_edge — the recovered trajectory must
    // be bit-identical to the unimpaired one
    let d = ss.z.shape()[1];
    let lr = 0.005f32 * (ss.batch * d) as f32;
    let mut seq = Seq::new();
    for step in ss.next_step..ss.end_step {
        let s = cc.for_step(step)?.encode(&ss.z);
        tp.send(&seq.stamp(Msg::Features { step, tensor: s }))?;
        tp.send(&seq.stamp(Msg::TrainLabels { step, labels: Labels(vec![0; ss.batch]) }))?;

        let gs = match seq
            .accept(tp.recv()?)
            .map_err(|e| C3Error::msg(format!("edge: {e}")))?
        {
            Msg::Gradients { step: gstep, tensor } => {
                ensure!(gstep == step, "gradient step mismatch: {gstep} != {step}");
                tensor
            }
            other => bail!("edge expected Gradients, got {other:?}"),
        };
        let loss = match seq
            .accept(tp.recv()?)
            .map_err(|e| C3Error::msg(format!("edge: {e}")))?
        {
            Msg::StepStats { loss, .. } => loss,
            other => bail!("edge expected StepStats, got {other:?}"),
        };

        let gz = cc.for_step(step)?.decode(&gs);
        ensure!(
            gz.shape() == ss.z.shape(),
            "gradient shape {:?} vs features {:?}",
            gz.shape(),
            ss.z.shape()
        );
        ss.z = ss.z.sub(&gz.scale(lr));
        if ss.first_loss.is_none() {
            ss.first_loss = Some(loss);
        }
        ss.last_loss = loss;
        // the gradient for `step` is applied and acknowledged: the resume
        // point moves past it
        ss.next_step = step + 1;
    }
    tp.send(&seq.stamp(Msg::Shutdown))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_caps_and_jitters_deterministically() {
        let policy = RetryPolicy {
            base_backoff_ms: 100,
            max_backoff_ms: 800,
            jitter_frac: 0.2,
            seed: 7,
            ..RetryPolicy::default()
        };
        let mut a = Rng::new(policy.seed);
        let mut b = Rng::new(policy.seed);
        for attempt in 1..=6 {
            let x = policy.backoff_ms(attempt, &mut a);
            let y = policy.backoff_ms(attempt, &mut b);
            assert_eq!(x, y, "same seed must give the same jitter");
            let raw = (100u64 << (attempt - 1)).min(800);
            let lo = (raw as f64 * 0.8).floor() as u64;
            let hi = (raw as f64 * 1.2).ceil() as u64;
            assert!(
                (lo..=hi).contains(&x),
                "attempt {attempt}: backoff {x} outside [{lo}, {hi}]"
            );
        }
    }

    #[test]
    fn zero_jitter_is_exact_exponential() {
        let policy = RetryPolicy {
            base_backoff_ms: 50,
            max_backoff_ms: 400,
            jitter_frac: 0.0,
            ..RetryPolicy::default()
        };
        let mut rng = Rng::new(1);
        assert_eq!(policy.backoff_ms(1, &mut rng), 50);
        assert_eq!(policy.backoff_ms(2, &mut rng), 100);
        assert_eq!(policy.backoff_ms(3, &mut rng), 200);
        assert_eq!(policy.backoff_ms(4, &mut rng), 400);
        assert_eq!(policy.backoff_ms(5, &mut rng), 400, "capped at max");
    }
}
