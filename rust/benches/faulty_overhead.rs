//! Bench: fault-injector overhead.  The chaos harness wraps every edge
//! link in a `transport::faulty::FaultyLink`; for the parity scenarios to
//! mean anything, a zero-impairment injector must be a near-free
//! pass-through — same frames, same accounting, and throughput within
//! noise of the bare transport.  This harness measures exactly that
//! tax, plus a sanity venue showing a scripted latency really costs what
//! the schedule says it does.
//!
//!   cargo bench --bench faulty_overhead
//!   C3SL_BENCH_QUICK=1 cargo bench --bench faulty_overhead     # CI smoke
//!
//! Venues (ping-pong round trips of a Features/Gradients pair over the
//! in-proc transport, feature rows 4 × D):
//!   bare          — InProc directly
//!   faulty-off    — InProc behind `Impairments::off()` both directions
//!   faulty-250us  — InProc behind a fixed 250 µs tx latency (sanity: the
//!                   measured per-frame cost must be at least the script)
//!
//! The bare vs faulty-off comparison prints the relative tax; it is
//! advisory output, not a gate — the bench-regression gate tracks the
//! codec and reactor venues, this one exists so a chaos-harness change
//! that makes the pass-through expensive is visible immediately.

use std::time::Instant;

use c3sl::tensor::Tensor;
use c3sl::transport::faulty::{FaultyLink, Impairments};
use c3sl::transport::{inproc_pair, Msg, Transport};

/// Drive `frames` Features→Gradients round trips through `tp` against an
/// echo peer already running on the other end.  Returns wall seconds.
fn pingpong(tp: &mut dyn Transport, frames: u64, d: usize) -> f64 {
    let t0 = Instant::now();
    for step in 0..frames {
        tp.send(&Msg::Features { step, tensor: Tensor::zeros(&[4, d]) })
            .expect("bench send");
        match tp.recv().expect("bench recv") {
            Msg::Gradients { step: got, .. } => assert_eq!(got, step),
            other => panic!("echo peer answered {other:?}"),
        }
    }
    tp.send(&Msg::Shutdown).expect("bench shutdown");
    t0.elapsed().as_secs_f64()
}

/// One venue: spawn the echo peer, run the driver (optionally behind a
/// `FaultyLink` with the given impairments), return seconds per frame.
fn venue(frames: u64, d: usize, wrap: Option<(Impairments, Impairments)>) -> f64 {
    let (mut a, mut b) = inproc_pair();
    std::thread::scope(|sc| {
        let echo = sc.spawn(move || loop {
            match b.recv() {
                Ok(Msg::Features { step, tensor }) => {
                    b.send(&Msg::Gradients { step, tensor }).expect("echo send");
                }
                Ok(Msg::Shutdown) | Err(_) => break,
                Ok(other) => panic!("echo peer got {other:?}"),
            }
        });
        let secs = match wrap {
            Some((tx, rx)) => {
                let mut link = FaultyLink::new(a, 0xBE_AC4, tx, rx);
                pingpong(&mut link, frames, d)
            }
            None => pingpong(&mut a, frames, d),
        };
        echo.join().expect("echo thread");
        secs / frames as f64
    })
}

fn main() {
    let quick = std::env::var("C3SL_BENCH_QUICK").is_ok();
    let frames: u64 = if quick { 2_000 } else { 20_000 };
    let lat_frames: u64 = if quick { 100 } else { 400 };
    let d = 256usize;

    println!("# faulty-link overhead: {frames} Features/Gradients round trips, D={d}\n");
    println!("{:<14} {:>12} {:>12}", "venue", "us/frame", "frames/s");

    let report = |name: &str, spf: f64| {
        println!("{:<14} {:>12.2} {:>12.0}", name, spf * 1e6, 1.0 / spf.max(1e-12));
    };

    // warm-up then measure, bare vs zero-impairment wrapper
    venue(frames / 10, d, None);
    let bare = venue(frames, d, None);
    report("bare", bare);
    let off = venue(frames, d, Some((Impairments::off(), Impairments::off())));
    report("faulty-off", off);

    // sanity: a scripted 250 µs tx latency must actually be paid per frame
    let scripted = Impairments { latency_us: 250, ..Impairments::off() };
    let lat = venue(lat_frames, d, Some((scripted, Impairments::off())));
    report("faulty-250us", lat);
    assert!(
        lat >= 250e-6,
        "scripted 250 us/frame latency not observed: {:.2} us/frame",
        lat * 1e6
    );

    let tax = (off / bare.max(1e-12) - 1.0) * 100.0;
    println!(
        "\nzero-impairment tax: {tax:+.1}% per frame (advisory — the injector \
         must stay a pass-through; see tests/chaos.rs parity scenarios for \
         the correctness side of this claim)"
    );
}
