//! Driver: assembles datasets, transports and the two workers for one
//! experiment, runs them concurrently, and returns the run record.
//!
//! In-proc mode spawns the cloud on its own OS thread (its own PJRT engine —
//! xla handles are not Send, so each actor constructs everything inside its
//! thread) and runs the edge on the caller's thread.  TCP mode is driven from
//! main.rs with `c3sl edge` / `c3sl cloud` in separate processes.

use super::multi::{self, EdgeReport, MultiStats};
use super::run_codec::RunCodec;
use super::{CloudWorker, EdgeWorker};
use crate::config::{ExperimentConfig, TransportKind};
use crate::data::open_dataset;
use crate::ensure;
use crate::metrics::RunRecorder;
use crate::runtime::Engine;
use crate::transport::sim::{LinkModel, SimLink};
use crate::transport::tcp::Tcp;
use crate::transport::{inproc_pair, Transport};
use crate::util::error::{C3Error, Context, Result};

/// Everything a finished run reports.
pub struct RunOutput {
    pub recorder: RunRecorder,
    /// Total bytes on the wire (uplink+downlink, serialized frames).
    pub wire_tx: u64,
    pub wire_rx: u64,
    /// Virtual link time if a LinkModel was configured.
    pub virtual_link_seconds: Option<f64>,
    pub wall_seconds: f64,
}

/// Run one experiment end to end (in-proc transport).
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunOutput> {
    ensure!(
        cfg.transport == TransportKind::InProc,
        "run_experiment drives in-proc runs; use `c3sl edge`/`c3sl cloud` for tcp"
    );
    let t0 = std::time::Instant::now();
    let (edge_tp, cloud_tp) = inproc_pair();

    // Cloud actor on its own thread with its own engine.
    let cloud_cfg = cfg.clone();
    let cloud_handle = std::thread::Builder::new()
        .name("cloud".into())
        .spawn(move || -> Result<()> {
            let engine = Engine::cpu().context("cloud engine")?;
            let mut cloud = CloudWorker::new(&engine, &cloud_cfg)?;
            let mut tp: Box<dyn Transport> = Box::new(cloud_tp);
            cloud.run(tp.as_mut())
        })
        .context("spawning cloud thread")?;

    // Edge actor on this thread.
    let engine = Engine::cpu().context("edge engine")?;
    let mut edge = EdgeWorker::new(&engine, cfg)?;
    let manifest_batch = edge.batch_size();

    let train = open_dataset(
        &cfg.data_root,
        classes_of(cfg)?,
        image_of(cfg)?,
        true,
        cfg.synth_train.max(manifest_batch),
    );
    let test = open_dataset(
        &cfg.data_root,
        classes_of(cfg)?,
        image_of(cfg)?,
        false,
        cfg.synth_test.max(manifest_batch),
    );

    let mut edge_transport: Box<dyn Transport> = match cfg.link {
        Some(link) => Box::new(SimLink::new(edge_tp, link)),
        None => Box::new(edge_tp),
    };

    let recorder = edge.run(edge_transport.as_mut(), train.as_ref(), test.as_ref(), cfg)?;

    cloud_handle
        .join()
        .map_err(|e| C3Error::msg(format!("cloud thread panicked: {e:?}")))??;

    let stats = edge_transport.stats();
    let virtual_link_seconds = cfg.link.map(|l: LinkModel| {
        // recompute from byte totals (tx and rx see the same link)
        l.transfer_time(stats.tx()) + l.transfer_time(stats.rx())
    });
    Ok(RunOutput {
        recorder,
        wire_tx: stats.tx(),
        wire_rx: stats.rx(),
        virtual_link_seconds,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

// ---------------------------------------------------------------------------
// Multi-edge scenario: N concurrent clients against one cloud.
// ---------------------------------------------------------------------------

/// Geometry + venue for one multi-edge codec run (the model halves stay out:
/// this is the codec/transport scale path — see coordinator::multi).
#[derive(Clone, Debug)]
pub struct MultiEdgeSpec {
    /// Concurrent edge clients.
    pub edges: usize,
    /// Training steps per edge.
    pub steps: u64,
    /// Per-edge batch size B (must be divisible by `r`).
    pub r: usize,
    pub d: usize,
    pub batch: usize,
    pub seed: u64,
    /// Group-parallel codec workers per endpoint.
    pub workers: usize,
    pub transport: TransportKind,
    /// Listen/connect address for the TCP venue.
    pub tcp_addr: String,
    /// Optional virtual-link cost model on the edge side (in-proc venue).
    pub link: Option<LinkModel>,
}

impl Default for MultiEdgeSpec {
    fn default() -> Self {
        MultiEdgeSpec {
            edges: 2,
            steps: 10,
            r: 4,
            d: 1024,
            batch: 16,
            seed: 0,
            workers: 1,
            transport: TransportKind::InProc,
            tcp_addr: "127.0.0.1:7071".into(),
            link: None,
        }
    }
}

/// Everything a finished multi-edge run reports.
#[derive(Clone, Debug)]
pub struct MultiRunOutput {
    /// Cloud-side per-client + aggregate stats.
    pub cloud: MultiStats,
    /// Edge-side reports, in spawn order.
    pub edges: Vec<EdgeReport>,
    pub wall_seconds: f64,
}

/// Run N concurrent edges against one multi-client cloud, end to end, over
/// the in-proc (optionally SimLink-wrapped) or TCP transport.  Both sides
/// derive their codec from the shared key seed — keys never cross the wire.
pub fn run_multi_edge(spec: &MultiEdgeSpec) -> Result<MultiRunOutput> {
    ensure!(spec.edges >= 1, "need at least one edge");
    ensure!(spec.steps >= 1, "need at least one step");
    ensure!(spec.r >= 1, "compression ratio R must be >= 1");
    ensure!(spec.d >= 1, "feature dim D must be >= 1");
    ensure!(
        spec.batch % spec.r == 0,
        "batch {} not divisible by R={}",
        spec.batch,
        spec.r
    );
    let t0 = std::time::Instant::now();
    let key_seed = spec.seed ^ 0xC3_C3_C3_C3u64;
    let cloud_codec = RunCodec::host(key_seed, spec.r, spec.d, spec.workers);
    let edge_codec = RunCodec::host(key_seed, spec.r, spec.d, spec.workers);

    let (cloud, edges) = match spec.transport {
        TransportKind::InProc => {
            let mut cloud_tps = Vec::with_capacity(spec.edges);
            let mut edge_tps: Vec<Box<dyn Transport>> = Vec::with_capacity(spec.edges);
            for _ in 0..spec.edges {
                let (e, c) = inproc_pair();
                cloud_tps.push(c);
                edge_tps.push(match spec.link {
                    Some(link) => Box::new(SimLink::new(e, link)),
                    None => Box::new(e),
                });
            }
            std::thread::scope(|sc| -> Result<(MultiStats, Vec<EdgeReport>)> {
                let cloud_handle = sc.spawn(|| multi::serve_clients(&cloud_codec, cloud_tps));
                let mut edge_handles = Vec::with_capacity(spec.edges);
                for (i, mut tp) in edge_tps.into_iter().enumerate() {
                    let codec = &edge_codec;
                    edge_handles.push(sc.spawn(move || {
                        multi::run_edge(
                            codec,
                            tp.as_mut(),
                            spec.steps,
                            key_seed,
                            spec.seed.wrapping_add(i as u64),
                            spec.batch,
                            spec.d,
                        )
                    }));
                }
                let mut edges = Vec::with_capacity(spec.edges);
                for h in edge_handles {
                    edges.push(
                        h.join()
                            .map_err(|_| C3Error::msg("edge thread panicked"))??,
                    );
                }
                let cloud = cloud_handle
                    .join()
                    .map_err(|_| C3Error::msg("cloud thread panicked"))??;
                Ok((cloud, edges))
            })?
        }
        TransportKind::Tcp => {
            // Bind before spawning edges so connects never race the listener.
            let listener = Tcp::bind(&spec.tcp_addr)
                .with_context(|| format!("binding {}", spec.tcp_addr))?;
            std::thread::scope(|sc| -> Result<(MultiStats, Vec<EdgeReport>)> {
                let n = spec.edges;
                let cloud_handle = sc.spawn(move || -> Result<MultiStats> {
                    // Deadline-bounded accept: a client that never connects
                    // must not hang the scope join forever.
                    let tps =
                        Tcp::accept_n(&listener, n, std::time::Duration::from_secs(30))
                            .context("accepting edges")?;
                    multi::serve_clients(&cloud_codec, tps)
                });
                let mut edge_handles = Vec::with_capacity(spec.edges);
                for i in 0..spec.edges {
                    let codec = &edge_codec;
                    let addr = spec.tcp_addr.clone();
                    edge_handles.push(sc.spawn(move || -> Result<EdgeReport> {
                        let mut tp =
                            Tcp::connect(&addr).with_context(|| format!("connecting {addr}"))?;
                        multi::run_edge(
                            codec,
                            &mut tp,
                            spec.steps,
                            key_seed,
                            spec.seed.wrapping_add(i as u64),
                            spec.batch,
                            spec.d,
                        )
                    }));
                }
                let mut edges = Vec::with_capacity(spec.edges);
                for h in edge_handles {
                    edges.push(
                        h.join()
                            .map_err(|_| C3Error::msg("edge thread panicked"))??,
                    );
                }
                let cloud = cloud_handle
                    .join()
                    .map_err(|_| C3Error::msg("cloud thread panicked"))??;
                Ok((cloud, edges))
            })?
        }
    };

    Ok(MultiRunOutput { cloud, edges, wall_seconds: t0.elapsed().as_secs_f64() })
}

/// Read classes from the model manifest (single source of truth).
fn classes_of(cfg: &ExperimentConfig) -> Result<usize> {
    Ok(crate::runtime::ModelManifest::load(cfg.model_dir())?.classes)
}

fn image_of(cfg: &ExperimentConfig) -> Result<usize> {
    Ok(crate::runtime::ModelManifest::load(cfg.model_dir())?.image)
}
