#!/usr/bin/env python3
"""Fill zeroed BENCH_baseline.json cells from a freshly measured bench run.

Usage: calibrate_baseline.py BASELINE_JSON FRESH_JSON

The CI bench-gate job runs this on green pushes to main, after both bench
gates passed.  It copies a measured value over every baseline cell that
still reads 0 ("no absolute trajectory recorded") — and ONLY those cells:

  * existing non-zero baseline numbers are never overwritten, so
    re-baselining a measured trajectory stays a reviewed human decision;
  * venues or sizes absent from the baseline are never added, so structural
    changes to the gate surface stay in code review;
  * `bytes_per_step` style structural fields are identical by construction
    and are skipped (they are non-zero already).

Exit status 0 always (an already-calibrated baseline is a no-op); the job
decides whether to commit by diffing the file.  Stdlib only — no pip.
"""

import json
import sys


def main() -> None:
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} BASELINE_JSON FRESH_JSON")
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(baseline_path, encoding="utf-8") as f:
        baseline = json.load(f)
    with open(fresh_path, encoding="utf-8") as f:
        fresh = json.load(f)

    fresh_venues = fresh.get("venues", {})
    filled = 0
    skipped_unmeasured = 0
    for venue, per_size in baseline.get("venues", {}).items():
        for size, cells in per_size.items():
            fresh_cells = fresh_venues.get(venue, {}).get(size, {})
            for key, value in cells.items():
                if value != 0:
                    continue  # measured already (or structural): hands off
                fresh_value = fresh_cells.get(key)
                if isinstance(fresh_value, (int, float)) and fresh_value > 0:
                    cells[key] = fresh_value
                    filled += 1
                else:
                    skipped_unmeasured += 1

    if filled:
        with open(baseline_path, "w", encoding="utf-8") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
    print(
        f"calibrate_baseline: filled {filled} zero cell(s); "
        f"{skipped_unmeasured} zero cell(s) had no fresh measurement"
    )


if __name__ == "__main__":
    main()
