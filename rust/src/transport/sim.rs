//! SimLink: a virtual-time network cost model layered over any transport.
//!
//! The paper's motivation is communication cost on constrained edge links;
//! this wrapper charges each message `latency + bytes / bandwidth` seconds of
//! *virtual* time (no real sleeping — the benches sweep many configurations)
//! and tracks per-direction totals, so `cargo bench --bench comm_cost` can
//! report epoch times for vanilla vs C3 vs BottleNet++ under WiFi / LTE /
//! BLE-class links.

use std::sync::Arc;

use super::{LinkStats, Msg, Transport, TransportError};
use crate::transport::wire;

/// Link parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// One-way latency in seconds.
    pub latency_s: f64,
    /// Bandwidth in bytes/second.
    pub bandwidth_bps: f64,
}

impl LinkModel {
    /// Build a model from one-way latency (s) and bandwidth (bytes/s).
    pub fn new(latency_s: f64, bandwidth_bps: f64) -> Self {
        assert!(bandwidth_bps > 0.0);
        LinkModel { latency_s, bandwidth_bps }
    }

    /// Transfer time for one message of `bytes` bytes.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    // Named profiles used by the benches (nominal, order-of-magnitude).

    /// WiFi-class link: 50 Mbit/s, 2 ms.
    pub fn wifi() -> Self {
        Self::new(2e-3, 50e6 / 8.0)
    }

    /// LTE-class link: 10 Mbit/s, 30 ms.
    pub fn lte() -> Self {
        Self::new(30e-3, 10e6 / 8.0)
    }

    /// NB-IoT-class link: 100 kbit/s, 100 ms.
    pub fn nbiot() -> Self {
        Self::new(100e-3, 100e3 / 8.0)
    }
}

/// Virtual clock accumulating transfer time per direction.
#[derive(Debug, Default, Clone)]
pub struct VirtualClock {
    /// Virtual seconds spent sending.
    pub tx_seconds: f64,
    /// Virtual seconds spent receiving.
    pub rx_seconds: f64,
}

/// Transport wrapper charging virtual time for every frame.
pub struct SimLink<T: Transport> {
    inner: T,
    model: LinkModel,
    /// Accumulated virtual time on this endpoint.
    pub clock: VirtualClock,
}

impl<T: Transport> SimLink<T> {
    /// Wrap `inner` under the given cost model.
    pub fn new(inner: T, model: LinkModel) -> Self {
        SimLink { inner, model, clock: VirtualClock::default() }
    }

    /// The cost model this link charges.
    pub fn model(&self) -> LinkModel {
        self.model
    }

    /// Total virtual seconds across both directions.
    pub fn total_virtual_seconds(&self) -> f64 {
        self.clock.tx_seconds + self.clock.rx_seconds
    }
}

impl<T: Transport> Transport for SimLink<T> {
    fn send(&mut self, msg: &Msg) -> Result<(), TransportError> {
        let bytes = wire::encode(msg).len() as u64;
        self.clock.tx_seconds += self.model.transfer_time(bytes);
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<Msg, TransportError> {
        let msg = self.inner.recv()?;
        let bytes = wire::encode(&msg).len() as u64;
        self.clock.rx_seconds += self.model.transfer_time(bytes);
        Ok(msg)
    }

    fn stats(&self) -> Arc<LinkStats> {
        self.inner.stats()
    }
}

/// Pure cost-model evaluation (no transport): epoch communication time for a
/// scheme that sends `uplink_bytes` and receives `downlink_bytes` per step.
pub fn epoch_comm_time(model: &LinkModel, steps: u64, uplink_bytes: u64,
                       downlink_bytes: u64) -> f64 {
    steps as f64 * (model.transfer_time(uplink_bytes) + model.transfer_time(downlink_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::transport::inproc_pair;

    #[test]
    fn transfer_time_formula() {
        let m = LinkModel::new(0.01, 1000.0);
        assert!((m.transfer_time(500) - 0.51).abs() < 1e-12);
    }

    #[test]
    fn simlink_charges_both_directions() {
        let (a, b) = inproc_pair();
        let m = LinkModel::new(0.0, 1000.0);
        let mut sa = SimLink::new(a, m);
        let mut sb = SimLink::new(b, m);
        let msg = Msg::Features { step: 0, tensor: Tensor::zeros(&[10]) };
        sa.send(&msg).unwrap();
        sb.recv().unwrap();
        let bytes = wire::encode(&msg).len() as f64;
        assert!((sa.clock.tx_seconds - bytes / 1000.0).abs() < 1e-9);
        assert!((sb.clock.rx_seconds - bytes / 1000.0).abs() < 1e-9);
    }

    #[test]
    fn compression_reduces_virtual_time_by_r() {
        let m = LinkModel::new(0.0, 1e6);
        let full = epoch_comm_time(&m, 100, 4096, 4096);
        let c3 = epoch_comm_time(&m, 100, 4096 / 16, 4096 / 16);
        assert!((full / c3 - 16.0).abs() < 1e-9);
    }

    #[test]
    fn latency_dominates_small_messages() {
        // On a high-latency link, compressing tiny messages barely helps —
        // the crossover behaviour the comm bench plots.
        let m = LinkModel::nbiot();
        let full = epoch_comm_time(&m, 10, 1000, 1000);
        let c3 = epoch_comm_time(&m, 10, 1000 / 16, 1000 / 16);
        let speedup = full / c3;
        assert!(speedup < 3.0, "latency-bound speedup {speedup} should be modest");
    }

    #[test]
    fn profiles_ordered_by_bandwidth() {
        assert!(LinkModel::wifi().bandwidth_bps > LinkModel::lte().bandwidth_bps);
        assert!(LinkModel::lte().bandwidth_bps > LinkModel::nbiot().bandwidth_bps);
    }
}
