//! Multi-edge split learning: N concurrent edges against one cloud, end to
//! end through the C3 codec in both directions, with per-client and
//! aggregate LinkStats.  Runs four times — over in-proc links under a WiFi
//! cost model, over real localhost TCP sockets (both thread-per-client),
//! over TCP served by the nonblocking reactor (one I/O thread + codec
//! worker pool), and once more with per-client key shards (`Msg::KeyShard`
//! handshake) rotating to a fresh key epoch mid-run — and needs no AOT
//! artifacts (host codec venue; the model halves are PJRT-gated).
//!
//!   cargo run --release --example train_multi_edge
//!   C3SL_EDGES=8 cargo run --release --example train_multi_edge

use c3sl::config::TransportKind;
use c3sl::coordinator::{run_multi_edge, MultiEdgeSpec, MultiRunOutput};
use c3sl::transport::sim::LinkModel;
use c3sl::util::error::Result;

fn report(label: &str, out: &MultiRunOutput) {
    println!("== {label}");
    println!(
        "{:>7} {:>7} {:>12} {:>12} {:>12}",
        "client", "steps", "rx bytes", "tx bytes", "last loss"
    );
    for c in &out.cloud.per_client {
        println!(
            "{:>7} {:>7} {:>12} {:>12} {:>12.5}",
            c.client, c.steps, c.rx_bytes, c.tx_bytes, c.last_loss
        );
    }
    let edge_tx: u64 = out.edges.iter().map(|e| e.tx_bytes).sum();
    println!(
        "aggregate: steps={} cloud_rx={}B (= edge uplinks {}B) cloud_tx={}B wall={:.2}s\n",
        out.cloud.total_steps(),
        out.cloud.total_rx(),
        edge_tx,
        out.cloud.total_tx(),
        out.wall_seconds
    );
}

fn main() -> Result<()> {
    let edges: usize = std::env::var("C3SL_EDGES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let base = MultiEdgeSpec {
        edges,
        steps: 12,
        r: 4,
        d: 1024,
        batch: 16,
        seed: 1,
        workers: 2,
        ..Default::default()
    };
    println!(
        "train_multi_edge: {} edges x {} steps, R={} D={} B={}, {} codec workers\n",
        base.edges, base.steps, base.r, base.d, base.batch, base.workers
    );

    let inproc = run_multi_edge(&MultiEdgeSpec {
        link: Some(LinkModel::wifi()),
        ..base.clone()
    })?;
    report("in-proc + wifi link model", &inproc);

    let tcp = run_multi_edge(&MultiEdgeSpec {
        transport: TransportKind::Tcp,
        tcp_addr: "127.0.0.1:39719".into(),
        ..base.clone()
    })?;
    report("localhost tcp", &tcp);

    let reactor = run_multi_edge(&MultiEdgeSpec {
        transport: TransportKind::Tcp,
        tcp_addr: "127.0.0.1:39720".into(),
        reactor: true,
        ..base.clone()
    })?;
    report("localhost tcp, reactor cloud (1 I/O thread)", &reactor);

    // per-client key shards (Msg::KeyShard handshake), rotating to a fresh
    // key epoch halfway through the run — one compromised edge cannot
    // decode any other edge's uplink, and nobody loses a step
    let sharded = run_multi_edge(&MultiEdgeSpec {
        reactor: true,
        key_sharding: true,
        rotation_steps: base.steps / 2,
        ..base
    })?;
    report("in-proc, reactor cloud, sharded keys + rotation", &sharded);

    for (label, out) in [("inproc", &inproc), ("tcp", &tcp), ("reactor", &reactor)] {
        for e in &out.edges {
            assert!(
                e.last_loss < e.first_loss,
                "{label}: probe loss did not decrease"
            );
        }
    }
    // rotation changes the key draw between first and last measurement, so
    // the robust check for the sharded run is the fleet aggregate
    let first: f64 = sharded.edges.iter().map(|e| e.first_loss as f64).sum();
    let last: f64 = sharded.edges.iter().map(|e| e.last_loss as f64).sum();
    assert!(last < first, "sharded: aggregate probe loss did not decrease");
    for c in &sharded.cloud.per_client {
        assert!(c.shard.is_some(), "sharded run reports each claimed shard");
    }
    println!("train_multi_edge OK — {edges} concurrent clients, compressed both ways");
    Ok(())
}
