# Minimal functional NN framework for the L2 JAX models.
#
# No flax/haiku in this environment — and the reproduction mandate is to own
# every substrate — so this is a tiny combinator library: a Layer is an
# (init, apply) pair; Sequential chains them; parameters are nested lists of
# arrays (a JAX pytree), so jax.grad / jit / vjp work untouched.

from .core import Layer, Sequential, Identity, Lambda
from .layers import (
    Conv2d,
    Deconv2d,
    Dense,
    ReLU,
    Sigmoid,
    MaxPool2d,
    GlobalAvgPool,
    Flatten,
    GroupNorm,
    BatchNormStatic,
)

__all__ = [
    "Layer", "Sequential", "Identity", "Lambda",
    "Conv2d", "Deconv2d", "Dense", "ReLU", "Sigmoid", "MaxPool2d",
    "GlobalAvgPool", "Flatten", "GroupNorm", "BatchNormStatic",
]
