//! Communication-efficiency simulator: evaluates per-epoch bytes and virtual
//! link time for each compression scheme over a link grid.  This regenerates
//! the paper's §1 headline ("reduces 16× communication costs") and the
//! crossover analysis in `cargo bench --bench comm_cost`.

use crate::flops::{CutSpec, Scheme};
use crate::transport::sim::LinkModel;
use crate::transport::wire;
use crate::tensor::Tensor;

/// One row of the communication report.
#[derive(Clone, Debug)]
pub struct CommRow {
    /// Compression scheme label.
    pub scheme: &'static str,
    /// Compression ratio R.
    pub r: usize,
    /// Link-model label (e.g. "wifi", "lte").
    pub link: &'static str,
    /// Serialized uplink bytes per training step.
    pub uplink_bytes_per_step: u64,
    /// Serialized downlink bytes per training step.
    pub downlink_bytes_per_step: u64,
    /// Virtual link time for one epoch under the link model.
    pub epoch_seconds: f64,
    /// Per-epoch byte reduction factor vs vanilla SL.
    pub reduction_vs_vanilla: f64,
}

/// Wire-accurate per-step payload bytes for a scheme at a cut spec.
/// Uses the actual frame encoding (header included), not element counts.
pub fn step_payload_bytes(spec: &CutSpec, r: usize, scheme: Scheme) -> (u64, u64) {
    let d = spec.d();
    let b = spec.b;
    let tensor_rows = match scheme {
        Scheme::Vanilla => b,
        Scheme::C3 => b / r,
        // BottleNet++ shrinks the feature dim instead of the batch dim.
        Scheme::BottleNetPP => b,
    };
    let tensor_cols = match scheme {
        Scheme::Vanilla | Scheme::C3 => d,
        Scheme::BottleNetPP => d / r,
    };
    let t = Tensor::zeros(&[tensor_rows, tensor_cols]);
    let bytes = wire::tensor_msg_bytes(&t) as u64;
    // uplink: features + labels; downlink: gradients (same tensor shape).
    // Measure the labels frame by encoding it — the codec, not a formula,
    // owns the framing overhead.
    let label_bytes = wire::encode(&crate::transport::Msg::TrainLabels {
        step: 0,
        labels: crate::tensor::Labels(vec![0; b]),
    })
    .len() as u64;
    (bytes + label_bytes, bytes)
}

/// Evaluate the full scheme × R × link grid.
pub fn comm_report(spec: &CutSpec, steps_per_epoch: u64) -> Vec<CommRow> {
    let links: &[(&'static str, LinkModel)] = &[
        ("wifi", LinkModel::wifi()),
        ("lte", LinkModel::lte()),
        ("nbiot", LinkModel::nbiot()),
    ];
    let mut rows = Vec::new();
    for &(lname, link) in links {
        let (vup, vdown) = step_payload_bytes(spec, 1, Scheme::Vanilla);
        let vanilla_t = steps_per_epoch as f64
            * (link.transfer_time(vup) + link.transfer_time(vdown));
        rows.push(CommRow {
            scheme: "vanilla",
            r: 1,
            link: lname,
            uplink_bytes_per_step: vup,
            downlink_bytes_per_step: vdown,
            epoch_seconds: vanilla_t,
            reduction_vs_vanilla: 1.0,
        });
        for &scheme in &[Scheme::C3, Scheme::BottleNetPP] {
            for &r in &[2usize, 4, 8, 16] {
                let (up, down) = step_payload_bytes(spec, r, scheme);
                let t = steps_per_epoch as f64
                    * (link.transfer_time(up) + link.transfer_time(down));
                rows.push(CommRow {
                    scheme: scheme.name(),
                    r,
                    link: lname,
                    uplink_bytes_per_step: up,
                    downlink_bytes_per_step: down,
                    epoch_seconds: t,
                    reduction_vs_vanilla: vanilla_t / t,
                });
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c3_payload_shrinks_by_r() {
        let spec = CutSpec::vgg16_cifar10();
        let (up1, down1) = step_payload_bytes(&spec, 1, Scheme::Vanilla);
        for r in [2, 4, 8, 16] {
            let (up, down) = step_payload_bytes(&spec, r, Scheme::C3);
            // data dominates; header+labels make the ratio slightly < r
            let ratio = down1 as f64 / down as f64;
            assert!(
                (ratio - r as f64).abs() / (r as f64) < 0.01,
                "r={r} ratio={ratio}"
            );
            assert!(up < up1);
        }
    }

    #[test]
    fn bnpp_and_c3_same_payload_at_same_r() {
        let spec = CutSpec::resnet50_cifar100();
        let (c3u, c3d) = step_payload_bytes(&spec, 8, Scheme::C3);
        let (bnu, bnd) = step_payload_bytes(&spec, 8, Scheme::BottleNetPP);
        // identical element count, slightly different headers
        assert!((c3u as i64 - bnu as i64).abs() < 64);
        assert!((c3d as i64 - bnd as i64).abs() < 64);
    }

    #[test]
    fn report_covers_grid_and_reductions_reasonable() {
        let spec = CutSpec::vgg16_cifar10();
        let rows = comm_report(&spec, 100);
        // 3 links × (1 vanilla + 2 schemes × 4 ratios) = 27 rows
        assert_eq!(rows.len(), 27);
        let (vup, _) = step_payload_bytes(&spec, 1, Scheme::Vanilla);
        for row in &rows {
            assert!(row.epoch_seconds > 0.0);
            if row.scheme == "c3" && row.link == "wifi" {
                // BYTES shrink by ≈R (the paper's 16× claim is about bytes);
                // wall-time reduction is capped below R by per-message
                // latency — it must stay between 60% of R and R.
                let byte_ratio = vup as f64 / row.uplink_bytes_per_step as f64;
                assert!(
                    (byte_ratio - row.r as f64).abs() / (row.r as f64) < 0.05,
                    "{row:?} byte_ratio={byte_ratio}"
                );
                assert!(
                    row.reduction_vs_vanilla > 0.6 * row.r as f64
                        && row.reduction_vs_vanilla <= row.r as f64 + 0.01,
                    "{row:?}"
                );
            }
        }
    }

    #[test]
    fn paper_headline_16x_byte_reduction() {
        // The §1 claim "reduces 16× communication costs" is about transmitted
        // volume; verify bytes shrink 16× (within header overhead) and that
        // the time reduction on a bandwidth-rich link is close behind.
        let spec = CutSpec::vgg16_cifar10();
        let rows = comm_report(&spec, 100);
        let r16 = rows
            .iter()
            .find(|r| r.scheme == "c3" && r.r == 16 && r.link == "wifi")
            .unwrap();
        let (vup, vdown) = step_payload_bytes(&spec, 1, Scheme::Vanilla);
        let byte_ratio = (vup + vdown) as f64
            / (r16.uplink_bytes_per_step + r16.downlink_bytes_per_step) as f64;
        assert!(byte_ratio > 15.5 && byte_ratio <= 16.0, "{byte_ratio}");
        assert!(r16.reduction_vs_vanilla > 10.0, "{}", r16.reduction_vs_vanilla);
    }
}
