//! Dataset substrate: CIFAR binary loader + SynthCIFAR procedural dataset,
//! shuffling sampler, batcher and light augmentation.
//!
//! This environment has no network access, so `make artifacts`/examples use
//! **SynthCIFAR** — a procedural class-conditional image distribution that
//! exercises the identical code path (conv stacks, split, codec, Adam) and is
//! learnable-but-nontrivial.  If real CIFAR binaries are present under
//! `data/cifar-10-batches-bin/` (or `data/cifar-100-binary/`) the loader
//! picks them up instead.  See DESIGN.md §3 (substitutions).
pub mod cifar;
pub mod synth;

use crate::tensor::{Labels, Tensor};
use crate::util::rng::Rng;

/// A labelled image dataset with fixed geometry.
pub trait Dataset: Send + Sync {
    /// Number of examples.
    fn len(&self) -> usize;
    /// True when the dataset holds no examples.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Number of distinct class labels.
    fn num_classes(&self) -> usize;
    /// (channels, height, width)
    fn image_shape(&self) -> (usize, usize, usize);
    /// Write example `i` (CHW, f32, normalized) into `out`; return its label.
    fn fetch(&self, i: usize, out: &mut [f32]) -> i32;
    /// Stable human-readable dataset name (used in logs and CSV venues).
    fn name(&self) -> &str;
}

/// Batch of images + labels, ready for the runtime.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Image tensor shaped `[batch, channels, height, width]`.
    pub images: Tensor,
    /// Class label per image (length = batch).
    pub labels: Labels,
}

/// Epoch-shuffling batcher with optional augmentation.
pub struct Loader<'a> {
    ds: &'a dyn Dataset,
    batch: usize,
    rng: Rng,
    augment: bool,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    scratch: Vec<f32>,
}

impl<'a> Loader<'a> {
    /// Loader over `ds` producing `batch`-sized batches, shuffled by `seed`,
    /// with the CIFAR flip/crop augmentation when `augment` is set.
    /// Panics when `batch` is 0 or exceeds the dataset size.
    pub fn new(ds: &'a dyn Dataset, batch: usize, seed: u64, augment: bool) -> Self {
        assert!(batch > 0 && batch <= ds.len(), "batch {batch} vs dataset {}", ds.len());
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        let (c, h, w) = ds.image_shape();
        Loader {
            ds,
            batch,
            rng,
            augment,
            order,
            cursor: 0,
            epoch: 0,
            scratch: vec![0.0; c * h * w],
        }
    }

    /// Completed passes over the dataset so far (0 during the first).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Full batches one pass over the dataset yields (remainder dropped).
    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len() / self.batch
    }

    /// Next batch; reshuffles (and bumps epoch) when the dataset is exhausted.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let (c, h, w) = self.ds.image_shape();
        let stride = c * h * w;
        let mut images = vec![0.0f32; self.batch * stride];
        let mut labels = Vec::with_capacity(self.batch);
        for bi in 0..self.batch {
            let idx = self.order[self.cursor + bi];
            let dst = &mut images[bi * stride..(bi + 1) * stride];
            let label = self.ds.fetch(idx, dst);
            labels.push(label);
            if self.augment {
                augment_inplace(&mut self.rng, dst, c, h, w, &mut self.scratch);
            }
        }
        self.cursor += self.batch;
        Batch {
            images: Tensor::from_vec(&[self.batch, c, h, w], images),
            labels: Labels(labels),
        }
    }

    /// Deterministic, un-augmented evaluation batches over the whole set.
    pub fn eval_batches(ds: &'a dyn Dataset, batch: usize) -> Vec<Batch> {
        let (c, h, w) = ds.image_shape();
        let stride = c * h * w;
        let n = ds.len() / batch;
        (0..n)
            .map(|bi| {
                let mut images = vec![0.0f32; batch * stride];
                let mut labels = Vec::with_capacity(batch);
                for i in 0..batch {
                    let label =
                        ds.fetch(bi * batch + i, &mut images[i * stride..(i + 1) * stride]);
                    labels.push(label);
                }
                Batch {
                    images: Tensor::from_vec(&[batch, c, h, w], images),
                    labels: Labels(labels),
                }
            })
            .collect()
    }
}

/// Random horizontal flip + pad-2 random crop (the standard CIFAR recipe).
fn augment_inplace(rng: &mut Rng, img: &mut [f32], c: usize, h: usize, w: usize,
                   scratch: &mut Vec<f32>) {
    // horizontal flip
    if rng.next_u64() & 1 == 1 {
        for ch in 0..c {
            for y in 0..h {
                let row = &mut img[ch * h * w + y * w..ch * h * w + (y + 1) * w];
                row.reverse();
            }
        }
    }
    // shift by dx, dy ∈ [-2, 2] with zero padding
    let dx = rng.below(5) as isize - 2;
    let dy = rng.below(5) as isize - 2;
    if dx == 0 && dy == 0 {
        return;
    }
    scratch.resize(c * h * w, 0.0);
    scratch.copy_from_slice(img);
    for v in img.iter_mut() {
        *v = 0.0;
    }
    for ch in 0..c {
        for y in 0..h {
            let sy = y as isize + dy;
            if sy < 0 || sy >= h as isize {
                continue;
            }
            for x in 0..w {
                let sx = x as isize + dx;
                if sx < 0 || sx >= w as isize {
                    continue;
                }
                img[ch * h * w + y * w + x] =
                    scratch[ch * h * w + sy as usize * w + sx as usize];
            }
        }
    }
}

/// Open the best available dataset: real CIFAR if the binaries exist under
/// `root`, otherwise SynthCIFAR with the given geometry.
pub fn open_dataset(root: &str, classes: usize, image: usize, train: bool,
                    synth_len: usize) -> Box<dyn Dataset> {
    if classes == 10 && image == 32 {
        if let Ok(ds) = cifar::Cifar10::open(root, train) {
            return Box::new(ds);
        }
    }
    if classes == 100 && image == 32 {
        if let Ok(ds) = cifar::Cifar100::open(root, train) {
            return Box::new(ds);
        }
    }
    Box::new(synth::SynthCifar::new(classes, image, synth_len, if train { 1 } else { 2 }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loader_covers_epoch_without_repeats() {
        let ds = synth::SynthCifar::new(4, 8, 64, 1);
        let mut loader = Loader::new(&ds, 16, 7, false);
        assert_eq!(loader.batches_per_epoch(), 4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let b = loader.next_batch();
            for i in 0..16 {
                // identify examples by hashing their first pixels + label
                let row = &b.images.data()[i * 3 * 64..i * 3 * 64 + 8];
                let key = format!("{:?}{}", row, b.labels.0[i]);
                assert!(seen.insert(key), "duplicate example within epoch");
            }
        }
        assert_eq!(loader.epoch(), 0);
        loader.next_batch();
        assert_eq!(loader.epoch(), 1);
    }

    #[test]
    fn batches_have_right_shape() {
        let ds = synth::SynthCifar::new(10, 16, 128, 1);
        let mut loader = Loader::new(&ds, 32, 3, true);
        let b = loader.next_batch();
        assert_eq!(b.images.shape(), &[32, 3, 16, 16]);
        assert_eq!(b.labels.len(), 32);
        assert!(b.labels.0.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn eval_batches_deterministic() {
        let ds = synth::SynthCifar::new(4, 8, 64, 2);
        let a = Loader::eval_batches(&ds, 16);
        let b = Loader::eval_batches(&ds, 16);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.images, y.images);
            assert_eq!(x.labels, y.labels);
        }
    }

    #[test]
    fn augmentation_preserves_shape_and_energy_scale() {
        let ds = synth::SynthCifar::new(4, 16, 64, 1);
        let mut plain = Loader::new(&ds, 32, 5, false);
        let mut aug = Loader::new(&ds, 32, 5, true);
        let b1 = plain.next_batch();
        let b2 = aug.next_batch();
        assert_eq!(b1.images.shape(), b2.images.shape());
        // augmented energy is within 2x of plain (crop zeroes some border)
        let e1 = b1.images.norm();
        let e2 = b2.images.norm();
        assert!(e2 > 0.3 * e1 && e2 < 2.0 * e1, "{e1} vs {e2}");
    }

    #[test]
    fn open_dataset_falls_back_to_synth() {
        let ds = open_dataset("/nonexistent", 10, 16, true, 256);
        assert_eq!(ds.len(), 256);
        assert_eq!(ds.num_classes(), 10);
        assert!(ds.name().starts_with("synth"));
    }
}
