//! Multi-client coordinator: one cloud serving N concurrent edges, with
//! per-client and aggregate `LinkStats`, in either of two serving styles:
//!
//! * **thread-per-client** ([`serve_clients`]) — one OS thread per edge,
//!   blocking transports; simple, but thread stacks and context switches cap
//!   concurrency at the dozens;
//! * **reactor** ([`serve_clients_reactor`]) — one I/O thread multiplexes
//!   every edge over nonblocking connections ([`crate::transport::reactor`])
//!   and feeds decode/step/encode jobs to a pool of `scheme.workers` codec
//!   threads, each owning a `C3Scratch`; per-client outbox bounds and a
//!   parsed-job bound give slow or pipelining clients genuine backpressure
//!   without stalling anyone else.  This is the thousand-edge path.
//!
//! The PJRT model halves are artifact-gated (runtime::xla_stub), so this
//! scenario exercises the full *codec + transport + accounting* stack
//! host-natively: each edge holds a feature buffer z, uplinks `encode(z)`
//! with labels, and the cloud decodes, evaluates the quadratic probe
//! objective L = ½·mean(ẑ²), encodes the gradient gẑ = ẑ/N and downlinks it
//! with the step stats — the same message protocol the single-edge
//! CloudWorker speaks.  The edge applies the decoded gradient to z (toy
//! SGD), so the objective genuinely decreases end-to-end *through* the lossy
//! codec in both directions — the property the tests assert.
//!
//! Both endpoints build their `RunCodec` from the shared key seed; the R×D
//! key matrix never crosses the wire (same key-agreement contract as the
//! single-edge coordinator).

use super::run_codec::RunCodec;
use crate::tensor::{Labels, Tensor};
use crate::transport::reactor::{Event, Reactor, ReactorConfig, ReactorConn};
use crate::transport::{Msg, Transport};
use crate::util::error::{C3Error, Context, Result};
use crate::util::rng::Rng;
use crate::{bail, ensure};

/// Per-client report from the multi-edge cloud (its half of the link).
#[derive(Clone, Debug)]
pub struct ClientReport {
    /// Accept-order client index.
    pub client: usize,
    /// Training steps served for this client.
    pub steps: u64,
    /// Bytes the cloud sent to this client (downlink).
    pub tx_bytes: u64,
    /// Bytes the cloud received from this client (uplink).
    pub rx_bytes: u64,
    /// Messages sent to this client.
    pub tx_msgs: u64,
    /// Messages received from this client.
    pub rx_msgs: u64,
    /// Probe loss at the client's final served step.
    pub last_loss: f32,
}

/// Aggregated multi-client stats.
#[derive(Clone, Debug, Default)]
pub struct MultiStats {
    /// One report per client, in accept order.
    pub per_client: Vec<ClientReport>,
}

impl MultiStats {
    /// Total downlink bytes across clients.
    pub fn total_tx(&self) -> u64 {
        self.per_client.iter().map(|c| c.tx_bytes).sum()
    }

    /// Total uplink bytes across clients.
    pub fn total_rx(&self) -> u64 {
        self.per_client.iter().map(|c| c.rx_bytes).sum()
    }

    /// Total training steps served across clients.
    pub fn total_steps(&self) -> u64 {
        self.per_client.iter().map(|c| c.steps).sum()
    }
}

/// Per-edge report (the edge's half of the link).
#[derive(Clone, Debug)]
pub struct EdgeReport {
    /// Training steps this edge ran.
    pub steps: u64,
    /// Probe loss reported by the cloud at the first step.
    pub first_loss: f32,
    /// Probe loss reported by the cloud at the final step.
    pub last_loss: f32,
    /// Bytes this edge sent (uplink).
    pub tx_bytes: u64,
    /// Bytes this edge received (downlink).
    pub rx_bytes: u64,
}

/// The probe objective L = ½·mean(ẑ²) on a raw slice (the codec workers
/// operate on `decode_into` output buffers, no Tensor in the loop).
fn probe_loss_slice(z: &[f32]) -> f32 {
    let n = z.len().max(1) as f32;
    0.5 * z.iter().map(|v| v * v).sum::<f32>() / n
}

fn probe_loss(zhat: &Tensor) -> f32 {
    probe_loss_slice(zhat.data())
}

/// Serve one edge until it sends Shutdown: decode uplink features, evaluate
/// the probe objective, encode the gradients back.
pub fn serve_one(
    codec: &RunCodec,
    transport: &mut dyn Transport,
    client: usize,
) -> Result<ClientReport> {
    let mut pending: Option<(u64, Tensor)> = None;
    let mut steps = 0u64;
    let mut last_loss = 0.0f32;
    loop {
        match transport.recv()? {
            Msg::KeySeed { .. } => {
                // keys already derived from the shared seed at construction
            }
            Msg::Features { step, tensor } => {
                ensure!(
                    pending.is_none(),
                    "client {client}: Features while a step is pending"
                );
                pending = Some((step, tensor));
            }
            Msg::TrainLabels { step, .. } => {
                let (fstep, s) = pending
                    .take()
                    .with_context(|| format!("client {client}: labels before features"))?;
                ensure!(
                    fstep == step,
                    "client {client}: label step mismatch {step} != {fstep}"
                );
                let zhat = codec.decode(&s)?;
                let loss = probe_loss(&zhat);
                // gẑ = dL/dẑ = ẑ/N, compressed for the downlink like the
                // real cloud compresses cut-layer gradients
                let gz = zhat.scale(1.0 / zhat.len().max(1) as f32);
                let gs = codec.encode(&gz)?;
                last_loss = loss;
                steps += 1;
                transport.send(&Msg::Gradients { step, tensor: gs })?;
                transport.send(&Msg::StepStats { step, loss, ncorrect: 0.0 })?;
            }
            Msg::EvalFeatures { step, tensor, labels } => {
                let zhat = codec.decode(&tensor)?;
                let loss = probe_loss(&zhat);
                transport.send(&Msg::EvalStats {
                    step,
                    loss,
                    ncorrect: labels.len() as f32,
                })?;
            }
            Msg::Shutdown => break,
            other => bail!("client {client}: unexpected message {other:?}"),
        }
    }
    let stats = transport.stats();
    Ok(ClientReport {
        client,
        steps,
        tx_bytes: stats.tx(),
        rx_bytes: stats.rx(),
        tx_msgs: stats.tx_msgs.load(std::sync::atomic::Ordering::Relaxed),
        rx_msgs: stats.rx_msgs.load(std::sync::atomic::Ordering::Relaxed),
        last_loss,
    })
}

/// Serve N edges concurrently, one OS thread per client.
pub fn serve_clients<T: Transport>(codec: &RunCodec, transports: Vec<T>) -> Result<MultiStats> {
    let mut reports = std::thread::scope(|sc| -> Result<Vec<ClientReport>> {
        let handles: Vec<_> = transports
            .into_iter()
            .enumerate()
            .map(|(ci, mut tp)| sc.spawn(move || serve_one(codec, &mut tp, ci)))
            .collect();
        let mut reports = Vec::with_capacity(handles.len());
        for h in handles {
            reports.push(
                h.join()
                    .map_err(|_| C3Error::msg("cloud client thread panicked"))??,
            );
        }
        Ok(reports)
    })?;
    reports.sort_by_key(|r| r.client);
    Ok(MultiStats { per_client: reports })
}

// ---------------------------------------------------------------------------
// Reactor serving: one I/O thread, a codec worker pool, N edges.
// ---------------------------------------------------------------------------

/// A unit of codec compute parsed from one client's protocol stream.
struct Job {
    client: usize,
    step: u64,
    kind: JobKind,
}

enum JobKind {
    /// Features + labels arrived: decode, evaluate, encode the gradient.
    Train(Tensor),
    /// Eval request: decode and evaluate only (`usize` = label count).
    Eval(Tensor, usize),
}

/// What a codec worker hands back to the reactor thread.
struct Done {
    client: usize,
    result: Result<DoneOk>,
}

struct DoneOk {
    is_train: bool,
    loss: f32,
    /// Ready-to-queue wire frames (workers serialize replies too, keeping
    /// the reactor thread to pure I/O).
    frames: Vec<Vec<u8>>,
}

/// Per-client protocol state machine driven by reactor events.
#[derive(Default)]
struct ClientSm {
    /// Features awaiting their TrainLabels companion.
    pending: Option<(u64, Tensor)>,
    /// Parsed jobs not yet dispatched to the worker pool.
    jobs: std::collections::VecDeque<Job>,
    /// A job for this client is on the worker pool.
    inflight: bool,
    steps: u64,
    last_loss: f32,
    /// Shutdown received; close once compute and outbox drain.
    finishing: bool,
    /// Connection observed closed by the peer.
    peer_gone: bool,
    closed: bool,
    /// Why this client was failed, if it was.  One broken client never
    /// takes the pool down (matching thread-per-client, where a failing
    /// `serve_one` only errors its own thread); the aggregate error
    /// surfaces after every healthy client finishes.
    failed: Option<String>,
}

/// Fail one client without disturbing the rest: close its connection, drop
/// its queued work, and record the reason for the final aggregate error.
fn fail_client(
    st: &mut [ClientSm],
    reactor: &mut Reactor,
    open: &mut usize,
    client: usize,
    why: String,
) {
    let c = &mut st[client];
    if c.closed {
        return;
    }
    c.failed = Some(why);
    c.jobs.clear();
    c.pending = None;
    c.closed = true;
    reactor.close(client);
    *open -= 1;
}

/// One codec worker: pull jobs, run decode → probe step → encode with a
/// thread-local `C3Scratch` (zero codec allocations in steady state on the
/// host venue), serialize the reply frames, hand them back.
fn codec_worker(
    codec: &RunCodec,
    jobs: &std::sync::Mutex<std::sync::mpsc::Receiver<Job>>,
    done: std::sync::mpsc::Sender<Done>,
) {
    let engine = codec.host_engine();
    let mut scratch = engine.map(|c3| crate::hdc::C3Scratch::new(c3.keys.d));
    let mut zbuf: Vec<f32> = Vec::new();
    let mut sbuf: Vec<f32> = Vec::new();
    loop {
        let job = jobs.lock().expect("job queue lock").recv();
        let Ok(job) = job else { break };
        let client = job.client;
        let result = run_job(codec, engine, scratch.as_mut(), &mut zbuf, &mut sbuf, job);
        if done.send(Done { client, result }).is_err() {
            break;
        }
    }
}

/// Decode → probe objective → (for training) gradient encode, on either the
/// zero-allocation host engine or the generic [`RunCodec`] fallback.
fn run_job(
    codec: &RunCodec,
    engine: Option<&crate::hdc::C3>,
    scratch: Option<&mut crate::hdc::C3Scratch>,
    zbuf: &mut Vec<f32>,
    sbuf: &mut Vec<f32>,
    job: Job,
) -> Result<DoneOk> {
    use crate::transport::wire;
    match job.kind {
        JobKind::Train(s) => {
            let (loss, gs) = match (engine, scratch) {
                (Some(c3), Some(scr)) => {
                    let (r, d) = (c3.keys.r, c3.keys.d);
                    let g = s.shape()[0];
                    zbuf.resize(g * r * d, 0.0);
                    c3.decode_into(&s, zbuf, scr);
                    let loss = probe_loss_slice(zbuf);
                    // gẑ = dL/dẑ = ẑ/N, compressed for the downlink like the
                    // real cloud compresses cut-layer gradients
                    let inv = 1.0 / zbuf.len().max(1) as f32;
                    for v in zbuf.iter_mut() {
                        *v *= inv;
                    }
                    let gz = Tensor::from_vec(&[g * r, d], std::mem::take(zbuf));
                    sbuf.resize(g * d, 0.0);
                    c3.encode_into(&gz, sbuf, scr);
                    *zbuf = gz.into_vec(); // reclaim the buffer for the next job
                    (loss, Tensor::from_vec(&[g, d], std::mem::take(sbuf)))
                }
                _ => {
                    let zhat = codec.decode(&s)?;
                    let loss = probe_loss(&zhat);
                    let gz = zhat.scale(1.0 / zhat.len().max(1) as f32);
                    (loss, codec.encode(&gz)?)
                }
            };
            let gmsg = Msg::Gradients { step: job.step, tensor: gs };
            let frames = vec![
                wire::encode(&gmsg),
                wire::encode(&Msg::StepStats { step: job.step, loss, ncorrect: 0.0 }),
            ];
            if engine.is_some() {
                // reclaim the encode buffer too: with both buffers recycled
                // the worker's steady state really is allocation-free on the
                // codec side (only the reply frames are fresh)
                let Msg::Gradients { tensor, .. } = gmsg else { unreachable!() };
                *sbuf = tensor.into_vec();
            }
            Ok(DoneOk { is_train: true, loss, frames })
        }
        JobKind::Eval(s, nlabels) => {
            let loss = match (engine, scratch) {
                (Some(c3), Some(scr)) => {
                    let (r, d) = (c3.keys.r, c3.keys.d);
                    let g = s.shape()[0];
                    zbuf.resize(g * r * d, 0.0);
                    c3.decode_into(&s, zbuf, scr);
                    probe_loss_slice(zbuf)
                }
                _ => probe_loss(&codec.decode(&s)?),
            };
            let frames = vec![wire::encode(&Msg::EvalStats {
                step: job.step,
                loss,
                ncorrect: nlabels as f32,
            })];
            Ok(DoneOk { is_train: false, loss, frames })
        }
    }
}

/// Reject wrong-geometry uplinks before they reach the worker pool (the host
/// engine's `decode_into` asserts on shape — one malicious client must not
/// take the shared pool down).
fn check_uplink_geometry(codec: &RunCodec, t: &Tensor, client: usize) -> Result<()> {
    if let Some(c3) = codec.host_engine() {
        ensure!(
            t.ndim() == 2 && t.shape()[1] == c3.keys.d,
            "client {client}: carrier shape {:?} does not match (G, {})",
            t.shape(),
            c3.keys.d
        );
    }
    Ok(())
}

/// Parse one client message into protocol state / compute jobs.  An `Err`
/// is a *per-client* protocol violation — the caller fails that client only.
fn handle_client_msg(
    codec: &RunCodec,
    c: &mut ClientSm,
    reactor: &mut Reactor,
    client: usize,
    msg: Msg,
) -> Result<()> {
    ensure!(!c.finishing, "client {client}: message after Shutdown");
    match msg {
        Msg::KeySeed { .. } => {
            // keys already derived from the shared seed at construction
        }
        Msg::Features { step, tensor } => {
            ensure!(
                c.pending.is_none(),
                "client {client}: Features while a step is pending"
            );
            check_uplink_geometry(codec, &tensor, client)?;
            c.pending = Some((step, tensor));
        }
        Msg::TrainLabels { step, .. } => {
            let (fstep, s) = c
                .pending
                .take()
                .with_context(|| format!("client {client}: labels before features"))?;
            ensure!(
                fstep == step,
                "client {client}: label step mismatch {step} != {fstep}"
            );
            c.jobs.push_back(Job { client, step, kind: JobKind::Train(s) });
        }
        Msg::EvalFeatures { step, tensor, labels } => {
            check_uplink_geometry(codec, &tensor, client)?;
            c.jobs.push_back(Job { client, step, kind: JobKind::Eval(tensor, labels.len()) });
        }
        Msg::Shutdown => {
            c.finishing = true;
            reactor.set_hold(client, true);
        }
        other => bail!("client {client}: unexpected message {other:?}"),
    }
    Ok(())
}

/// Apply one finished compute result: queue its reply frames and update the
/// client state machine.  A worker-side error fails that client only.
fn apply_done(
    done: Done,
    st: &mut [ClientSm],
    reactor: &mut Reactor,
    open: &mut usize,
    inflight_total: &mut usize,
) {
    let Done { client, result } = done;
    st[client].inflight = false;
    *inflight_total -= 1;
    match result {
        Ok(ok) => {
            let c = &mut st[client];
            if c.closed {
                return; // late result for an already-failed client
            }
            if ok.is_train {
                c.steps += 1;
                c.last_loss = ok.loss;
            }
            for frame in ok.frames {
                reactor.queue_frame(client, frame);
            }
        }
        Err(e) => {
            fail_client(st, reactor, open, client, format!("codec worker: {e}"));
        }
    }
}

/// Serve N edges from ONE I/O thread plus `workers` codec threads: the
/// reactor pumps frames, per-client state machines parse the protocol, a
/// shared job queue feeds the codec pool, and replies flow back through
/// bounded per-client outboxes.  Reports the same per-client accounting as
/// [`serve_clients`] — the two serving styles are interchangeable to the
/// edges and to the byte-accounting tests.
pub fn serve_clients_reactor(
    codec: &RunCodec,
    conns: Vec<Box<dyn ReactorConn>>,
    workers: usize,
    cfg: ReactorConfig,
) -> Result<MultiStats> {
    if conns.is_empty() {
        return Ok(MultiStats::default());
    }
    let (job_tx, job_rx) = std::sync::mpsc::channel::<Job>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<Done>();
    let job_rx = std::sync::Mutex::new(job_rx);
    std::thread::scope(|sc| {
        for _ in 0..workers.max(1) {
            let done_tx = done_tx.clone();
            let job_rx = &job_rx;
            sc.spawn(move || codec_worker(codec, job_rx, done_tx));
        }
        // only the workers hold Done senders now, so a dead pool is
        // observable as a disconnected done_rx
        drop(done_tx);
        // job_tx moves into the loop and drops on return, which is what
        // releases the workers (and lets this scope join them)
        reactor_serve_loop(codec, conns, cfg, job_tx, &done_rx)
    })
}

fn reactor_serve_loop(
    codec: &RunCodec,
    conns: Vec<Box<dyn ReactorConn>>,
    cfg: ReactorConfig,
    job_tx: std::sync::mpsc::Sender<Job>,
    done_rx: &std::sync::mpsc::Receiver<Done>,
) -> Result<MultiStats> {
    use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
    let n = conns.len();
    // this loop reads cfg bounds directly (step 3's hold), so normalize the
    // same way Reactor::new does
    let cfg = cfg.clamped();
    let mut reactor = Reactor::new(conns, cfg);
    let mut st: Vec<ClientSm> = (0..n).map(|_| ClientSm::default()).collect();
    let mut reports: Vec<Option<ClientReport>> = (0..n).map(|_| None).collect();
    let mut events: Vec<Event> = Vec::new();
    let mut open = n;
    let mut inflight_total = 0usize;

    while open > 0 {
        // 1) one fair I/O sweep; per-client failures (protocol violations,
        //    transport errors, mid-protocol hangups) close that client only
        let mut worked = reactor.poll(&mut events);
        for ev in events.drain(..) {
            match ev {
                Event::Msg { client, msg } => {
                    if st[client].closed {
                        continue;
                    }
                    if let Err(e) =
                        handle_client_msg(codec, &mut st[client], &mut reactor, client, msg)
                    {
                        fail_client(&mut st, &mut reactor, &mut open, client, e.to_string());
                    }
                }
                Event::Closed { client } => {
                    if st[client].finishing || st[client].closed {
                        st[client].peer_gone = true;
                    } else {
                        fail_client(
                            &mut st,
                            &mut reactor,
                            &mut open,
                            client,
                            "connection closed mid-protocol".into(),
                        );
                    }
                }
                Event::Error { client, error } => {
                    fail_client(&mut st, &mut reactor, &mut open, client, error.to_string());
                }
            }
        }

        // 2) collect finished compute without blocking
        loop {
            match done_rx.try_recv() {
                Ok(done) => {
                    worked = true;
                    apply_done(done, &mut st, &mut reactor, &mut open, &mut inflight_total);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    ensure!(
                        inflight_total == 0,
                        "codec worker pool died with {inflight_total} jobs in flight"
                    );
                    break;
                }
            }
        }

        // 3) dispatch ready jobs (one in flight per client keeps replies in
        //    step order) and refresh job-queue backpressure holds
        for ci in 0..n {
            let c = &mut st[ci];
            if c.closed {
                continue;
            }
            if !c.inflight {
                if let Some(job) = c.jobs.pop_front() {
                    job_tx
                        .send(job)
                        .map_err(|_| C3Error::msg("codec worker pool unavailable"))?;
                    c.inflight = true;
                    inflight_total += 1;
                    worked = true;
                }
            }
            if !c.finishing {
                let hold = c.jobs.len() >= cfg.max_pending_jobs;
                reactor.set_hold(ci, hold);
            }
        }

        // 4) retire clients whose protocol, compute and outbox all drained
        for ci in 0..n {
            let c = &mut st[ci];
            if !c.closed
                && c.finishing
                && !c.inflight
                && c.jobs.is_empty()
                && (c.peer_gone || reactor.outbox_len(ci) == 0)
            {
                let stats = reactor.stats(ci);
                reports[ci] = Some(ClientReport {
                    client: ci,
                    steps: c.steps,
                    tx_bytes: stats.tx(),
                    rx_bytes: stats.rx(),
                    tx_msgs: stats.tx_msgs.load(std::sync::atomic::Ordering::Relaxed),
                    rx_msgs: stats.rx_msgs.load(std::sync::atomic::Ordering::Relaxed),
                    last_loss: c.last_loss,
                });
                reactor.close(ci);
                c.closed = true;
                open -= 1;
                worked = true;
            }
        }

        // 5) idle: park briefly, but wake immediately on finished compute
        if !worked && open > 0 {
            match done_rx
                .recv_timeout(std::time::Duration::from_micros(cfg.poll_sleep_us.max(1)))
            {
                Ok(done) => {
                    apply_done(done, &mut st, &mut reactor, &mut open, &mut inflight_total)
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    ensure!(
                        inflight_total == 0,
                        "codec worker pool died with {inflight_total} jobs in flight"
                    );
                    reactor.idle_sleep();
                }
            }
        }
    }

    // every healthy client has fully retired; only now surface failures,
    // matching serve_clients (whose per-client threads all finish before
    // the aggregate join reports the first error)
    let failures: Vec<String> = st
        .iter()
        .enumerate()
        .filter_map(|(ci, c)| c.failed.as_ref().map(|why| format!("client {ci}: {why}")))
        .collect();
    ensure!(
        failures.is_empty(),
        "reactor serve: {} client(s) failed: {}",
        failures.len(),
        failures.join("; ")
    );

    Ok(MultiStats {
        per_client: reports
            .into_iter()
            .map(|r| r.expect("every retired client leaves a report"))
            .collect(),
    })
}

/// One synthetic edge: hold a (B, D) feature buffer, uplink `encode(z)`,
/// apply the decoded downlink gradient with a toy SGD step, repeat.  The
/// probe loss contracts geometrically when the codec round trip is faithful,
/// which is exactly what the multi-edge tests assert.
pub fn run_edge(
    codec: &RunCodec,
    transport: &mut dyn Transport,
    steps: u64,
    key_seed: u64,
    data_seed: u64,
    batch: usize,
    d: usize,
) -> Result<EdgeReport> {
    ensure!(steps >= 1, "edge needs at least one step");
    let mut rng = Rng::new(data_seed);
    let mut zdata = vec![0.0f32; batch * d];
    rng.fill_normal(&mut zdata, 0.0, 1.0);
    let mut z = Tensor::from_vec(&[batch, d], zdata);

    // Key agreement: announce the seed the codec keys derive from (the keys
    // never cross the wire).  This is the codec-construction seed, NOT the
    // per-edge data seed — a cloud that honors the handshake must arrive at
    // the same KeySet this edge encodes with.
    transport.send(&Msg::KeySeed { seed: key_seed })?;

    // Effective update: z ← (I − c·A²)z with A = D∘E.  decode = encodeᵀ
    // makes A PSD, but its top eigenvalue is max_f Σ_i |K̂_i(f)|² (well above
    // 1 for random keys), so c must be small for every mode to contract:
    // c·μ_max² < 2.  c = 0.005 leaves a wide margin at the R/D used here
    // while still shrinking the probe loss measurably over a few steps.
    let lr = 0.005f32 * (batch * d) as f32;
    let (mut first_loss, mut last_loss) = (0.0f32, 0.0f32);
    for step in 0..steps {
        let s = codec.encode(&z)?;
        transport.send(&Msg::Features { step, tensor: s })?;
        transport.send(&Msg::TrainLabels { step, labels: Labels(vec![0; batch]) })?;

        let gs = match transport.recv()? {
            Msg::Gradients { step: gstep, tensor } => {
                ensure!(gstep == step, "gradient step mismatch: {gstep} != {step}");
                tensor
            }
            other => bail!("edge expected Gradients, got {other:?}"),
        };
        let loss = match transport.recv()? {
            Msg::StepStats { loss, .. } => loss,
            other => bail!("edge expected StepStats, got {other:?}"),
        };

        let gz = codec.decode(&gs)?;
        ensure!(
            gz.shape() == z.shape(),
            "gradient shape {:?} vs features {:?}",
            gz.shape(),
            z.shape()
        );
        z = z.sub(&gz.scale(lr));

        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
    }
    transport.send(&Msg::Shutdown)?;
    let stats = transport.stats();
    Ok(EdgeReport {
        steps,
        first_loss,
        last_loss,
        tx_bytes: stats.tx(),
        rx_bytes: stats.rx(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{inproc_pair, inproc_reactor_pair};

    #[test]
    fn single_client_roundtrip_decreases_probe_loss() {
        let (mut etp, ctp) = inproc_pair();
        let cloud_codec = RunCodec::host(7, 2, 128, 1);
        let edge_codec = RunCodec::host(7, 2, 128, 1);
        let (cloud, edge) = std::thread::scope(|sc| {
            let cloud = sc.spawn(move || {
                let mut tp = ctp;
                serve_one(&cloud_codec, &mut tp, 0)
            });
            let edge = run_edge(&edge_codec, &mut etp, 8, 7, 3, 4, 128).unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.steps, 8);
        assert_eq!(edge.steps, 8);
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease: {} -> {}",
            edge.first_loss,
            edge.last_loss
        );
        // the two halves of the link must agree byte-for-byte
        assert_eq!(cloud.rx_bytes, edge.tx_bytes);
        assert_eq!(cloud.tx_bytes, edge.rx_bytes);
    }

    #[test]
    fn reactor_single_client_matches_thread_per_client_contract() {
        let (mut etp, cloud_conn) = inproc_reactor_pair();
        let cloud_codec = RunCodec::host(7, 2, 128, 1);
        let edge_codec = RunCodec::host(7, 2, 128, 1);
        let (cloud, edge) = std::thread::scope(|sc| {
            let cloud = sc.spawn(move || {
                let conns: Vec<Box<dyn ReactorConn>> = vec![Box::new(cloud_conn)];
                serve_clients_reactor(&cloud_codec, conns, 2, ReactorConfig::default())
            });
            let edge = run_edge(&edge_codec, &mut etp, 8, 7, 3, 4, 128).unwrap();
            (cloud.join().unwrap().unwrap(), edge)
        });
        assert_eq!(cloud.per_client.len(), 1);
        let c = &cloud.per_client[0];
        assert_eq!(c.steps, 8);
        assert!(
            edge.last_loss < edge.first_loss,
            "probe loss did not decrease: {} -> {}",
            edge.first_loss,
            edge.last_loss
        );
        // both halves of the link agree byte-for-byte, like serve_one
        assert_eq!(c.rx_bytes, edge.tx_bytes);
        assert_eq!(c.tx_bytes, edge.rx_bytes);
        assert_eq!(c.rx_msgs, 8 * 2 + 2);
        assert_eq!(c.tx_msgs, 8 * 2);
    }

    #[test]
    fn reactor_rejects_bad_geometry_uplink() {
        let (mut etp, cloud_conn) = inproc_reactor_pair();
        let cloud_codec = RunCodec::host(1, 2, 64, 1);
        let err = std::thread::scope(|sc| {
            let cloud = sc.spawn(move || {
                let conns: Vec<Box<dyn ReactorConn>> = vec![Box::new(cloud_conn)];
                serve_clients_reactor(&cloud_codec, conns, 1, ReactorConfig::default())
            });
            // wrong feature dim (32 != 64) must fail the serve, not panic a
            // shared worker
            etp.send(&Msg::Features { step: 0, tensor: Tensor::zeros(&[2, 32]) }).unwrap();
            cloud.join().unwrap()
        });
        assert!(err.is_err(), "bad geometry must surface as an error");
    }

    #[test]
    fn reactor_isolates_one_broken_client() {
        // One client vanishing mid-protocol must not take the pool down:
        // the healthy edges train to completion, and the failure surfaces
        // only in the aggregate result afterwards (same contract as the
        // thread-per-client pool, where serve_one fails its own thread).
        let (mut e1, c1) = inproc_reactor_pair();
        let (mut e2, c2) = inproc_reactor_pair();
        let (e3, c3) = inproc_reactor_pair();
        let cloud_codec = RunCodec::host(3, 2, 64, 1);
        let edge_codec = RunCodec::host(3, 2, 64, 1);
        let (serve_result, a, b) = std::thread::scope(|sc| {
            let cloud = sc.spawn(move || {
                let conns: Vec<Box<dyn ReactorConn>> =
                    vec![Box::new(c1), Box::new(c2), Box::new(c3)];
                serve_clients_reactor(&cloud_codec, conns, 2, ReactorConfig::default())
            });
            drop(e3); // client 2 hangs up without ever speaking
            let a = run_edge(&edge_codec, &mut e1, 5, 3, 1, 4, 64).unwrap();
            let b = run_edge(&edge_codec, &mut e2, 5, 3, 2, 4, 64).unwrap();
            (cloud.join().unwrap(), a, b)
        });
        assert!(a.last_loss < a.first_loss, "edge 0 must finish training");
        assert!(b.last_loss < b.first_loss, "edge 1 must finish training");
        let err = serve_result.expect_err("broken client must surface as an error");
        assert!(err.to_string().contains("client 2"), "{err}");
    }

    #[test]
    fn serve_clients_reports_per_client() {
        let (mut e1, c1) = inproc_pair();
        let (mut e2, c2) = inproc_pair();
        let cloud_codec = RunCodec::host(9, 2, 64, 1);
        let edge_codec = RunCodec::host(9, 2, 64, 1);
        let stats = std::thread::scope(|sc| {
            let cloud = sc.spawn(|| serve_clients(&cloud_codec, vec![c1, c2]));
            let a = run_edge(&edge_codec, &mut e1, 3, 9, 1, 4, 64).unwrap();
            let b = run_edge(&edge_codec, &mut e2, 4, 9, 2, 4, 64).unwrap();
            let stats = cloud.join().unwrap().unwrap();
            assert_eq!(stats.total_rx(), a.tx_bytes + b.tx_bytes);
            stats
        });
        assert_eq!(stats.per_client.len(), 2);
        assert_eq!(stats.per_client[0].client, 0);
        assert_eq!(stats.per_client[1].client, 1);
        assert_eq!(stats.total_steps(), 3 + 4);
    }
}
