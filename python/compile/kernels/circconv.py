# L1: Pallas kernels for the C3-SL codec (paper §3.1 encoder, §3.2 decoder).
#
# The paper's encoder is the *direct* O(D^2) circular convolution (Table 2
# counts D^2 MACs per bind, not D log D) fused with the superposition sum.
# On GPU the authors relied on framework ops; here the hot-spot is re-thought
# for the TPU memory hierarchy:
#
#   * Circular convolution with a fixed key is a matvec against a circulant
#     matrix.  We tile the OUTPUT index n into TN-wide blocks; for each block
#     we materialize the rotated feature slice Zrot[n, m] = z[(n − m) mod D]
#     in VMEM via broadcasted_iota index arithmetic and contract it against
#     the key on the MXU:  out[n0:n0+TN] = Zrot @ k   — an (TN, D)·(D,)
#     systolic-friendly contraction instead of a gather-per-output loop.
#   * The superposition Σ_i K_i ⊛ Z_i accumulates across the sequential key
#     grid dimension directly into the output ref, so the compressed feature
#     never round-trips to HBM between binds (the GPU equivalent would be a
#     shared-memory reduction; on TPU the output block simply stays in VMEM).
#   * VMEM budget per grid step:  TN·D·4 (rotated slice) + D·4 (feature row)
#     + D·4 (key row) + TN·4 (out tile).  With TN=256, D=4096 that is
#     ≈ 4.2 MiB — comfortably inside a 16 MiB VMEM budget, leaving room for
#     double buffering of the streamed z rows.
#
# interpret=True is mandatory here: the CPU PJRT client cannot execute the
# Mosaic custom-calls a real TPU lowering would emit.  Numerics are verified
# against the FFT oracle in ref.py (a different algorithm) by pytest.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["c3_encode", "c3_decode", "pick_tile", "DEFAULT_TILE", "DEFAULT_VARIANT"]

DEFAULT_TILE = 256

# Kernel variant (see §Perf in DESIGN.md / EXPERIMENTS.md):
#   "matvec" — v1: grid (G, R, D/TN); each step gathers the rotated FEATURE
#              slice and contracts (TN, D) @ (D,) — one matvec per feature.
#              Simple, but a matvec feeds the 128×128 MXU one output column
#              at a time (~1/128 utilization at f32).
#   "matmul" — v2 (default): uses the transposed identity
#              (k ⊛ z)[n] = Σ_m z[m] · k[(n−m) mod D],
#              so the gather builds a circulant tile of the KEY, shared by
#              every group, and each grid step computes
#              (G, D) @ (D, TN) → (G, TN) — a true matmul that batches all
#              G groups onto the MXU (utilization ∝ min(G,128)/1 better).
#              VMEM per step: D·TN·4 (key tile) + G·D·4 (features) + G·TN·4;
#              at D=4096, TN=256, G=8 that is 4.2 + 0.13 + 0.01 MiB.
DEFAULT_VARIANT = "matmul"


def pick_tile(d: int, requested: int = DEFAULT_TILE) -> int:
    """Largest power-of-two tile ≤ requested that divides D."""
    t = min(requested, d)
    while t > 1 and d % t != 0:
        t //= 2
    return max(t, 1)


# ---------------------------------------------------------------------------
# Encoder: bind (circular convolution) + superpose, Eq. (1)+(2)
# ---------------------------------------------------------------------------

def _encode_kernel(z_ref, k_ref, o_ref, *, tile: int, d: int):
    """Grid = (G, R, D // tile).

    Block views:  z_ref (1, 1, D) — feature row Z_i^g, resident in VMEM;
                  k_ref (1, D)    — key row K_i;
                  o_ref (1, tile) — output tile of S^g, accumulated over i.
    """
    i = pl.program_id(1)            # key index — sequential: safe accumulate
    t = pl.program_id(2)            # output-tile index
    z = z_ref[0, 0, :]              # (D,)
    k = k_ref[0, :]                 # (D,)

    n = t * tile + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)   # (tile,1)
    m = jax.lax.broadcasted_iota(jnp.int32, (tile, d), 1)              # (tile,D)
    idx = (n - m) % d                                                  # (n−m) mod D
    zrot = jnp.take(z, idx, axis=0)                                    # (tile, D) in VMEM
    part = jnp.dot(zrot, k, preferred_element_type=jnp.float32)        # MXU contraction
    part = part.astype(o_ref.dtype)

    @pl.when(i == 0)
    def _init():
        o_ref[0, :] = part

    @pl.when(i != 0)
    def _accum():
        o_ref[0, :] += part


def _encode_matvec(z, keys, tn):
    g, r, d = z.shape
    grid = (g, r, d // tn)
    return pl.pallas_call(
        functools.partial(_encode_kernel, tile=tn, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda gi, ri, ti: (gi, ri, 0)),
            pl.BlockSpec((1, d), lambda gi, ri, ti: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda gi, ri, ti: (gi, ti)),
        out_shape=jax.ShapeDtypeStruct((g, d), z.dtype),
        interpret=True,
    )(z, keys)


def _encode_matmul_kernel(z_ref, k_ref, o_ref, *, tile: int, d: int):
    """Grid = (R, D // tile).  v2: circulant-tile matmul, groups batched.

    Block views:  z_ref (G, 1, D) — feature rows Z_{:,i,:} for key i;
                  k_ref (1, D)    — key row K_i;
                  o_ref (G, tile) — output tile of S, accumulated over i.

    Uses (K_i ⊛ Z)[n] = Σ_m Z[m] · K_i[(n − m) mod D]: the gathered circulant
    tile Krot[m, n] = K_i[(n−m) mod D] is SHARED across groups, so the MXU
    sees one (G, D) @ (D, tile) contraction per step.
    """
    i = pl.program_id(0)
    t = pl.program_id(1)
    zg = z_ref[:, 0, :]                                                # (G, D)
    k = k_ref[0, :]                                                    # (D,)

    m = jax.lax.broadcasted_iota(jnp.int32, (d, tile), 0)              # (D, tile)
    n = t * tile + jax.lax.broadcasted_iota(jnp.int32, (d, tile), 1)
    krot = jnp.take(k, (n - m) % d, axis=0)                            # (D, tile)
    part = jnp.dot(zg, krot, preferred_element_type=jnp.float32)       # (G, tile)
    part = part.astype(o_ref.dtype)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i != 0)
    def _accum():
        o_ref[...] += part


def _encode_matmul(z, keys, tn):
    g, r, d = z.shape
    grid = (r, d // tn)
    return pl.pallas_call(
        functools.partial(_encode_matmul_kernel, tile=tn, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((g, 1, d), lambda ri, ti: (0, ri, 0)),
            pl.BlockSpec((1, d), lambda ri, ti: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((g, tn), lambda ri, ti: (0, ti)),
        out_shape=jax.ShapeDtypeStruct((g, d), z.dtype),
        interpret=True,
    )(z, keys)


@functools.partial(jax.jit, static_argnames=("tile", "variant"))
def c3_encode(z: jnp.ndarray, keys: jnp.ndarray, tile: int = DEFAULT_TILE,
              variant: str = DEFAULT_VARIANT) -> jnp.ndarray:
    """Compress z (G, R, D) with keys (R, D) into s (G, D).  Paper Eq. (1)+(2)."""
    g, r, d = z.shape
    assert keys.shape == (r, d), (z.shape, keys.shape)
    tn = pick_tile(d, tile)
    if variant == "matmul":
        return _encode_matmul(z, keys, tn)
    return _encode_matvec(z, keys, tn)


# ---------------------------------------------------------------------------
# Decoder: unbind (circular correlation), Eq. (3)
# ---------------------------------------------------------------------------

def _decode_kernel(s_ref, k_ref, o_ref, *, tile: int, d: int):
    """Grid = (G, R, D // tile).

    Block views:  s_ref (1, D)       — compressed feature S^g;
                  k_ref (1, D)       — key row K_i;
                  o_ref (1, 1, tile) — output tile of Ẑ_i^g.
    """
    t = pl.program_id(2)
    s = s_ref[0, :]
    k = k_ref[0, :]

    n = t * tile + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)
    m = jax.lax.broadcasted_iota(jnp.int32, (tile, d), 1)
    idx = (n + m) % d                                                  # (n+m) mod D
    srot = jnp.take(s, idx, axis=0)                                    # (tile, D)
    out = jnp.dot(srot, k, preferred_element_type=jnp.float32)
    o_ref[0, 0, :] = out.astype(o_ref.dtype)


def _decode_matvec(s, keys, tn):
    g, d = s.shape
    r = keys.shape[0]
    grid = (g, r, d // tn)
    return pl.pallas_call(
        functools.partial(_decode_kernel, tile=tn, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda gi, ri, ti: (gi, 0)),
            pl.BlockSpec((1, d), lambda gi, ri, ti: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tn), lambda gi, ri, ti: (gi, ri, ti)),
        out_shape=jax.ShapeDtypeStruct((g, r, d), s.dtype),
        interpret=True,
    )(s, keys)


def _decode_matmul_kernel(s_ref, k_ref, o_ref, *, tile: int, d: int):
    """Grid = (R, D // tile).  v2: circulant-tile matmul for correlation.

    (K_i ⋆ S)[n] = Σ_m S[m] · K_i[(m − n) mod D]: gather the key circulant
    Krot[m, n] = K_i[(m−n) mod D] (shared across groups) and contract
    (G, D) @ (D, tile) → (G, tile).
    """
    t = pl.program_id(1)
    sg = s_ref[...]                                                    # (G, D)
    k = k_ref[0, :]

    m = jax.lax.broadcasted_iota(jnp.int32, (d, tile), 0)
    n = t * tile + jax.lax.broadcasted_iota(jnp.int32, (d, tile), 1)
    krot = jnp.take(k, (m - n) % d, axis=0)                            # (D, tile)
    out = jnp.dot(sg, krot, preferred_element_type=jnp.float32)        # (G, tile)
    o_ref[:, 0, :] = out.astype(o_ref.dtype)


def _decode_matmul(s, keys, tn):
    g, d = s.shape
    r = keys.shape[0]
    grid = (r, d // tn)
    return pl.pallas_call(
        functools.partial(_decode_matmul_kernel, tile=tn, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((g, d), lambda ri, ti: (0, 0)),
            pl.BlockSpec((1, d), lambda ri, ti: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((g, 1, tn), lambda ri, ti: (0, ri, ti)),
        out_shape=jax.ShapeDtypeStruct((g, r, d), s.dtype),
        interpret=True,
    )(s, keys)


@functools.partial(jax.jit, static_argnames=("tile", "variant"))
def c3_decode(s: jnp.ndarray, keys: jnp.ndarray, tile: int = DEFAULT_TILE,
              variant: str = DEFAULT_VARIANT) -> jnp.ndarray:
    """Decode s (G, D) with keys (R, D) into ẑ (G, R, D).  Paper Eq. (3)."""
    g, d = s.shape
    r = keys.shape[0]
    assert keys.shape == (r, d), (s.shape, keys.shape)
    tn = pick_tile(d, tile)
    if variant == "matmul":
        return _decode_matmul(s, keys, tn)
    return _decode_matvec(s, keys, tn)
